//! Edge-cluster simulation: an 8-worker, 4-server deployment training all
//! four paper models — per-model normalized times plus the Fig. 11
//! scalability curve under server-side bandwidth contention.
//!
//! ```sh
//! cargo run --release --example edge_cluster_sim -- --workers 8
//! ```

use dynacomm::config::{Strategy, SystemConfig};
use dynacomm::figures::{self, Pass};
use dynacomm::models;
use dynacomm::sim::cluster;
use dynacomm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = SystemConfig::default().apply_args(&args);

    println!("=== per-model normalized times (batch={}) ===\n", cfg.batch);
    for pass in [Pass::Forward, Pass::Backward] {
        let cells = figures::normalized_pass_times(cfg.batch, pass);
        let label = if pass == Pass::Forward { "forward" } else { "backward" };
        println!("{}", figures::render_normalized(&cells, label));
    }

    println!("=== scalability: {}-worker cluster ===\n", cfg.workers);
    let model = models::by_name(&cfg.model).unwrap();
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>12}",
        "workers", "sequential", "lbl", "ibatch", "dynacomm"
    );
    let mut n = 1;
    while n <= cfg.workers {
        let mut row = format!("{n:<10}");
        for s in Strategy::ALL {
            row.push_str(&format!(
                " {:>12.2}",
                cluster::speedup(&model, &cfg, s, n)
            ));
        }
        println!("{row}");
        n *= 2;
    }
    Ok(())
}
