//! END-TO-END DRIVER: train EdgeCNN on a synthetic 10-class dataset through
//! the full three-layer stack —
//!
//!   L1  Pallas kernels (tiled matmul / im2col conv), AOT-lowered
//!   L2  layer-wise JAX fwd/bwd artifacts, executed via PJRT
//!   L3  this Rust coordinator: parameter-server shards + edge workers on
//!       real loopback TCP through the shaped edge network, with DynaComm
//!       scheduling the segmented pulls/pushes from live profiles.
//!
//! Logs the loss curve and accuracies; the run is recorded in
//! EXPERIMENTS.md. Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example train_edgecnn -- \
//!     --workers 2 --servers 2 --epochs 4 --iters 10 --strategy dynacomm
//! ```

use dynacomm::config::Strategy;
use dynacomm::runtime::artifacts_available;
use dynacomm::training::{train, TrainConfig};
use dynacomm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if !artifacts_available("artifacts") {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let mut cfg = TrainConfig::default();
    cfg.workers = args.usize("workers", 2);
    cfg.servers = args.usize("servers", 2);
    cfg.epochs = args.usize("epochs", 4);
    cfg.iters_per_epoch = args.usize("iters", 10);
    cfg.lr = args.f64("lr", cfg.lr as f64) as f32;
    cfg.setup_ms = args.f64("setup-ms", 2.0);
    cfg.latency_ms = args.f64("latency-ms", 1.0);
    cfg.bytes_per_ms = args.f64("bytes-per-ms", 500_000.0);
    if let Some(s) = args.get("gain-threshold-ms") {
        cfg.gain_threshold_ms = dynacomm::config::parse_gain_threshold(s)
            .ok_or_else(|| anyhow::anyhow!("bad --gain-threshold-ms '{s}'"))?;
    }
    if let Some(s) = args.get("strategy") {
        cfg.strategy = Strategy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --strategy '{s}'"))?;
    }
    if let Some(s) = args.get("codec") {
        cfg.codec = dynacomm::net::codec::CodecId::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --codec '{s}' (fp32|fp16|int8)"))?;
    }
    println!(
        "training edgecnn: {} workers x {} servers, {} epochs x {} iters, \
         strategy={}, codec={}",
        cfg.workers,
        cfg.servers,
        cfg.epochs,
        cfg.iters_per_epoch,
        cfg.strategy.name(),
        cfg.codec.name()
    );

    let r = train(&cfg)?;
    println!("\n{:<7} {:>10} {:>12} {:>12}", "epoch", "loss", "train-top1", "iter(ms)");
    for e in 0..r.epoch_loss.len() {
        println!(
            "{:<7} {:>10.4} {:>12.3} {:>12.1}",
            e, r.epoch_loss[e], r.epoch_train_acc[e], r.epoch_iter_ms[e]
        );
    }
    println!(
        "\nval-top1 = {:.3}   samples/sec/worker = {:.2}",
        r.val_acc, r.samples_per_sec_per_worker
    );
    for (w, rep) in r.per_worker.iter().enumerate() {
        if let Some(p) = rep.plans.last() {
            println!(
                "worker {w}: last plan change @iter {}: fwd {} / bwd {} segments \
                 (that re-plan took {:.3} ms; {} of {} re-plan calls reused the \
                 cached plan)",
                p.iter,
                p.fwd_segments,
                p.bwd_segments,
                p.sched_ms,
                rep.sched_reused,
                rep.sched_ms.len(),
            );
        }
    }
    Ok(())
}
