//! Sensitivity exploration (Fig. 9): how the iteration-time-reduced ratio
//! responds to batch size, bandwidth, and Δt — including the crossovers
//! the paper discusses (compute-bound beyond ~bs 24-48; comm-bound at
//! 1 Gbps).
//!
//! ```sh
//! cargo run --release --example schedule_sensitivity -- --model resnet152
//! ```

use dynacomm::config::{Strategy, SystemConfig};
use dynacomm::models;
use dynacomm::ps::sync::SyncMode;
use dynacomm::sim::straggler::{StragglerCluster, TierSpec};
use dynacomm::sim::{reduced_ratio, sweep};
use dynacomm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = SystemConfig::default().apply_args(&args);
    let model = models::by_name(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", cfg.model))?;

    let rows = sweep::sweep_batch(&model, &cfg, &[4, 8, 16, 24, 32, 48, 64, 96]);
    println!(
        "{}",
        dynacomm::figures::render_sweep(&rows, "batch", "reduced ratio vs batch size")
    );

    let rows =
        sweep::sweep_bandwidth(&model, &cfg, &[0.5, 1.0, 2.0, 5.0, 10.0, 20.0]);
    println!(
        "{}",
        dynacomm::figures::render_sweep(&rows, "gbps", "reduced ratio vs bandwidth")
    );

    // Δt sweep (beyond the paper: ablate the overhead the schedulers trade
    // against).
    println!("reduced ratio vs Δt (ms):");
    println!(
        "{:<10} {:>11} {:>11} {:>11}",
        "Δt", "lbl", "ibatch", "dynacomm"
    );
    for dt in [0.0, 2.0, 5.0, 9.0, 20.0, 50.0] {
        let mut c = cfg.clone();
        c.net.delta_t_ms = dt;
        let cv = model.cost_vectors(&c);
        println!(
            "{:<10} {:>11.4} {:>11.4} {:>11.4}",
            dt,
            reduced_ratio(&cv, Strategy::LayerByLayer),
            reduced_ratio(&cv, Strategy::IBatch),
            reduced_ratio(&cv, Strategy::DynaComm),
        );
    }

    // Codec sweep (AccEPT-style compressed transfers): as the wire codec
    // shrinks pt/gt, DynaComm re-segments — transmissions get cheaper
    // relative to Δt, so the DP consolidates into fewer, larger segments
    // while the predicted iteration time drops.
    println!("\nwire codec sweep (DynaComm re-segmentation):");
    println!(
        "{:<8} {:>12} {:>14} {:>14} {:>14}",
        "codec", "wire-bytes", "fwd-segments", "bwd-segments", "iteration(ms)"
    );
    for codec in dynacomm::net::codec::CodecId::ALL {
        let mut c = cfg.clone();
        c.codec = codec;
        let cv = model.cost_vectors(&c);
        let r = dynacomm::sim::simulate_cv(&cv, Strategy::DynaComm);
        let wire: f64 = model
            .layers
            .iter()
            .map(|l| codec.wire_bytes_f64(l.param_bytes()))
            .sum();
        println!(
            "{:<8} {:>12.0} {:>14} {:>14} {:>14.1}",
            codec.name(),
            wire,
            r.sched.plan.fwd.num_transmissions(),
            r.sched.plan.bwd.num_transmissions(),
            r.total_ms(),
        );
    }

    // Sync-mode × straggler-severity sweep (ps/sync, ACE-Sync-style): the
    // DP can only re-segment *within* an iteration; when one worker runs
    // 2-8× slow, the BSP barrier stalls the whole fleet and the remaining
    // lever is the synchronization model. Cells are iteration-throughput
    // speedups over BSP on this model's simulated iteration time (8
    // workers, one straggler, horizon = 8 slowest-iterations, SSP bound
    // from --staleness-bound, default 4).
    let iter_ms =
        dynacomm::sim::simulate_cv(&model.cost_vectors(&cfg), Strategy::DynaComm).total_ms();
    let bound = if cfg.staleness_bound > 0 { cfg.staleness_bound } else { 4 };
    let workers = cfg.workers.max(2);
    println!("\nsync-mode x straggler sweep (speedup vs bsp, {workers} workers):");
    println!(
        "{:<10} {:>10} {:>16} {:>10} {:>14}",
        "slowdown",
        "bsp",
        format!("ssp(N={bound})"),
        "asp",
        "ssp max-lead"
    );
    for severity in [1.0, 2.0, 4.0, 8.0] {
        let c = StragglerCluster::one_straggler(iter_ms, workers, severity);
        let ssp = c.throughput(SyncMode::Ssp, bound, 8);
        println!(
            "{:<10} {:>10.2} {:>16.2} {:>10.2} {:>14.1}",
            format!("{severity}x"),
            c.speedup_vs_bsp(SyncMode::Bsp, 0, 8),
            c.speedup_vs_bsp(SyncMode::Ssp, bound, 8),
            c.speedup_vs_bsp(SyncMode::Asp, 0, 8),
            ssp.max_lead,
        );
    }

    // Tier sweep (ps/agg, docs/TOPOLOGY.md): group size × per-hop sync
    // mode on the same one-straggler cluster. Grouping buys cloud-ingress
    // reduction (~1/group) unconditionally; its throughput cost depends
    // on the hop modes — an edge-BSP group locksteps to its slowest
    // member, so a bigger group captures more victims of the straggler,
    // while a relaxed regional→cloud hop frees the clean groups. Columns
    // are edge/cloud mode pairs, speedup vs the flat BSP fleet.
    println!(
        "\ntier x per-hop sync sweep ({workers} workers, one 4x straggler, \
         speedup vs flat bsp):"
    );
    println!(
        "{:<12} {:>14} {:>10} {:>14} {:>18}",
        "group size",
        "cloud ingress",
        "bsp/bsp",
        format!("bsp/ssp({bound})"),
        format!("ssp({bound})/ssp({bound})")
    );
    let c = StragglerCluster::one_straggler(iter_ms, workers, 4.0);
    let flat_bsp = c.throughput(SyncMode::Bsp, 0, 8).iters_per_sec();
    for gs in [1usize, 2, 4, workers] {
        let cell = |edge: SyncMode, cloud: SyncMode| {
            c.tiered_throughput(
                TierSpec {
                    group_size: gs,
                    edge_sync: edge,
                    edge_bound: if edge == SyncMode::Ssp { bound } else { 0 },
                    cloud_sync: cloud,
                    cloud_bound: if cloud == SyncMode::Ssp { bound } else { 0 },
                },
                8,
            )
        };
        let bb = cell(SyncMode::Bsp, SyncMode::Bsp);
        println!(
            "{:<12} {:>14} {:>10.2} {:>14.2} {:>18.2}",
            gs,
            format!("x{:.3}", bb.cloud_ingress_ratio),
            bb.iters_per_sec() / flat_bsp,
            cell(SyncMode::Bsp, SyncMode::Ssp).iters_per_sec() / flat_bsp,
            cell(SyncMode::Ssp, SyncMode::Ssp).iters_per_sec() / flat_bsp,
        );
    }
    Ok(())
}
