//! Quickstart: schedule one iteration of ResNet-152 on the paper's default
//! edge testbed and compare all four strategies.
//!
//! ```sh
//! cargo run --release --example quickstart -- --model resnet152 --batch 32
//! ```

use dynacomm::config::{Strategy, SystemConfig};
use dynacomm::models;
use dynacomm::sim::{self, timeline};
use dynacomm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = SystemConfig::default().apply_args(&args);
    let model = models::by_name(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", cfg.model))?;
    let cv = model.cost_vectors(&cfg);

    println!(
        "== {} | {} layers | batch {} | {} Gbps nominal | Δt = {:.1} ms | \
         codec {} | sync {} ==",
        model.name,
        model.depth(),
        cfg.batch,
        cfg.net.bandwidth_gbps,
        cv.delta_t,
        cfg.codec.name(),
        cfg.sync.name()
    );
    // Sync modes (`--sync {bsp,ssp,asp}`, docs/SYNC.md): the schedules
    // below overlap communication *within* one worker's iteration; on a
    // heterogeneous fleet the synchronization model decides how much one
    // slow worker stalls the others. bsp is the paper's full barrier, ssp
    // bounds staleness at `--staleness-bound N` iterations, asp never
    // gates — sweep them against straggler severity with the
    // schedule_sensitivity example or `dynacomm train --sync ...`.
    println!(
        "   (sync modes: bsp barrier | ssp bounded staleness | asp async — \
         see docs/SYNC.md)"
    );
    // Topology (`--tier {flat,regional}`, docs/TOPOLOGY.md): `regional`
    // inserts group aggregators between the edge fleet and the cloud
    // shards — one combined push upstream per group, one shared pull
    // fan-out downstream, each hop with its own sync mode and codec
    // (`--group-size`, `--agg-sync`, `--agg-codec`).
    println!(
        "   (tiers: flat direct | regional edge->agg->cloud fan-in — \
         see docs/TOPOLOGY.md)"
    );
    // Observability (`--metrics-addr`, `--trace-out`, docs/OBSERVABILITY.md):
    // a real `dynacomm train` run can serve Prometheus snapshots of every
    // wire/sync/scheduler counter and export a Chrome trace of the
    // pull/compute/push overlap the schedules below only predict.
    println!(
        "   (observability: --metrics-addr host:port scrape | --trace-out \
         trace.json spans — see docs/OBSERVABILITY.md)\n"
    );

    let seq_total = sim::simulate_cv(&cv, Strategy::Sequential).total_ms();
    for s in Strategy::ALL {
        let r = sim::simulate_cv(&cv, s);
        println!(
            "{:<11} segments fwd/bwd = {:>3}/{:<3}  iteration = {:>9.1} ms  \
             (-{:.1}% vs sequential)",
            s.name(),
            r.sched.plan.fwd.num_transmissions(),
            r.sched.plan.bwd.num_transmissions(),
            r.total_ms(),
            100.0 * (1.0 - r.total_ms() / seq_total),
        );
    }

    // Show DynaComm's actual forward decomposition as segment ranges.
    let r = sim::simulate_cv(&cv, Strategy::DynaComm);
    println!("\nDynaComm forward segments (layer ranges):");
    let segs = r.sched.plan.fwd.fwd_segments();
    for chunk in segs.chunks(8) {
        let row: Vec<String> =
            chunk.iter().map(|(a, b)| format!("[{a}-{b}]")).collect();
        println!("  {}", row.join(" "));
    }

    // And the first few timeline events.
    println!("\nforward timeline (first 12 events):");
    let events = timeline::forward_timeline(&cv, &r.sched.plan.fwd);
    for e in events.iter().take(12) {
        println!(
            "  {:>8.1} .. {:>8.1} ms  {:?} layers {}-{}",
            e.start, e.end, e.kind, e.lo, e.hi
        );
    }

    // Fig. 3-style Gantt charts: the baseline vs the dynamic schedule.
    let seq = sim::simulate_cv(&cv, Strategy::Sequential);
    println!("\nsequential forward:");
    print!(
        "{}",
        dynacomm::sim::gantt::render(
            &timeline::forward_timeline(&cv, &seq.sched.plan.fwd),
            72
        )
    );
    println!("dynacomm forward:");
    print!("{}", dynacomm::sim::gantt::render(&events, 72));
    Ok(())
}
