"""Pure-jnp correctness oracles for the Pallas kernels and the model.

Everything here must avoid the Pallas path entirely: these are the ground
truth the kernels and the layer-wise model are tested against at build time
(pytest + hypothesis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """Ground truth for :func:`..kernels.matmul.matmul`."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def conv2d_3x3_same_ref(x, w):
    """Ground truth for :func:`..kernels.conv2d.conv2d_3x3_same` (NHWC/HWIO)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def maxpool2x2_ref(x):
    """2x2 stride-2 max pool, NHWC."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
