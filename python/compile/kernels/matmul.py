"""L1: tiled matmul as a Pallas kernel (the compute hot-spot).

The paper's testbed is CPU-edge machines; per the session's
Hardware-Adaptation rule we author the hot-spot the TPU way instead of a
mechanical port: the matmul is block-tiled for the MXU systolic array
(128x128x128 f32 tiles by default, VMEM-resident blocks expressed through
``BlockSpec``), accumulating in f32 with ``preferred_element_type``.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO so the same
artifact executes under the Rust PJRT client.

The kernel is wrapped in ``jax.custom_vjp`` so the layer-wise backward
functions in ``model.py`` can differentiate through it (``pallas_call`` has
no autodiff rule); the backward pass reuses the same Pallas kernel for
``gx = gy @ w^T`` and ``gw = x^T @ gy``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile, CPU-interpret-tuned (see EXPERIMENTS.md §Perf): interpret
# mode pays a fixed ~6 ms per grid step, so the fastest CPU execution uses
# as FEW grid steps as VMEM-equivalent budget allows. The sweep measured
# 12x speedup going bm 128→2048 on the conv im2col shapes. On a real TPU
# the same kernel should be built with (128, 128, 128)–(512, 128, 512)
# MXU-square tiles — blocks here stay within a 4 MiB x-block so the
# BlockSpec remains VMEM-legal either way (DESIGN.md §Hardware-Adaptation).
BLOCK_M = 4096
BLOCK_N = 128
BLOCK_K = 2048


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; grid axis 2 walks the K blocks."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _matmul_padded(x, w, bm: int, bn: int, bk: int):
    """Pallas matmul over inputs already padded to block multiples."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_raw(x, w, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """``x @ w`` through the Pallas kernel, no autodiff wrapper.

    Shapes need not be multiples of the block sizes; inputs are zero-padded
    up to block multiples (zeros contribute nothing to the contraction) and
    the result is sliced back.

    Block sizes adapt downward to the actual dims (8-aligned): padding a
    27-wide contraction to a 128-wide block would waste ~5x FLOPs — on the
    small edge models this library targets, shrinking the tile to the
    workload beats the fixed MXU-square tile. Dims ≥ the requested block
    keep the full 128 tile (the MXU-shaped choice for large layers). See
    EXPERIMENTS.md §Perf for the measured effect.
    """
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bk = min(bk, _ceil_to(k, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    out = _matmul_padded(xp, wp, bm, bn, bk)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, w):
    """Differentiable Pallas matmul: ``(m, k) @ (k, n) -> (m, n)`` in f32."""
    return matmul_raw(x, w)


def _matmul_fwd(x, w):
    return matmul_raw(x, w), (x, w)


def _matmul_bwd(res, gy):
    x, w = res
    gx = matmul_raw(gy, w.T)
    gw = matmul_raw(x.T, gy)
    return gx, gw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
