"""L1: 3x3 SAME conv2d lowered onto the Pallas matmul via im2col.

The TPU-shaped formulation of convolution: instead of a CUDA-style implicit
GEMM over threadblocks, patches are materialized (im2col — pure data
movement XLA fuses into the surrounding graph) and the contraction runs on
the MXU-tiled Pallas matmul from :mod:`.matmul`. Differentiability comes for
free: im2col is plain jnp (autodiff-able) and the matmul carries a custom
VJP.

Layout is NHWC for activations and HWIO for weights, matching
``jax.lax.conv_general_dilated`` in the reference oracle (``ref.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

from .matmul import matmul


def im2col_3x3_same(x):
    """Extract 3x3 SAME patches: ``(n, h, w, c) -> (n, h, w, 9*c)``.

    Feature order is ``(dy, dx, c)`` row-major, matching a row-major
    reshape of an HWIO weight tensor ``(3, 3, cin, cout) -> (9*cin, cout)``.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(xp[:, dy : dy + h, dx : dx + w, :])
    return jnp.concatenate(cols, axis=-1)


def conv2d_3x3_same(x, w):
    """3x3 stride-1 SAME convolution: ``(n,h,w,cin) * (3,3,cin,cout)``."""
    n, h, wd, cin = x.shape
    kh, kw, wcin, cout = w.shape
    assert (kh, kw) == (3, 3) and wcin == cin, (x.shape, w.shape)
    patches = im2col_3x3_same(x).reshape(n * h * wd, 9 * cin)
    wmat = w.reshape(9 * cin, cout)
    out = matmul(patches, wmat)
    return out.reshape(n, h, wd, cout)
