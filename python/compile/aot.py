"""AOT export: lower the layer-wise EdgeCNN to HLO-text artifacts.

This is the only place Python touches the system: ``make artifacts`` runs it
once, and the Rust coordinator (L3) loads the resulting ``artifacts/`` at
startup through PJRT. Interchange is HLO **text**, not serialized
``HloModuleProto`` — jax >= 0.5 emits protos with 64-bit instruction ids
that the image's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Emitted per batch size:

* ``<layer>_fwd.hlo.txt``  — ``(w, b, x) -> (y,)``
* ``<layer>_bwd.hlo.txt``  — ``(w, b, x, gy) -> (gw, gb, gx)``
* ``loss.hlo.txt``         — ``(logits, onehot) -> (loss, glogits)``
* ``full_fwd.hlo.txt``     — ``(w1, b1, ..., wL, bL, x) -> (logits,)``
* ``init/<layer>_{w,b}.bin`` — little-endian f32 initial parameters
* ``manifest.json``        — everything the Rust side needs to wire it up
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """jitted-and-lowered jax function -> XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _conv_flops(layer: M.LayerDef, batch: int) -> tuple[int, int]:
    """(fwd, bwd) FLOPs for one layer at the given batch size."""
    if layer.kind == "conv":
        h, w, _ = layer.in_shape
        _, _, cin, cout = layer.w_shape
        fwd = 2 * 9 * cin * cout * h * w * batch
    else:
        fin, fout = layer.w_shape
        fwd = 2 * fin * fout * batch
    # backward computes both the input and the weight gradient: ~2x forward.
    return fwd, 2 * fwd


def export(out_dir: str, batch: int, seed: int = 0, tuple1_wrap: bool = True) -> dict:
    """Lower every artifact into ``out_dir`` and return the manifest dict."""
    os.makedirs(os.path.join(out_dir, "init"), exist_ok=True)
    layers = M.edgecnn_layers()
    params = M.init_params(seed)

    manifest: dict = {
        "model": "edgecnn",
        "batch": batch,
        "seed": seed,
        "num_classes": 10,
        "input_shape": list(layers[0].in_shape),
        "loss": "loss.hlo.txt",
        "full_fwd": "full_fwd.hlo.txt",
        "layers": [],
    }

    for layer, (w, b) in zip(layers, params):
        fwd = M.make_layer_fwd(layer)
        bwd = M.make_layer_bwd(layer)
        x_spec = _spec((batch, *layer.in_shape))
        gy_spec = _spec((batch, *layer.out_shape))
        w_spec, b_spec = _spec(layer.w_shape), _spec(layer.b_shape)

        fwd_txt = to_hlo_text(jax.jit(fwd, keep_unused=True).lower(w_spec, b_spec, x_spec))
        bwd_txt = to_hlo_text(
            jax.jit(bwd, keep_unused=True).lower(w_spec, b_spec, x_spec, gy_spec)
        )
        fwd_file = f"{layer.name}_fwd.hlo.txt"
        bwd_file = f"{layer.name}_bwd.hlo.txt"
        with open(os.path.join(out_dir, fwd_file), "w") as f:
            f.write(fwd_txt)
        with open(os.path.join(out_dir, bwd_file), "w") as f:
            f.write(bwd_txt)

        w_file = f"init/{layer.name}_w.bin"
        b_file = f"init/{layer.name}_b.bin"
        np.asarray(w, dtype="<f4").tofile(os.path.join(out_dir, w_file))
        np.asarray(b, dtype="<f4").tofile(os.path.join(out_dir, b_file))

        fwd_flops, bwd_flops = _conv_flops(layer, batch)
        param_count = int(np.prod(layer.w_shape) + np.prod(layer.b_shape))
        manifest["layers"].append(
            {
                "name": layer.name,
                "kind": layer.kind,
                "w_shape": list(layer.w_shape),
                "b_shape": list(layer.b_shape),
                "in_shape": list(layer.in_shape),
                "out_shape": list(layer.out_shape),
                "pool": layer.pool,
                "relu": layer.relu,
                "fwd": fwd_file,
                "bwd": bwd_file,
                "w_init": w_file,
                "b_init": b_file,
                "param_count": param_count,
                "param_bytes": 4 * param_count,
                "fwd_flops": fwd_flops,
                "bwd_flops": bwd_flops,
            }
        )

    # Loss head.
    logits_spec = _spec((batch, 10))
    loss_txt = to_hlo_text(jax.jit(M.loss_fwd, keep_unused=True).lower(logits_spec, logits_spec))
    with open(os.path.join(out_dir, "loss.hlo.txt"), "w") as f:
        f.write(loss_txt)

    # Fused whole-model forward: used by the Rust integration tests to check
    # that layer-wise composition reproduces the monolithic lowering.
    def full(*args):
        ps = [(args[2 * i], args[2 * i + 1]) for i in range(len(layers))]
        return M.full_fwd(ps, args[-1])

    specs = []
    for layer in layers:
        specs += [_spec(layer.w_shape), _spec(layer.b_shape)]
    specs.append(_spec((batch, *layers[0].in_shape)))
    full_txt = to_hlo_text(jax.jit(full, keep_unused=True).lower(*specs))
    with open(os.path.join(out_dir, "full_fwd.hlo.txt"), "w") as f:
        f.write(full_txt)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    manifest = export(out_dir, args.batch, args.seed)
    n_files = 2 * len(manifest["layers"]) + 2
    print(f"exported {n_files} HLO artifacts (batch={args.batch}) to {out_dir}")


if __name__ == "__main__":
    main()
