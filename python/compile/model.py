"""L2: EdgeCNN — the paper's training workload, expressed layer-wise in JAX.

DynaComm schedules *per-layer* parameter pulls and gradient pushes that
overlap with *per-layer* compute. To let the Rust worker reproduce that
execution model faithfully, the model is not exported as one monolithic
train step: every parameterized layer gets its own forward function
``fwd(w, b, x) -> y`` and its own backward function
``bwd(w, b, x, gy) -> (gw, gb, gx)`` (derived with ``jax.vjp``), each lowered
to an independent HLO artifact. Transformation layers with no parameters
(pooling, flatten) are folded into the preceding/following parameterized
layer exactly as the paper prescribes (Section III-A).

EdgeCNN is a CIFAR-10-scale CNN (6 parameterized layers, ~280k params):

    conv1 3->16        (B,32,32,3)  -> (B,32,32,16)
    conv2 16->16 +pool              -> (B,16,16,16)
    conv3 16->32                    -> (B,16,16,32)
    conv4 32->32 +pool              -> (B,8,8,32)
    fc1   2048->128   (flatten)     -> (B,128)
    fc2   128->10                   -> (B,10) logits

Convolutions and dense layers run on the L1 Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.conv2d import conv2d_3x3_same
from .kernels.matmul import matmul
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class LayerDef:
    """Static description of one parameterized layer."""

    name: str
    kind: str  # "conv" | "fc"
    w_shape: Tuple[int, ...]
    b_shape: Tuple[int, ...]
    in_shape: Tuple[int, ...]  # without batch dim
    out_shape: Tuple[int, ...]  # without batch dim
    pool: bool = False  # 2x2 maxpool folded after activation
    relu: bool = True


def edgecnn_layers() -> List[LayerDef]:
    """The 6 parameterized layers of EdgeCNN (shapes without batch dim)."""
    return [
        LayerDef("conv1", "conv", (3, 3, 3, 16), (16,), (32, 32, 3), (32, 32, 16)),
        LayerDef(
            "conv2", "conv", (3, 3, 16, 16), (16,), (32, 32, 16), (16, 16, 16), pool=True
        ),
        LayerDef("conv3", "conv", (3, 3, 16, 32), (32,), (16, 16, 16), (16, 16, 32)),
        LayerDef(
            "conv4", "conv", (3, 3, 32, 32), (32,), (16, 16, 32), (8, 8, 32), pool=True
        ),
        LayerDef("fc1", "fc", (2048, 128), (128,), (8, 8, 32), (128,)),
        LayerDef("fc2", "fc", (128, 10), (10,), (128,), (10,), relu=False),
    ]


def _maxpool2x2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def make_layer_fwd(layer: LayerDef, use_ref: bool = False) -> Callable:
    """Forward function ``(w, b, x) -> y`` for one layer.

    ``use_ref=True`` swaps the Pallas kernels for the pure-jnp oracles —
    used only by the build-time test suite.
    """
    conv = ref.conv2d_3x3_same_ref if use_ref else conv2d_3x3_same
    mm = ref.matmul_ref if use_ref else matmul

    if layer.kind == "conv":

        def fwd(w, b, x):
            y = conv(x, w) + b
            if layer.relu:
                y = jax.nn.relu(y)
            if layer.pool:
                y = _maxpool2x2(y)
            return y

    elif layer.kind == "fc":

        def fwd(w, b, x):
            x2 = x.reshape(x.shape[0], -1)  # folds the flatten transform
            y = mm(x2, w) + b
            if layer.relu:
                y = jax.nn.relu(y)
            return y

    else:  # pragma: no cover - guarded by LayerDef construction
        raise ValueError(layer.kind)

    return fwd


def make_layer_bwd(layer: LayerDef, use_ref: bool = False) -> Callable:
    """Backward function ``(w, b, x, gy) -> (gw, gb, gx)`` for one layer."""
    fwd = make_layer_fwd(layer, use_ref=use_ref)

    def bwd(w, b, x, gy):
        _, vjp = jax.vjp(fwd, w, b, x)
        gw, gb, gx = vjp(gy)
        return gw, gb, gx

    return bwd


def loss_fwd(logits, onehot):
    """Softmax cross-entropy head: ``(logits, onehot) -> (loss, glogits)``.

    Returns both the mean loss and its gradient w.r.t. logits so the Rust
    worker gets the backward seed from a single PJRT call.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    n = logits.shape[0]
    glogits = (jax.nn.softmax(logits, axis=-1) - onehot) / n
    return loss, glogits


def init_params(seed: int = 0) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """He-normal initialization for every layer, deterministic in ``seed``."""
    key = jax.random.PRNGKey(seed)
    params = []
    for layer in edgecnn_layers():
        key, wk = jax.random.split(key)
        fan_in = 1
        for d in layer.w_shape[:-1]:
            fan_in *= d
        w = jax.random.normal(wk, layer.w_shape, jnp.float32) * jnp.sqrt(
            2.0 / fan_in
        )
        b = jnp.zeros(layer.b_shape, jnp.float32)
        params.append((w, b))
    return params


def full_fwd(params, x, use_ref: bool = False):
    """Whole-model forward (composition of the layer functions) -> logits."""
    for layer, (w, b) in zip(edgecnn_layers(), params):
        x = make_layer_fwd(layer, use_ref=use_ref)(w, b, x)
    return x


def full_loss(params, x, onehot, use_ref: bool = False):
    """Whole-model loss — autodiff ground truth for the layer-wise bwd."""
    logits = full_fwd(params, x, use_ref=use_ref)
    loss, _ = loss_fwd(logits, onehot)
    return loss
