"""AOT export: manifest integrity and HLO-text validity.

Uses a tiny batch so lowering every layer stays fast; the real artifacts are
produced by ``make artifacts``.
"""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export(str(out), batch=2, seed=0)
    return str(out), manifest


def test_manifest_written_and_parses(exported):
    out, manifest = exported
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["batch"] == 2
    assert len(on_disk["layers"]) == 6


def test_all_artifact_files_exist(exported):
    out, manifest = exported
    files = [manifest["loss"], manifest["full_fwd"]]
    for layer in manifest["layers"]:
        files += [layer["fwd"], layer["bwd"], layer["w_init"], layer["b_init"]]
    for f in files:
        path = os.path.join(out, f)
        assert os.path.exists(path), f
        assert os.path.getsize(path) > 0, f


def test_hlo_text_is_parseable_hlo(exported):
    out, manifest = exported
    for layer in manifest["layers"]:
        with open(os.path.join(out, layer["fwd"])) as f:
            text = f.read()
        assert "ENTRY" in text and "HloModule" in text, layer["name"]


def test_init_bins_match_model_init(exported):
    out, manifest = exported
    params = M.init_params(0)
    for layer, (w, b) in zip(manifest["layers"], params):
        w_disk = np.fromfile(os.path.join(out, layer["w_init"]), dtype="<f4")
        np.testing.assert_array_equal(w_disk, np.asarray(w).ravel())
        b_disk = np.fromfile(os.path.join(out, layer["b_init"]), dtype="<f4")
        np.testing.assert_array_equal(b_disk, np.asarray(b).ravel())


def test_flops_accounting_positive_and_ordered(exported):
    _, manifest = exported
    for layer in manifest["layers"]:
        assert layer["fwd_flops"] > 0
        assert layer["bwd_flops"] == 2 * layer["fwd_flops"]
        assert layer["param_bytes"] == 4 * layer["param_count"]
