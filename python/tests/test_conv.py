"""L1 correctness: im2col conv2d (Pallas matmul inside) vs lax conv oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv2d import conv2d_3x3_same, im2col_3x3_same

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    h=st.integers(1, 12),
    w=st.integers(1, 12),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref_random(n, h, w, cin, cout, seed):
    x = _rand((n, h, w, cin), seed)
    k = _rand((3, 3, cin, cout), seed + 1)
    got = conv2d_3x3_same(jnp.asarray(x), jnp.asarray(k))
    want = ref.conv2d_3x3_same_ref(x, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "shape,kshape",
    [
        ((2, 32, 32, 3), (3, 3, 3, 16)),  # conv1 of EdgeCNN
        ((2, 16, 16, 16), (3, 3, 16, 32)),  # conv3
        ((1, 8, 8, 32), (3, 3, 32, 32)),
    ],
)
def test_conv_edgecnn_shapes(shape, kshape):
    x, k = _rand(shape, 1), _rand(kshape, 2)
    got = conv2d_3x3_same(jnp.asarray(x), jnp.asarray(k))
    want = ref.conv2d_3x3_same_ref(x, k)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_im2col_feature_order():
    """Patch features must be (dy, dx, c) row-major — the weight reshape
    in conv2d_3x3_same silently depends on it."""
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    p = im2col_3x3_same(x)
    assert p.shape == (2, 4, 4, 27)
    # center tap (dy=1, dx=1) of an interior pixel equals the pixel itself.
    c = 3 * (1 * 3 + 1)
    np.testing.assert_array_equal(
        np.asarray(p[:, 1:3, 1:3, c : c + 3]), np.asarray(x[:, 1:3, 1:3, :])
    )


def test_conv_gradients_match_ref():
    x = _rand((2, 6, 6, 4), 3)
    k = _rand((3, 3, 4, 5), 4)

    def f_pallas(x, k):
        return jnp.sum(conv2d_3x3_same(x, k) ** 2)

    def f_ref(x, k):
        return jnp.sum(ref.conv2d_3x3_same_ref(x, k) ** 2)

    gx, gk = jax.grad(f_pallas, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(k))
    gx_r, gk_r = jax.grad(f_ref, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(k))
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gk_r), rtol=1e-4, atol=1e-4)
