"""L1 correctness: Pallas tiled matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes (divisible and non-divisible by the block sizes)
and dtypes; every case asserts allclose against ``ref.matmul_ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_raw

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


dims = st.integers(min_value=1, max_value=70)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    x, w = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = matmul_raw(jnp.asarray(x), jnp.asarray(w), bm=32, bn=32, bk=32)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),  # exactly one default block
        (256, 384, 128),  # multi-block in every dim
        (1, 1, 1),  # degenerate
        (130, 127, 129),  # off-by-a-little from the block size
        (32, 2048, 128),  # fc1 shape at batch 32
    ],
)
def test_matmul_block_boundaries(m, k, n):
    x, w = _rand((m, k), 7), _rand((k, n), 8)
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (64, 16, 32)])
def test_matmul_block_shape_invariance(bm, bn, bk):
    """The result must not depend on the tiling."""
    x, w = _rand((40, 24), 3), _rand((24, 56), 4)
    got = matmul_raw(jnp.asarray(x), jnp.asarray(w), bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-4
    )


def test_matmul_dtype_promotion_bf16():
    """bf16 inputs accumulate in f32 (preferred_element_type)."""
    x = _rand((33, 17), 0).astype(jnp.bfloat16)
    w = _rand((17, 9), 1).astype(jnp.bfloat16)
    got = matmul_raw(jnp.asarray(x), jnp.asarray(w), bm=16, bn=16, bk=16)
    want = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32)
    )
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_matmul_custom_vjp_matches_autodiff():
    """The hand-written VJP must equal autodiff of the reference."""
    x, w = _rand((12, 20), 5), _rand((20, 8), 6)

    def f_pallas(x, w):
        return jnp.sum(matmul(x, w) ** 2)

    def f_ref(x, w):
        return jnp.sum(ref.matmul_ref(x, w) ** 2)

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    gx_ref, gw_ref = jax.grad(f_ref, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref), rtol=1e-4, atol=1e-4)


def test_matmul_jittable():
    x, w = _rand((48, 48), 9), _rand((48, 48), 10)
    got = jax.jit(matmul)(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-4
    )
