"""L2 correctness: layer-wise EdgeCNN vs the monolithic pure-jnp model.

The Rust worker composes per-layer fwd/bwd artifacts; these tests pin down
that (a) each layer's Pallas path equals its jnp oracle path, (b) the
layer-wise backward chain reproduces autodiff of the whole model, and (c)
the loss head's hand-computed gradient equals autodiff.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

BATCH = 2


def _data(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((BATCH, 32, 32, 3)).astype(np.float32)
    labels = rng.integers(0, 10, BATCH)
    onehot = np.eye(10, dtype=np.float32)[labels]
    return jnp.asarray(x), jnp.asarray(onehot)


def test_layer_defs_chain():
    """out_shape of layer l must feed in_shape of layer l+1 (modulo flatten)."""
    layers = M.edgecnn_layers()
    for prev, nxt in zip(layers, layers[1:]):
        a = int(np.prod(prev.out_shape))
        b = int(np.prod(nxt.in_shape))
        assert a == b, (prev.name, nxt.name)


@pytest.mark.parametrize("idx", range(6))
def test_layer_fwd_pallas_vs_ref(idx):
    layer = M.edgecnn_layers()[idx]
    params = M.init_params(0)
    w, b = params[idx]
    rng = np.random.default_rng(idx)
    x = jnp.asarray(
        rng.standard_normal((BATCH, *layer.in_shape)).astype(np.float32)
    )
    got = M.make_layer_fwd(layer)(w, b, x)
    want = M.make_layer_fwd(layer, use_ref=True)(w, b, x)
    assert got.shape == (BATCH, *layer.out_shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("idx", range(6))
def test_layer_bwd_pallas_vs_ref(idx):
    layer = M.edgecnn_layers()[idx]
    params = M.init_params(0)
    w, b = params[idx]
    rng = np.random.default_rng(100 + idx)
    x = jnp.asarray(
        rng.standard_normal((BATCH, *layer.in_shape)).astype(np.float32)
    )
    gy = jnp.asarray(
        rng.standard_normal((BATCH, *layer.out_shape)).astype(np.float32)
    )
    got = M.make_layer_bwd(layer)(w, b, x, gy)
    want = M.make_layer_bwd(layer, use_ref=True)(w, b, x, gy)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-4, atol=1e-4)


def test_full_fwd_composition_matches_ref():
    params = M.init_params(0)
    x, _ = _data()
    got = M.full_fwd(params, x)
    want = M.full_fwd(params, x, use_ref=True)
    assert got.shape == (BATCH, 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_loss_glogits_matches_autodiff():
    x, onehot = _data(1)
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((BATCH, 10)).astype(np.float32))
    loss, glogits = M.loss_fwd(logits, onehot)
    loss_ad, glogits_ad = jax.value_and_grad(
        lambda lg: M.loss_fwd(lg, onehot)[0]
    )(logits)
    np.testing.assert_allclose(float(loss), float(loss_ad), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(glogits), np.asarray(glogits_ad), rtol=1e-4, atol=1e-5
    )


def test_layerwise_backward_chain_matches_whole_model_autodiff():
    """Drive the exact sequence the Rust worker executes: forward through
    every layer saving inputs, loss head, then backward layer-by-layer —
    and compare every parameter gradient against jax.grad of the full model.
    """
    layers = M.edgecnn_layers()
    params = M.init_params(0)
    x, onehot = _data(3)

    # Rust-style layer-wise execution (using ref ops for speed).
    acts = [x]
    for layer, (w, b) in zip(layers, params):
        acts.append(M.make_layer_fwd(layer, use_ref=True)(w, b, acts[-1]))
    _, g = M.loss_fwd(acts[-1], onehot)
    grads = [None] * len(layers)
    for idx in range(len(layers) - 1, -1, -1):
        w, b = params[idx]
        gw, gb, gx = M.make_layer_bwd(layers[idx], use_ref=True)(
            w, b, acts[idx], g
        )
        grads[idx] = (gw, gb)
        g = gx.reshape(acts[idx].shape)

    # Ground truth: autodiff of the monolithic loss.
    ad = jax.grad(lambda p: M.full_loss(p, x, onehot, use_ref=True))(params)
    for (gw, gb), (gw_ad, gb_ad), layer in zip(grads, ad, layers):
        np.testing.assert_allclose(
            np.asarray(gw), np.asarray(gw_ad), rtol=1e-3, atol=1e-5,
            err_msg=layer.name,
        )
        np.testing.assert_allclose(
            np.asarray(gb), np.asarray(gb_ad), rtol=1e-3, atol=1e-5,
            err_msg=layer.name,
        )


def test_init_params_deterministic():
    a, b = M.init_params(7), M.init_params(7)
    for (wa, ba), (wb, bb) in zip(a, b):
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
        np.testing.assert_array_equal(np.asarray(ba), np.asarray(bb))
    c = M.init_params(8)
    assert not np.array_equal(np.asarray(a[0][0]), np.asarray(c[0][0]))
