import os
import sys

# Make `compile` (the build-time package) importable when pytest runs from
# the `python/` directory or from the repo root.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
