//! Offline, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment vendors its two external dependencies (this crate
//! and `xla`); this module reimplements the subset of `anyhow`'s API the
//! repository uses, with the same names and semantics:
//!
//! * [`Error`] — an opaque error value carrying a chain of context
//!   messages (outermost first). `{e}` prints the outermost message,
//!   `{e:#}` the colon-joined chain, `{e:?}` the message plus a
//!   `Caused by:` list.
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a defaulted
//!   error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the usual macros.
//!
//! Any `E: std::error::Error + Send + Sync + 'static` converts into
//! [`Error`] via `?`, preserving its source chain as messages.

use std::fmt;

/// An opaque error: a chain of display messages, outermost context first.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(src) = cur.source.as_deref() {
            cur = src;
        }
        cur
    }
}

/// Iterator over an [`Error`]'s context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a str;

    fn next(&mut self) -> Option<&'a str> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(&cur.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the std source chain into our message chain.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("chain has at least one message")
    }
}

/// Attach context to the error variant of a `Result`, or turn an `Option`'s
/// `None` into an error.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        assert_eq!(e.root_cause().to_string(), "inner");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
        assert_eq!(Some(5).context("absent").unwrap(), 5);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e: Error = anyhow!("plain {}", "fmt");
        assert_eq!(format!("{e}"), "plain fmt");
    }

    #[test]
    fn bare_ensure_reports_condition() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("1 + 1 == 3"));
    }
}
