//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The coordinator only needs two things from the XLA crate:
//!
//! 1. [`Literal`] — a host-side f32 tensor value used to marshal inputs
//!    and outputs. This is implemented for real (vec1 / reshape / to_vec /
//!    tuples), so everything that moves data around works offline.
//! 2. The PJRT compile/execute surface ([`PjRtClient`],
//!    [`HloModuleProto`], [`XlaComputation`], [`PjRtLoadedExecutable`],
//!    [`PjRtBuffer`]) — stubbed to return a descriptive [`Error`]. Callers
//!    already gate the real-runtime paths on `artifacts_available()`, so
//!    tests and figures degrade gracefully until a real `xla_extension`
//!    build is wired back in.

use std::fmt;

/// Error type mirroring the binding crate's error surface.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable(what: &str) -> Error {
        Error::new(format!(
            "{what}: the XLA/PJRT runtime is not available in this offline \
             build (vendor a real xla_extension to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Sized {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl NativeType for f64 {
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
}

/// A host-side tensor value: dense f32 data plus dimensions, or a tuple of
/// literals (XLA computations with `return_tuple=True` produce tuples).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// A rank-1 literal over the given values.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64], tuple: None }
    }

    /// A tuple literal.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { data: Vec::new(), dims: Vec::new(), tuple: Some(elements) }
    }

    /// Reinterpret the data with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), tuple: None })
    }

    /// Read the data back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::new("to_vec on a tuple literal"));
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Unpack a tuple literal; a non-tuple unpacks to a 1-tuple of itself.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(elements) => Ok(elements),
            None => Ok(vec![self]),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }
}

/// PJRT client handle (stub: construction fails offline).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub: parsing fails offline).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled executable (stub: execution fails offline).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[3.5]).reshape(&[]).unwrap();
        assert_eq!(l.dims(), &[] as &[i64]);
        assert_eq!(l.element_count(), 1);
    }

    #[test]
    fn tuples_unpack() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0]), Literal::vec1(&[2.0])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        // Non-tuples unpack to themselves (return_tuple=False artifacts).
        let single = Literal::vec1(&[9.0]);
        assert_eq!(single.clone().to_tuple().unwrap(), vec![single]);
    }

    #[test]
    fn runtime_entry_points_error_offline() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
