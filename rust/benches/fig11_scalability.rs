//! Fig. 11 — speedup versus the number of workers (ResNet-152), all
//! strategies, with server-side bandwidth contention.

mod common;

use dynacomm::figures;

fn main() {
    let rows = common::timed("fig11 worker sweep", figures::fig11_worker_sweep);
    println!(
        "{}",
        figures::render_sweep(
            &rows,
            "workers",
            "Fig. 11: speedup vs number of workers (ResNet-152)"
        )
    );
    figures::write_result("fig11_scalability", figures::sweep_to_json(&rows)).unwrap();
}
