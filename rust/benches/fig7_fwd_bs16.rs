//! Fig. 7 — normalized execution time of the forward propagation, batch
//! size 16 (halved compute: more exposed communication).

mod common;

use dynacomm::figures::{self, Pass};

fn main() {
    let cells = common::timed("fig7 grid", || {
        figures::normalized_pass_times(16, Pass::Forward)
    });
    println!(
        "{}",
        figures::render_normalized(
            &cells,
            "Fig. 7: normalized forward execution time (batch=16)"
        )
    );
    figures::write_result("fig7_fwd_bs16", figures::normalized_to_json(&cells))
        .expect("writing results");
}
