//! Fig. 9 — sensitivity of the iteration-time-reduced ratio to the
//! computation/communication balance, ResNet-152:
//! (a) batch-size sweep at 10 Gbps, (b) bandwidth sweep at batch 32.

mod common;

use dynacomm::figures;

fn main() {
    let batch = common::timed("fig9a batch sweep", figures::fig9_batch_sweep);
    println!(
        "{}",
        figures::render_sweep(
            &batch,
            "batch",
            "Fig. 9a: iteration time reduced ratio vs batch size (ResNet-152, 10 Gbps)"
        )
    );
    figures::write_result("fig9a_batch", figures::sweep_to_json(&batch)).unwrap();

    let bw = common::timed("fig9b bandwidth sweep", figures::fig9_bandwidth_sweep);
    println!(
        "{}",
        figures::render_sweep(
            &bw,
            "gbps",
            "Fig. 9b: iteration time reduced ratio vs bandwidth (ResNet-152, batch=32)"
        )
    );
    figures::write_result("fig9b_bandwidth", figures::sweep_to_json(&bw)).unwrap();
}
