//! PS wire-path throughput bench — the perf trajectory's first entry
//! (`results/BENCH_wire.json`, uploaded by CI on every PR).
//!
//! Drives N workers × L layers of full-range pulls through a real loopback
//! shard and measures aggregate server-side egress two ways:
//!
//! * **current path** — shared pull-reply broadcast (assembled once per
//!   `(iter, segment)`, served to every worker as an `Arc` clone), pooled
//!   slabs, vectored `[header][slab]` send;
//! * **legacy path** — the pre-change serve loop, reconstructed verbatim
//!   in this bench: per-worker slab assembly into a fresh buffer, then a
//!   full memcpy of the slab into the frame scratch (`encode_into`), then
//!   `write_all`.
//!
//! Alongside bytes/sec it reports the reply-cache hit rate and the pool's
//! steady-state allocation count (which must be zero after warm-up).
//! Target: ≥ 2× server-side throughput at 8 workers.
//!
//! A **codec matrix** (fp32/fp16/int8 at 8 workers × 2 MiB) drives the
//! same pull storm through negotiated quantized sessions and reports
//! per-codec bytes-on-wire (fp16 target: ≥ 45% saved), effective raw
//! throughput and speedup vs fp32, reply-cache hit rate (must be
//! unchanged), steady-state allocations (must stay 0), and the server's
//! measured max quantization error — all recorded as `codec_matrix` rows
//! in `results/BENCH_wire.json`.
//!
//! A **tier matrix** (`ps/agg`, docs/TOPOLOGY.md) runs the same 8-worker
//! fleet twice against two cloud shards — flat (every worker pushes
//! straight to the owning shard) and regional (2 groups of 4 behind
//! regional aggregators that forward one combined push per group) — and
//! reports the bytes actually crossing the cloud boundary (the shards'
//! ingress counters), fleet iteration throughput, and the ingress-saved
//! ratio (target ≥ 3× at group size 4), recorded as `tier_matrix` rows.
//!
//! An **obs overhead** leg re-runs the BSP lockstep mix with the
//! observability plane fully armed — span tracing recording every
//! server-side segment plus a live scraper polling the Prometheus
//! endpoint — and asserts the best-of-3 regression vs the disarmed run
//! stays ≤ 5% (`obs_overhead_pct` in `results/BENCH_wire.json`,
//! docs/OBSERVABILITY.md).

mod common;

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use dynacomm::figures;
use dynacomm::net::codec::CodecId;
use dynacomm::net::{slab, Connection, Message, PROTOCOL_VERSION};
use dynacomm::ps::sync::{SyncConfig, SyncMode};
use dynacomm::ps::{
    AggConfig, Checkpoint, ParamServer, RegionalAggregator, ServerConfig, ServerOptions,
};
use dynacomm::util::json::Json;

const LAYERS: usize = 8;
/// 256 KiB per layer → 2 MiB per full-range reply.
const LAYER_F32S: usize = 64 << 10;
const WORKERS: usize = 8;

fn reply_bytes() -> usize {
    4 * LAYER_F32S * LAYERS
}

fn layer_init() -> HashMap<usize, Vec<f32>> {
    (0..LAYERS).map(|l| (l, vec![l as f32 + 0.5; LAYER_F32S])).collect()
}

/// `workers` concurrent clients × `reps` full-range pulls of iteration 0
/// against `addr`, each session negotiated to `codec`; returns the
/// wall-clock seconds of the pull phase.
fn drive_pulls_codec(
    addr: std::net::SocketAddr,
    codec: CodecId,
    workers: usize,
    reps: usize,
) -> f64 {
    // Per-layer encodings concatenated: the full-range reply size.
    let expect: usize = (0..LAYERS).map(|_| codec.wire_len(4 * LAYER_F32S)).sum();
    let barrier = Arc::new(Barrier::new(workers + 1));
    let mut threads = Vec::new();
    for _ in 0..workers {
        let barrier = barrier.clone();
        threads.push(std::thread::spawn(move || {
            let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
            if codec != CodecId::Fp32 {
                conn.send(&Message::CodecPropose { pref: codec }).unwrap();
                match conn.recv().unwrap() {
                    Message::CodecAgree { codec: agreed } => assert_eq!(agreed, codec),
                    m => panic!("{m:?}"),
                }
            }
            barrier.wait();
            for _ in 0..reps {
                conn.send(&Message::Pull { iter: 0, lo: 0, hi: LAYERS as u32 - 1 })
                    .unwrap();
                match conn.recv().unwrap() {
                    Message::PullReply { codec: got, data, .. } => {
                        assert_eq!(got, codec);
                        assert_eq!(data.len(), expect)
                    }
                    m => panic!("{m:?}"),
                }
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for t in threads {
        t.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn drive_pulls(addr: std::net::SocketAddr, workers: usize, reps: usize) -> f64 {
    drive_pulls_codec(addr, CodecId::Fp32, workers, reps)
}

/// `workers` clients in BSP lockstep over iterations `start..end`: each
/// pulls the full range at its iteration, then pushes a zero gradient for
/// it — so the server assembles one fresh reply per iteration (plus
/// eviction, push accumulation, and version waits), the realistic
/// steady-state mix rather than the cache-hot broadcast case. Returns
/// wall-clock seconds.
fn drive_bsp(addr: std::net::SocketAddr, workers: usize, start: u64, end: u64) -> f64 {
    let grad = vec![0.0f32; LAYER_F32S * LAYERS];
    let barrier = Arc::new(Barrier::new(workers + 1));
    let mut threads = Vec::new();
    for _ in 0..workers {
        let barrier = barrier.clone();
        let grad = grad.clone();
        threads.push(std::thread::spawn(move || {
            let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
            barrier.wait();
            for iter in start..end {
                conn.send(&Message::Pull { iter, lo: 0, hi: LAYERS as u32 - 1 })
                    .unwrap();
                match conn.recv().unwrap() {
                    Message::PullReply { data, .. } => {
                        assert_eq!(data.len(), reply_bytes())
                    }
                    m => panic!("{m:?}"),
                }
                conn.send(&Message::Push {
                    iter,
                    lo: 0,
                    hi: LAYERS as u32 - 1,
                    codec: CodecId::Fp32,
                    data: slab::from_f32s(&grad),
                })
                .unwrap();
                match conn.recv().unwrap() {
                    Message::PushAck { .. } => {}
                    m => panic!("{m:?}"),
                }
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for t in threads {
        t.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

/// One straggler-matrix worker: registered (`Hello` + `SyncPropose`), a
/// per-iteration compute sleep, full-range pull + zero-gradient push per
/// iteration. Returns the max staleness observed (`iter − applied`).
fn straggler_worker(
    addr: std::net::SocketAddr,
    worker: u32,
    mode: SyncMode,
    bound: u32,
    iters: u64,
    compute_ms: u64,
) -> u64 {
    let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::HelloAck { .. }));
    conn.send(&Message::SyncPropose { mode, bound }).unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::SyncAgree { .. }));
    let grad = vec![0.0f32; LAYER_F32S * LAYERS];
    let mut max_stale = 0u64;
    for iter in 0..iters {
        conn.send(&Message::Pull { iter, lo: 0, hi: LAYERS as u32 - 1 }).unwrap();
        match conn.recv().unwrap() {
            Message::PullReply { applied, .. } => {
                max_stale = max_stale.max(iter.saturating_sub(applied));
            }
            m => panic!("{m:?}"),
        }
        std::thread::sleep(std::time::Duration::from_millis(compute_ms));
        conn.send(&Message::Push {
            iter,
            lo: 0,
            hi: LAYERS as u32 - 1,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&grad),
        })
        .unwrap();
        assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
    }
    max_stale
}

/// One straggler-matrix cell: `WORKERS` workers with one 4×-slowed
/// straggler under `mode`. The straggler runs `k_slow` iterations; under
/// the relaxed modes the fast workers run as far as the mode allows
/// (`k_slow − 1 + bound` under SSP — the gate's admission horizon once
/// the straggler's clock stops — and the full 4× multiple under ASP), so
/// the cell measures exactly what the consistency model recovers.
/// Returns (aggregate iters/sec, max staleness observed).
fn drive_straggler(mode: SyncMode, bound: u32, k_slow: u64, fast_ms: u64) -> (f64, u64) {
    const SLOWDOWN: u64 = 4;
    let srv = ParamServer::start_with(
        ServerConfig { workers: WORKERS, lr: 0.1 },
        layer_init(),
        None,
        ServerOptions {
            sync: SyncConfig::new(mode, bound).unwrap(),
            ..ServerOptions::default()
        },
    )
    .unwrap();
    let addr = srv.handle().addr;
    let fast_iters = match mode {
        SyncMode::Bsp => k_slow,
        SyncMode::Ssp => k_slow - 1 + bound as u64,
        SyncMode::Asp => k_slow * SLOWDOWN,
    };
    let barrier = Arc::new(Barrier::new(WORKERS + 1));
    let mut threads = Vec::new();
    for w in 0..WORKERS as u32 {
        let barrier = barrier.clone();
        threads.push(std::thread::spawn(move || {
            let (iters, compute) = if w == 0 {
                (k_slow, fast_ms * SLOWDOWN)
            } else {
                (fast_iters, fast_ms)
            };
            barrier.wait();
            (iters, straggler_worker(addr, w, mode, bound, iters, compute))
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    let mut total_iters = 0u64;
    let mut max_stale = 0u64;
    for t in threads {
        let (iters, stale) = t.join().unwrap();
        total_iters += iters;
        max_stale = max_stale.max(stale);
    }
    let secs = t0.elapsed().as_secs_f64();
    drop(srv);
    (total_iters as f64 / secs, max_stale)
}

/// Tier-matrix scale: two cloud shards, each owning one 64 KiB layer
/// (layer `s` → shard `s`), an 8-worker fleet split into 2 groups of 4.
const TIER_LAYER_F32S: usize = 16 << 10;
const TIER_SHARDS: usize = 2;
const TIER_GROUPS: usize = 2;
const TIER_GROUP_SIZE: usize = 4;

fn tier_shards() -> Vec<ParamServer> {
    (0..TIER_SHARDS)
        .map(|s| {
            let mut layers = HashMap::new();
            layers.insert(s, vec![0.5f32; TIER_LAYER_F32S]);
            ParamServer::start(ServerConfig { workers: WORKERS, lr: 0.1 }, layers, None)
                .unwrap()
        })
        .collect()
}

/// Flat leg: every worker holds a connection to each shard and, per
/// iteration, pulls + pushes its owned layer directly — `WORKERS` pushes
/// per layer per iteration cross the cloud boundary. Returns wall-clock
/// seconds of the whole fleet run.
fn drive_tier_flat(addrs: &[std::net::SocketAddr], iters: u64) -> f64 {
    let grad = slab::from_f32s(&vec![0.0f32; TIER_LAYER_F32S]);
    let barrier = Arc::new(Barrier::new(WORKERS + 1));
    let mut threads = Vec::new();
    for _ in 0..WORKERS {
        let barrier = barrier.clone();
        let addrs = addrs.to_vec();
        let grad = grad.clone();
        threads.push(std::thread::spawn(move || {
            let mut conns: Vec<Connection> = addrs
                .iter()
                .map(|a| Connection::new(TcpStream::connect(a).unwrap(), None))
                .collect();
            barrier.wait();
            for iter in 0..iters {
                for (s, conn) in conns.iter_mut().enumerate() {
                    conn.send(&Message::Pull { iter, lo: s as u32, hi: s as u32 })
                        .unwrap();
                    assert!(matches!(conn.recv().unwrap(), Message::PullReply { .. }));
                    conn.send(&Message::Push {
                        iter,
                        lo: s as u32,
                        hi: s as u32,
                        codec: CodecId::Fp32,
                        data: grad.clone(),
                    })
                    .unwrap();
                    assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
                }
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for t in threads {
        t.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

/// Regional leg: the same fleet behind `TIER_GROUPS` aggregators — each
/// worker speaks only to its group's aggregator (full range, one session)
/// and the cloud sees one combined push per group per layer per
/// iteration. Returns wall-clock seconds of the whole fleet run.
fn drive_tier_regional(aggs: &[RegionalAggregator], iters: u64) -> f64 {
    // Both layers are the same size, so the full-range fp32 push payload
    // is just the two per-layer slabs concatenated.
    let grad = slab::from_f32s(&vec![0.0f32; TIER_SHARDS * TIER_LAYER_F32S]);
    let barrier = Arc::new(Barrier::new(WORKERS + 1));
    let mut threads = Vec::new();
    for w in 0..WORKERS {
        let barrier = barrier.clone();
        let addr = aggs[w / TIER_GROUP_SIZE].addr();
        let grad = grad.clone();
        threads.push(std::thread::spawn(move || {
            let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
            barrier.wait();
            for iter in 0..iters {
                conn.send(&Message::Pull { iter, lo: 0, hi: TIER_SHARDS as u32 - 1 })
                    .unwrap();
                assert!(matches!(conn.recv().unwrap(), Message::PullReply { .. }));
                conn.send(&Message::Push {
                    iter,
                    lo: 0,
                    hi: TIER_SHARDS as u32 - 1,
                    codec: CodecId::Fp32,
                    data: grad.clone(),
                })
                .unwrap();
                assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for t in threads {
        t.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

/// One legacy handler: framed recv, per-pull assembly into a **fresh**
/// buffer, full-copy `encode_into`, `write_all` — the pre-change server's
/// exact per-byte work.
fn legacy_conn(mut stream: TcpStream, params: &HashMap<usize, Vec<u8>>) {
    stream.set_nodelay(true).ok();
    let mut scratch = Vec::new();
    let mut recv_buf = Vec::new();
    loop {
        let mut len = [0u8; 4];
        if stream.read_exact(&mut len).is_err() {
            return;
        }
        let len = u32::from_le_bytes(len) as usize;
        recv_buf.resize(len, 0);
        if stream.read_exact(&mut recv_buf).is_err() {
            return;
        }
        let Ok(Message::Pull { iter, lo, hi }) = Message::decode(&recv_buf) else {
            return;
        };
        let cap: usize = (lo as usize..=hi as usize)
            .filter_map(|l| params.get(&l).map(Vec::len))
            .sum();
        let mut data = Vec::with_capacity(cap);
        for l in lo as usize..=hi as usize {
            if let Some(p) = params.get(&l) {
                data.extend_from_slice(p);
            }
        }
        Message::PullReply { iter, lo, hi, applied: iter, codec: CodecId::Fp32, data }
            .encode_into(&mut scratch);
        if stream.write_all(&scratch).is_err() {
            return;
        }
    }
}

/// The pre-change serve loop as a standalone loopback server.
fn legacy_server(
    layers: HashMap<usize, Vec<f32>>,
) -> (std::net::SocketAddr, Arc<AtomicBool>) {
    let params: Arc<HashMap<usize, Vec<u8>>> = Arc::new(
        layers.into_iter().map(|(l, p)| (l, slab::from_f32s(&p))).collect(),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    std::thread::spawn(move || loop {
        let Ok((stream, _)) = listener.accept() else { break };
        if stop2.load(Ordering::SeqCst) {
            break;
        }
        let params = params.clone();
        std::thread::spawn(move || legacy_conn(stream, &params));
    });
    (addr, stop)
}

fn main() {
    let reps = if common::fast_mode() { 40 } else { 300 };
    let layers = layer_init();
    let total_pulls = (WORKERS * reps) as u64;
    let mb = |secs: f64| {
        total_pulls as f64 * reply_bytes() as f64 / (1 << 20) as f64 / secs
    };

    // --- Current path: broadcast cache + pool + vectored send. ---
    let srv = ParamServer::start(
        ServerConfig { workers: WORKERS, lr: 0.1 },
        layers.clone(),
        None,
    )
    .unwrap();
    let addr = srv.handle().addr;
    drive_pulls(addr, 1, 2); // warm the cache, pool, and page tables
    let s0 = srv.wire_stats();
    let secs_new = drive_pulls(addr, WORKERS, reps);
    let s1 = srv.wire_stats();
    let hits = s1.reply_cache_hits - s0.reply_cache_hits;
    let builds = s1.reply_cache_builds - s0.reply_cache_builds;
    let hit_rate = hits as f64 / total_pulls as f64;
    let steady_allocs = s1.pool.allocations - s0.pool.allocations;
    drop(srv);

    // --- BSP lockstep scenario: one assembly per iteration (plus pushes,
    // eviction, version waits) — the realistic steady-state mix, measured
    // on the real server so assembly-path regressions are visible.
    let bsp_iters = (reps / 4).max(4) as u64;
    let srv = ParamServer::start(
        ServerConfig { workers: WORKERS, lr: 0.1 },
        layers.clone(),
        None,
    )
    .unwrap();
    let baddr = srv.handle().addr;
    // Three warm-up iterations: the reply-slab rotation (two cached
    // entries + one in flight) is fully allocated only after the first
    // eviction, so measuring earlier would count one warm-up allocation.
    let warmup_iters = 3u64;
    drive_bsp(baddr, WORKERS, 0, warmup_iters);
    let b0 = srv.wire_stats();
    // Continue from where the warm-up's BSP clock stopped.
    let secs_bsp = drive_bsp(baddr, WORKERS, warmup_iters, warmup_iters + bsp_iters);
    let b1 = srv.wire_stats();
    let bsp_pulls = WORKERS as u64 * bsp_iters;
    let bsp_builds = b1.reply_cache_builds - b0.reply_cache_builds;
    let bsp_hits = b1.reply_cache_hits - b0.reply_cache_hits;
    let bsp_allocs = b1.pool.allocations - b0.pool.allocations;
    let bsp_pull_mb_s = bsp_pulls as f64 * reply_bytes() as f64
        / (1 << 20) as f64
        / secs_bsp;
    drop(srv);

    // --- Codec matrix: fp32/fp16/int8 at 8 workers × 2 MiB replies. ---
    // Each codec gets a fresh shard and the same pull storm; rows report
    // bytes-on-wire, effective (raw-parameter) throughput, speedup vs the
    // fp32 broadcast path, reply-cache behavior, steady-state allocations,
    // and the server's measured max quantization error.
    struct CodecRow {
        codec: CodecId,
        wire_reply_bytes: usize,
        saved_pct: f64,
        raw_mb_per_s: f64,
        wire_mb_per_s: f64,
        hit_rate: f64,
        steady_allocs: u64,
        max_quant_error: f64,
    }
    let mut codec_rows: Vec<CodecRow> = Vec::new();
    for codec in CodecId::ALL {
        let srv = ParamServer::start(
            ServerConfig { workers: WORKERS, lr: 0.1 },
            layers.clone(),
            None,
        )
        .unwrap();
        let caddr = srv.handle().addr;
        drive_pulls_codec(caddr, codec, 1, 2); // warm cache + pool
        let c0 = srv.wire_stats();
        let secs = drive_pulls_codec(caddr, codec, WORKERS, reps);
        let c1 = srv.wire_stats();
        let wire_reply_bytes: usize =
            (0..LAYERS).map(|_| codec.wire_len(4 * LAYER_F32S)).sum();
        let hits = c1.reply_cache_hits - c0.reply_cache_hits;
        codec_rows.push(CodecRow {
            codec,
            wire_reply_bytes,
            saved_pct: 100.0 * (1.0 - wire_reply_bytes as f64 / reply_bytes() as f64),
            raw_mb_per_s: mb(secs),
            wire_mb_per_s: total_pulls as f64 * wire_reply_bytes as f64
                / (1 << 20) as f64
                / secs,
            hit_rate: hits as f64 / total_pulls as f64,
            steady_allocs: c1.pool.allocations - c0.pool.allocations,
            max_quant_error: c1.codec(codec).max_quant_error as f64,
        });
        drop(srv);
    }

    // --- Straggler sync matrix: one 4×-slowed worker × {bsp,ssp,asp}. ---
    // The acceptance row: with one straggler, SSP iteration throughput
    // must recover ≥ 1.5× BSP while every reply stays within the
    // staleness bound (checked worker-side off the v4 `applied` field).
    struct SyncRow {
        mode: SyncMode,
        iters_per_sec: f64,
        speedup_vs_bsp: f64,
        max_staleness: u64,
        bound: u32,
    }
    let (k_slow, fast_ms) = if common::fast_mode() { (4u64, 8u64) } else { (4, 15) };
    let ssp_bound = 8u32;
    let mut sync_rows: Vec<SyncRow> = Vec::new();
    for mode in SyncMode::ALL {
        let bound = if mode == SyncMode::Ssp { ssp_bound } else { 0 };
        let (ips, stale) = drive_straggler(mode, bound, k_slow, fast_ms);
        let bsp_ips = sync_rows.first().map(|r| r.iters_per_sec).unwrap_or(ips);
        sync_rows.push(SyncRow {
            mode,
            iters_per_sec: ips,
            speedup_vs_bsp: ips / bsp_ips,
            max_staleness: stale,
            bound,
        });
    }
    assert!(
        sync_rows[1].speedup_vs_bsp >= 1.5,
        "ssp recovered only {:.2}x over bsp with a 4x straggler",
        sync_rows[1].speedup_vs_bsp
    );
    assert!(
        sync_rows[1].max_staleness <= ssp_bound as u64,
        "ssp staleness {} broke the bound {ssp_bound}",
        sync_rows[1].max_staleness
    );

    // --- Tier matrix: flat 8-direct vs 2 groups x 4 behind regional
    // aggregators (ps/agg, docs/TOPOLOGY.md), same fleet and layers. The
    // cloud-boundary metric is the shards' ingress counters: the tiered
    // run admits one combined push per group instead of one per worker,
    // so the bytes crossing into the cloud must shrink by ~group size.
    let tier_iters = if common::fast_mode() { 8u64 } else { 40 };
    let shards = tier_shards();
    let taddrs: Vec<_> = shards.iter().map(|s| s.handle().addr).collect();
    let secs_flat = drive_tier_flat(&taddrs, tier_iters);
    let flat_ingress: u64 = shards.iter().map(|s| s.wire_stats().ingress_bytes).sum();
    drop(shards);

    let shards = tier_shards();
    let taddrs: Vec<_> = shards.iter().map(|s| s.handle().addr).collect();
    let aggs: Vec<RegionalAggregator> = (0..TIER_GROUPS)
        .map(|g| {
            RegionalAggregator::start(AggConfig {
                group: 100 + g as u32,
                workers: TIER_GROUP_SIZE as u32,
                upstream_addrs: taddrs.clone(),
                layer_elems: vec![TIER_LAYER_F32S; TIER_SHARDS],
                downstream_sync: SyncConfig::default(),
                upstream_sync: SyncConfig::default(),
                upstream_codec: CodecId::Fp32,
                handler_threads: TIER_GROUP_SIZE + 2,
                io_timeout_ms: 0,
            })
            .unwrap()
        })
        .collect();
    let secs_tiered = drive_tier_regional(&aggs, tier_iters);
    let tiered_ingress: u64 = shards.iter().map(|s| s.wire_stats().ingress_bytes).sum();
    drop(aggs);
    drop(shards);

    let tier_ratio = flat_ingress as f64 / tiered_ingress as f64;
    assert!(
        tier_ratio >= 3.0,
        "tiered cloud ingress shrank only {tier_ratio:.2}x at group size \
         {TIER_GROUP_SIZE} (target >= 3x)"
    );
    let fleet_ips = |secs: f64| WORKERS as f64 * tier_iters as f64 / secs;

    // --- Legacy path: per-worker assembly + full-copy encode. ---
    let (laddr, stop) = legacy_server(layers);
    drive_pulls(laddr, 1, 2);
    let secs_legacy = drive_pulls(laddr, WORKERS, reps);
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(laddr); // release the accept loop

    let (thr_new, thr_legacy) = (mb(secs_new), mb(secs_legacy));
    let speedup = thr_new / thr_legacy;

    println!(
        "[bench] ps_throughput: {WORKERS} workers x {reps} pulls x {:.1} MiB reply",
        reply_bytes() as f64 / (1 << 20) as f64
    );
    println!("  legacy (per-worker assembly + copy): {thr_legacy:>8.0} MB/s");
    println!("  shared broadcast + vectored send:    {thr_new:>8.0} MB/s");
    println!("  server-side speedup: {speedup:.2}x (target >= 2x)");
    println!(
        "  reply cache: {hits} hits / {builds} builds (hit rate {:.3})",
        hit_rate
    );
    println!(
        "  pool: {} steady-state allocations over {total_pulls} pulls \
         (target 0), {:?}",
        steady_allocs, s1.pool
    );
    println!(
        "  BSP lockstep ({bsp_iters} iters): {bsp_pull_mb_s:.0} MB/s pull \
         egress, {bsp_builds} builds / {bsp_hits} hits over {bsp_pulls} \
         pulls, {bsp_allocs} steady-state allocations"
    );
    let fp32_raw = codec_rows[0].raw_mb_per_s;
    println!(
        "  codec matrix ({WORKERS} workers x {:.1} MiB raw replies):",
        reply_bytes() as f64 / (1 << 20) as f64
    );
    for row in &codec_rows {
        println!(
            "    {:<5} wire {:>9} B/reply ({:>5.1}% saved)  raw {:>7.0} MB/s \
             ({:.2}x vs fp32)  hit-rate {:.3}  allocs {}  max-qerr {:.3e}",
            row.codec.name(),
            row.wire_reply_bytes,
            row.saved_pct,
            row.raw_mb_per_s,
            row.raw_mb_per_s / fp32_raw,
            row.hit_rate,
            row.steady_allocs,
            row.max_quant_error,
        );
    }
    println!(
        "  straggler matrix ({WORKERS} workers, 1 at 4x, {k_slow} straggler \
         iters, ssp bound {ssp_bound}):"
    );
    for row in &sync_rows {
        println!(
            "    {:<4} {:>8.1} iters/s  ({:.2}x vs bsp, target ssp >= 1.5x)  \
             max-staleness {} (bound {})",
            row.mode.name(),
            row.iters_per_sec,
            row.speedup_vs_bsp,
            row.max_staleness,
            row.bound,
        );
    }
    println!(
        "  tier matrix ({WORKERS} workers, {TIER_SHARDS} shards, group size \
         {TIER_GROUP_SIZE}, {tier_iters} iters):"
    );
    println!(
        "    flat     cloud ingress {flat_ingress:>10} B  {:>7.1} fleet iters/s",
        fleet_ips(secs_flat)
    );
    println!(
        "    regional cloud ingress {tiered_ingress:>10} B  {:>7.1} fleet \
         iters/s  ({tier_ratio:.2}x less ingress, target >= 3x)",
        fleet_ips(secs_tiered)
    );

    // --- Checkpoint matrix: shard checkpoint write / parse / restore-boot
    // wall-clock (`ps::checkpoint`, docs/FAULTS.md) on the reply-bench
    // shard shape (LAYERS x LAYER_F32S = 2 MiB of parameters). The write
    // number includes durability (tmp + fsync + rename); the roundtrip is
    // asserted byte-identical — the same slab-for-slab guarantee the
    // restore path promises.
    let ck_reps = if common::fast_mode() { 3 } else { 10 };
    let ck_dir = std::env::temp_dir()
        .join(format!("dynacomm-bench-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&ck_dir).unwrap();
    let ck_path = ck_dir.join("shard-0.ckpt");
    let ck_path2 = ck_dir.join("shard-0.rewrite.ckpt");
    let ck_cfg = ServerConfig { workers: WORKERS, lr: 0.1 };
    let srv = ParamServer::start(ck_cfg, layer_init(), None).unwrap();
    srv.write_checkpoint(&ck_path).unwrap(); // warm the file + page cache
    let t = Instant::now();
    for _ in 0..ck_reps {
        srv.write_checkpoint(&ck_path).unwrap();
    }
    let secs_ck_write = t.elapsed().as_secs_f64() / ck_reps as f64;
    drop(srv);
    let ck_bytes = std::fs::metadata(&ck_path).unwrap().len();
    let t = Instant::now();
    let mut ck = Checkpoint::read_from(&ck_path).unwrap();
    for _ in 1..ck_reps {
        ck = Checkpoint::read_from(&ck_path).unwrap();
    }
    let secs_ck_read = t.elapsed().as_secs_f64() / ck_reps as f64;
    let t = Instant::now();
    let restored =
        ParamServer::start_restored(ck_cfg, None, ServerOptions::default(), &ck)
            .unwrap();
    let secs_ck_boot = t.elapsed().as_secs_f64();
    restored.write_checkpoint(&ck_path2).unwrap();
    assert_eq!(
        std::fs::read(&ck_path).unwrap(),
        std::fs::read(&ck_path2).unwrap(),
        "checkpoint roundtrip must be byte-identical"
    );
    drop(restored);
    let _ = std::fs::remove_dir_all(&ck_dir);
    let ck_mb = |secs: f64| reply_bytes() as f64 / (1 << 20) as f64 / secs;
    println!(
        "  checkpoint matrix ({:.1} MiB params, {ck_bytes} B on disk): write \
         {:>6.0} MB/s (fsynced)  parse {:>6.0} MB/s  restore boot {:.1} ms  \
         roundtrip byte-identical",
        reply_bytes() as f64 / (1 << 20) as f64,
        ck_mb(secs_ck_write),
        ck_mb(secs_ck_read),
        secs_ck_boot * 1e3,
    );

    // --- Obs overhead: the BSP lockstep mix with the observability plane
    // fully armed (tracing recording every assemble/apply span, a live
    // scraper polling the exposition endpoint) vs disarmed. Every metric
    // update is one relaxed atomic and spans are two clock reads + a ring
    // write, so the armed run must stay within 5% of baseline.
    let obs_iters = (reps / 4).max(4) as u64;
    let run_bsp_batch = |armed: bool| -> f64 {
        dynacomm::obs::trace::set_enabled(armed);
        let srv = ParamServer::start(
            ServerConfig { workers: WORKERS, lr: 0.1 },
            layer_init(),
            None,
        )
        .unwrap();
        let addr = srv.handle().addr;
        let mut scraper = None;
        let mut msrv = None;
        let stop_scrape = Arc::new(AtomicBool::new(false));
        if armed {
            let m = dynacomm::obs::expo::MetricsServer::bind("127.0.0.1:0").unwrap();
            let maddr = m.addr();
            msrv = Some(m);
            let stop = stop_scrape.clone();
            scraper = Some(std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let _ = dynacomm::obs::expo::scrape(maddr);
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }));
        }
        drive_bsp(addr, WORKERS, 0, 3); // warm the slab rotation
        let mut best = f64::INFINITY;
        for k in 0..3 {
            let start = 3 + k * obs_iters;
            best = best.min(drive_bsp(addr, WORKERS, start, start + obs_iters));
        }
        stop_scrape.store(true, Ordering::SeqCst);
        if let Some(t) = scraper {
            t.join().unwrap();
        }
        if let Some(m) = msrv.as_mut() {
            m.shutdown();
        }
        dynacomm::obs::trace::set_enabled(false);
        drop(srv);
        best
    };
    let best_off = run_bsp_batch(false);
    let best_on = run_bsp_batch(true);
    let obs_overhead_pct = 100.0 * (best_on / best_off - 1.0);
    assert!(
        obs_overhead_pct <= 5.0,
        "obs plane cost {obs_overhead_pct:.2}% of BSP lockstep wall-clock \
         (target <= 5%)"
    );
    println!(
        "  obs overhead ({obs_iters} iters, best of 3, tracing + live \
         scraper): off {best_off:.3}s  on {best_on:.3}s  \
         ({obs_overhead_pct:+.2}%, target <= 5%)"
    );

    // --- Pull-RTT quantiles: one worker's measured round-trip
    // distribution with the obs plane armed, interpolated from the log2
    // buckets by `Histogram::quantile`. The histogram stays alive through
    // the `obs_metrics_snapshot` dump below, so its `_p50`/`_p99` rows
    // land in the JSON artifact alongside these explicit columns.
    dynacomm::obs::trace::set_enabled(true);
    let rtt_hist = dynacomm::obs::register_histogram(
        "dynacomm_bench_pull_rtt_ms",
        "",
        dynacomm::obs::next_inst(),
    );
    {
        let srv = ParamServer::start(
            ServerConfig { workers: 1, lr: 0.1 },
            layer_init(),
            None,
        )
        .unwrap();
        let mut conn =
            Connection::new(TcpStream::connect(srv.handle().addr).unwrap(), None);
        let grad = vec![0.0f32; LAYER_F32S * LAYERS];
        for iter in 0..obs_iters.max(32) {
            let t0 = Instant::now();
            conn.send(&Message::Pull { iter, lo: 0, hi: LAYERS as u32 - 1 })
                .unwrap();
            match conn.recv().unwrap() {
                Message::PullReply { .. } => {}
                m => panic!("{m:?}"),
            }
            rtt_hist.observe(t0.elapsed().as_secs_f64() * 1e3);
            conn.send(&Message::Push {
                iter,
                lo: 0,
                hi: LAYERS as u32 - 1,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&grad),
            })
            .unwrap();
            match conn.recv().unwrap() {
                Message::PushAck { .. } => {}
                m => panic!("{m:?}"),
            }
        }
        drop(conn);
        drop(srv);
    }
    dynacomm::obs::trace::set_enabled(false);
    let rtt_p50 = rtt_hist.quantile(0.5).expect("populated histogram");
    let rtt_p99 = rtt_hist.quantile(0.99).expect("populated histogram");
    assert!(
        rtt_p50 > 0.0 && rtt_p99 >= rtt_p50,
        "quantiles ordered and positive: p50 {rtt_p50}, p99 {rtt_p99}"
    );
    println!(
        "  pull RTT quantiles (obs armed): p50 {rtt_p50:.3} ms  p99 {rtt_p99:.3} ms"
    );

    let json = Json::obj(vec![
        ("workers", Json::Num(WORKERS as f64)),
        ("layers", Json::Num(LAYERS as f64)),
        ("reply_bytes", Json::Num(reply_bytes() as f64)),
        ("pulls", Json::Num(total_pulls as f64)),
        ("server_mb_per_s", Json::Num(thr_new)),
        ("legacy_mb_per_s", Json::Num(thr_legacy)),
        ("speedup", Json::Num(speedup)),
        ("reply_cache_hit_rate", Json::Num(hit_rate)),
        ("reply_cache_builds", Json::Num(builds as f64)),
        ("steady_state_allocs", Json::Num(steady_allocs as f64)),
        (
            "steady_state_allocs_per_pull",
            Json::Num(steady_allocs as f64 / total_pulls as f64),
        ),
        ("pool_checkouts", Json::Num(s1.pool.checkouts as f64)),
        ("pool_recycled", Json::Num(s1.pool.recycled as f64)),
        ("pool_allocations", Json::Num(s1.pool.allocations as f64)),
        ("bsp_iters", Json::Num(bsp_iters as f64)),
        ("bsp_pull_mb_per_s", Json::Num(bsp_pull_mb_s)),
        ("bsp_builds", Json::Num(bsp_builds as f64)),
        ("bsp_hits", Json::Num(bsp_hits as f64)),
        ("bsp_steady_state_allocs", Json::Num(bsp_allocs as f64)),
        (
            "codec_matrix",
            Json::Arr(
                codec_rows
                    .iter()
                    .map(|row| {
                        Json::obj(vec![
                            ("codec", Json::Str(row.codec.name().to_string())),
                            ("wire_reply_bytes", Json::Num(row.wire_reply_bytes as f64)),
                            ("raw_reply_bytes", Json::Num(reply_bytes() as f64)),
                            ("bytes_saved_pct", Json::Num(row.saved_pct)),
                            ("raw_mb_per_s", Json::Num(row.raw_mb_per_s)),
                            ("wire_mb_per_s", Json::Num(row.wire_mb_per_s)),
                            (
                                "speedup_vs_fp32",
                                Json::Num(row.raw_mb_per_s / fp32_raw),
                            ),
                            ("reply_cache_hit_rate", Json::Num(row.hit_rate)),
                            ("steady_state_allocs", Json::Num(row.steady_allocs as f64)),
                            ("max_quant_error", Json::Num(row.max_quant_error)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "sync_matrix",
            Json::Arr(
                sync_rows
                    .iter()
                    .map(|row| {
                        Json::obj(vec![
                            ("sync", Json::Str(row.mode.name().to_string())),
                            ("straggler_slowdown", Json::Num(4.0)),
                            ("iters_per_sec", Json::Num(row.iters_per_sec)),
                            ("speedup_vs_bsp", Json::Num(row.speedup_vs_bsp)),
                            ("max_staleness", Json::Num(row.max_staleness as f64)),
                            ("staleness_bound", Json::Num(row.bound as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "tier_matrix",
            Json::Arr(vec![
                Json::obj(vec![
                    ("topology", Json::Str("flat".to_string())),
                    ("cloud_ingress_bytes", Json::Num(flat_ingress as f64)),
                    ("fleet_iters_per_sec", Json::Num(fleet_ips(secs_flat))),
                ]),
                Json::obj(vec![
                    ("topology", Json::Str("regional".to_string())),
                    ("group_size", Json::Num(TIER_GROUP_SIZE as f64)),
                    ("groups", Json::Num(TIER_GROUPS as f64)),
                    ("cloud_ingress_bytes", Json::Num(tiered_ingress as f64)),
                    ("fleet_iters_per_sec", Json::Num(fleet_ips(secs_tiered))),
                    ("ingress_saved_ratio", Json::Num(tier_ratio)),
                ]),
            ]),
        ),
        (
            "checkpoint_matrix",
            Json::Arr(vec![Json::obj(vec![
                ("param_bytes", Json::Num(reply_bytes() as f64)),
                ("file_bytes", Json::Num(ck_bytes as f64)),
                ("write_mb_per_s", Json::Num(ck_mb(secs_ck_write))),
                ("parse_mb_per_s", Json::Num(ck_mb(secs_ck_read))),
                ("restore_boot_ms", Json::Num(secs_ck_boot * 1e3)),
                ("roundtrip_byte_identical", Json::Num(1.0)),
            ])]),
        ),
        ("obs_overhead_pct", Json::Num(obs_overhead_pct)),
        ("obs_bsp_secs_off", Json::Num(best_off)),
        ("obs_bsp_secs_on", Json::Num(best_on)),
        ("obs_pull_rtt_p50_ms", Json::Num(rtt_p50)),
        ("obs_pull_rtt_p99_ms", Json::Num(rtt_p99)),
        (
            "obs_metrics_snapshot",
            Json::Arr(
                dynacomm::obs::snapshot_pairs()
                    .into_iter()
                    .map(|(series, value)| {
                        Json::obj(vec![
                            ("series", Json::Str(series)),
                            ("value", Json::Num(value)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("fast_mode", Json::Num(if common::fast_mode() { 1.0 } else { 0.0 })),
    ]);
    figures::write_result("BENCH_wire", json).unwrap();
    println!("[bench] wrote results/BENCH_wire.json");
}
