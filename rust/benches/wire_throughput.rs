//! Wire-path microbench: byte-slab encode/decode versus the seed's
//! element-wise f32 path, on a 16 MiB `PullReply`.
//!
//! The slab pipeline's claim (docs/WIRE.md): serializing a tensor message
//! is a bulk byte copy, so encode+decode throughput is memcpy-bound
//! rather than per-element-loop-bound. This bench reconstructs the seed's
//! per-element encoder/decoder verbatim and races it against
//! `Message::encode_into`/`Message::decode`, printing MB/s per direction
//! and the end-to-end speedup.

mod common;

use std::hint::black_box;
use std::time::Instant;

use dynacomm::net::{slab, Message};

/// 4 Mi f32 elements = 16 MiB of tensor payload.
const ELEMS: usize = 4 << 20;
const PAYLOAD_BYTES: usize = 4 * ELEMS;

/// The seed's encoder: header writes plus a per-element
/// `extend_from_slice(&v.to_le_bytes())` loop over `Vec<f32>` data.
fn legacy_encode(iter: u64, lo: u32, hi: u32, data: &[f32]) -> Vec<u8> {
    let wire_size = 1 + 8 + 4 + 4 + 4 + 4 * data.len();
    let mut buf = Vec::with_capacity(4 + wire_size);
    buf.extend_from_slice(&(wire_size as u32).to_le_bytes());
    buf.push(2); // PullReply opcode
    buf.extend_from_slice(&iter.to_le_bytes());
    buf.extend_from_slice(&lo.to_le_bytes());
    buf.extend_from_slice(&hi.to_le_bytes());
    buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

/// The seed's decoder tail: element count, then a per-element
/// `f32::from_le_bytes` collect into a fresh `Vec<f32>`.
fn legacy_decode(payload: &[u8]) -> (u64, u32, u32, Vec<f32>) {
    assert_eq!(payload[0], 2);
    let b = &payload[1..];
    let iter = u64::from_le_bytes(b[..8].try_into().unwrap());
    let lo = u32::from_le_bytes(b[8..12].try_into().unwrap());
    let hi = u32::from_le_bytes(b[12..16].try_into().unwrap());
    let n = u32::from_le_bytes(b[16..20].try_into().unwrap()) as usize;
    let data: Vec<f32> = b[20..20 + 4 * n]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    (iter, lo, hi, data)
}

/// Best-of-`reps` seconds for one full encode+decode round trip.
fn time_best(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn mb_per_s(seconds: f64) -> f64 {
    PAYLOAD_BYTES as f64 / (1 << 20) as f64 / seconds
}

fn main() {
    let reps = if common::fast_mode() { 5 } else { 15 };
    let values: Vec<f32> = (0..ELEMS).map(|i| (i as f32) * 0.25 - 1000.0).collect();

    // --- Seed path: Vec<f32> payload, per-element encode/decode. ---
    let legacy_enc = time_best(reps, || {
        black_box(legacy_encode(7, 0, 5, black_box(&values)));
    });
    let frame = legacy_encode(7, 0, 5, &values);
    let legacy_dec = time_best(reps, || {
        black_box(legacy_decode(black_box(&frame[4..])));
    });

    // --- Slab path: Vec<u8> payload, bulk copies, reused scratch. ---
    let msg = Message::PullReply {
        iter: 7,
        lo: 0,
        hi: 5,
        applied: 7,
        codec: dynacomm::net::codec::CodecId::Fp32,
        data: slab::from_f32s(&values),
    };
    let mut scratch = Vec::new();
    msg.encode_into(&mut scratch); // warm the scratch buffer
    let slab_enc = time_best(reps, || {
        msg.encode_into(black_box(&mut scratch));
        black_box(&scratch);
    });
    let slab_dec = time_best(reps, || {
        black_box(Message::decode(black_box(&scratch[4..])).unwrap());
    });

    // Cross-check: both paths carry the same 16 MiB of tensor bytes and
    // decode back to the original values. (The count-field semantics
    // differ — elements vs bytes — and the v4 reply header carries the
    // extra `applied: u64`, so each frame is decoded by its own decoder
    // and the tensor bytes are compared at their respective offsets.)
    assert_eq!(scratch.len(), frame.len() + 8, "v4 header adds exactly `applied`");
    assert_eq!(scratch[33..], frame[25..], "tensor bytes diverged");
    let (_, _, _, legacy_values) = legacy_decode(&frame[4..]);
    assert_eq!(legacy_values, values);
    match Message::decode(&scratch[4..]).unwrap() {
        Message::PullReply { data, .. } => assert_eq!(slab::to_f32s(&data), values),
        m => panic!("{m:?}"),
    }

    println!(
        "[bench] wire_throughput: 16 MiB PullReply, best of {reps} (release build expected)"
    );
    println!(
        "  encode: legacy {:>8.0} MB/s   slab {:>8.0} MB/s   ({:.1}x)",
        mb_per_s(legacy_enc),
        mb_per_s(slab_enc),
        legacy_enc / slab_enc
    );
    println!(
        "  decode: legacy {:>8.0} MB/s   slab {:>8.0} MB/s   ({:.1}x)",
        mb_per_s(legacy_dec),
        mb_per_s(slab_dec),
        legacy_dec / slab_dec
    );
    let total_speedup = (legacy_enc + legacy_dec) / (slab_enc + slab_dec);
    println!("  encode+decode speedup: {total_speedup:.1}x (target ≥ 5x)");
}
