//! Fig. 8 — normalized execution time of the backward propagation, batch
//! size 16 (balanced comp/comm regime: biggest backward gains).

mod common;

use dynacomm::figures::{self, Pass};

fn main() {
    let cells = common::timed("fig8 grid", || {
        figures::normalized_pass_times(16, Pass::Backward)
    });
    println!(
        "{}",
        figures::render_normalized(
            &cells,
            "Fig. 8: normalized backward execution time (batch=16)"
        )
    );
    figures::write_result("fig8_bwd_bs16", figures::normalized_to_json(&cells))
        .expect("writing results");
}
