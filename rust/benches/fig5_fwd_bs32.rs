//! Fig. 5 — normalized execution time of the **forward** propagation,
//! batch size 32, four models × four strategies, with the
//! {non-overlapping compute, overlap, non-overlapping comm} split.

mod common;

use dynacomm::figures::{self, Pass};

fn main() {
    let cells = common::timed("fig5 grid", || {
        figures::normalized_pass_times(32, Pass::Forward)
    });
    println!(
        "{}",
        figures::render_normalized(
            &cells,
            "Fig. 5: normalized forward execution time (batch=32)"
        )
    );
    figures::write_result("fig5_fwd_bs32", figures::normalized_to_json(&cells))
        .expect("writing results");
}
