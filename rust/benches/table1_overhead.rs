//! Table I — scheduling overhead of DynaComm and iBatch per model, against
//! the idle windows that hide them (Δt + gt¹ forward / Δt + pt¹ backward).

mod common;

use dynacomm::figures;
use dynacomm::util::json::Json;

fn main() {
    let reps = if common::fast_mode() { 5 } else { 25 };
    let rows = common::timed("table1", || figures::table1(reps));
    println!("Table I: scheduling overhead (ms, mean ± std over {reps} runs)");
    println!(
        "{:<14} {:>16} {:>16} {:>12} {:>16} {:>16} {:>12}",
        "network", "DynaComm/Fwd", "iBatch/Fwd", "Δt+gt¹", "DynaComm/Bwd", "iBatch/Bwd", "Δt+pt¹"
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        println!(
            "{:<14} {:>8.4}±{:<7.4} {:>8.4}±{:<7.4} {:>12.2} {:>8.4}±{:<7.4} {:>8.4}±{:<7.4} {:>12.2}",
            r.model,
            r.dynacomm_fwd_ms.mean,
            r.dynacomm_fwd_ms.std,
            r.ibatch_fwd_ms.mean,
            r.ibatch_fwd_ms.std,
            r.idle_fwd_ms,
            r.dynacomm_bwd_ms.mean,
            r.dynacomm_bwd_ms.std,
            r.ibatch_bwd_ms.mean,
            r.ibatch_bwd_ms.std,
            r.idle_bwd_ms
        );
        // The paper's point: forward scheduling hides inside the Δt+gt¹
        // window for every evaluated model.
        if r.dynacomm_fwd_ms.mean > r.idle_fwd_ms {
            println!("  note: {} forward scheduling exceeds its idle window", r.model);
        }
        json_rows.push(Json::obj(vec![
            ("model", Json::Str(r.model.clone())),
            ("dynacomm_fwd_ms", Json::Num(r.dynacomm_fwd_ms.mean)),
            ("ibatch_fwd_ms", Json::Num(r.ibatch_fwd_ms.mean)),
            ("idle_fwd_ms", Json::Num(r.idle_fwd_ms)),
            ("dynacomm_bwd_ms", Json::Num(r.dynacomm_bwd_ms.mean)),
            ("ibatch_bwd_ms", Json::Num(r.ibatch_bwd_ms.mean)),
            ("idle_bwd_ms", Json::Num(r.idle_bwd_ms)),
        ]));
    }
    figures::write_result("table1_overhead", Json::Arr(json_rows)).unwrap();

    // Companion table: scheduling-cost savings from gain-thresholded
    // re-planning (the cached DynaComm plan short-circuits the O(L^3) DP).
    let calls = if common::fast_mode() { 10 } else { 40 };
    let sav = common::timed("gain threshold savings", || {
        figures::gain_threshold_savings(152, calls, 42, &[0.0, 1.0, 5.0, 25.0])
    });
    println!("\ngain-thresholded re-planning ({calls} re-profilings, 152 layers)");
    println!("{:<14} {:>14} {:>10}", "threshold(ms)", "plan(ms)", "reused");
    let mut json_rows = Vec::new();
    for r in &sav {
        println!(
            "{:<14} {:>7.4}±{:<6.4} {:>6}/{}",
            r.threshold_ms, r.plan_ms.mean, r.plan_ms.std, r.reused, r.calls
        );
        json_rows.push(Json::obj(vec![
            ("threshold_ms", Json::Num(r.threshold_ms)),
            ("plan_ms", Json::Num(r.plan_ms.mean)),
            ("reused", Json::Num(r.reused as f64)),
            ("calls", Json::Num(r.calls as f64)),
        ]));
    }
    figures::write_result("table1_gain_threshold", Json::Arr(json_rows)).unwrap();
}
