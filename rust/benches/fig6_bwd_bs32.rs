//! Fig. 6 — normalized execution time of the **backward** propagation,
//! batch size 32.

mod common;

use dynacomm::figures::{self, Pass};

fn main() {
    let cells = common::timed("fig6 grid", || {
        figures::normalized_pass_times(32, Pass::Backward)
    });
    println!(
        "{}",
        figures::render_normalized(
            &cells,
            "Fig. 6: normalized backward execution time (batch=32)"
        )
    );
    figures::write_result("fig6_bwd_bs32", figures::normalized_to_json(&cells))
        .expect("writing results");
}
