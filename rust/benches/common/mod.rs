//! Shared bench plumbing: wall-clock measurement of the figure drivers and
//! result emission under `results/`.

use std::time::Instant;

#[allow(dead_code)]
/// Time a closure, printing a one-line bench report.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("[bench] {label}: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    out
}

/// `true` when benches should use reduced iteration counts (CI).
#[allow(dead_code)]
pub fn fast_mode() -> bool {
    std::env::var("DYNACOMM_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}
