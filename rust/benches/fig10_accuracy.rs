//! Fig. 10 — top-1 accuracy and loss versus epoch, DynaComm vs the
//! sequential default PS, through the REAL stack: PJRT artifacts (Pallas
//! kernels inside), the Rust PS framework, and the shaped loopback edge
//! network. The paper's claim is that the curves coincide — with a single
//! worker the update sequence is deterministic, so ours coincide exactly.
//!
//! Requires `make artifacts`.

mod common;

use dynacomm::config::Strategy;
use dynacomm::runtime::artifacts_available;
use dynacomm::training::{train, TrainConfig};
use dynacomm::util::json::Json;

fn main() {
    if !artifacts_available("artifacts") {
        println!("fig10: skipped (run `make artifacts` first)");
        return;
    }
    let (epochs, iters) = if common::fast_mode() { (2, 4) } else { (4, 8) };
    let mut results = Vec::new();
    for strategy in [Strategy::Sequential, Strategy::DynaComm] {
        let cfg = TrainConfig {
            strategy,
            workers: 1,
            servers: 2,
            epochs,
            iters_per_epoch: iters,
            setup_ms: 1.0,
            latency_ms: 0.5,
            bytes_per_ms: 1_000_000.0,
            val_batches: 4,
            ..TrainConfig::default()
        };
        let r = common::timed(&format!("train {}", strategy.name()), || {
            train(&cfg).expect("training failed")
        });
        println!("\nFig. 10 [{}]:", strategy.name());
        for (e, (loss, acc)) in
            r.epoch_loss.iter().zip(&r.epoch_train_acc).enumerate()
        {
            println!("  epoch {e}: loss={loss:.4} train-top1={acc:.3}");
        }
        println!("  val-top1={:.3}", r.val_acc);
        results.push((strategy, r));
    }
    let (_, seq) = &results[0];
    let (_, dyna) = &results[1];
    let identical = seq.per_worker[0].losses == dyna.per_worker[0].losses;
    println!(
        "\nloss sequences identical across strategies: {identical} \
         (paper: accuracy untouched)"
    );
    let to_json = |r: &dynacomm::training::TrainResult| {
        Json::obj(vec![
            ("epoch_loss", Json::arr_f64(&r.epoch_loss)),
            ("epoch_train_acc", Json::arr_f64(&r.epoch_train_acc)),
            ("val_acc", Json::Num(r.val_acc)),
        ])
    };
    dynacomm::figures::write_result(
        "fig10_accuracy",
        Json::obj(vec![
            ("sequential", to_json(seq)),
            ("dynacomm", to_json(dyna)),
            ("identical", Json::Bool(identical)),
        ]),
    )
    .unwrap();
    assert!(identical, "scheduling changed the math!");
}
