//! Table II — per-worker training speed (samples/sec) with the real-time
//! profiling switch on and off, through the real stack.
//!
//! Requires `make artifacts`.

mod common;

use dynacomm::runtime::artifacts_available;
use dynacomm::training::{train, TrainConfig};
use dynacomm::util::json::Json;

fn main() {
    if !artifacts_available("artifacts") {
        println!("table2: skipped (run `make artifacts` first)");
        return;
    }
    let iters = if common::fast_mode() { 4 } else { 10 };
    let mut rates = Vec::new();
    // Warm-up pass first (allocator/caches), then measure off→on so any
    // residual warm-up bias works AGAINST the profiling=on run.
    for profiling in [false, true] {
        let cfg = TrainConfig {
            profiling,
            workers: 1,
            servers: 2,
            epochs: 1,
            iters_per_epoch: iters,
            setup_ms: 1.0,
            latency_ms: 0.5,
            bytes_per_ms: 1_000_000.0,
            val_batches: 0,
            ..TrainConfig::default()
        };
        let r = common::timed(&format!("profiling={profiling}"), || {
            train(&cfg).expect("training failed")
        });
        println!(
            "profiling {}: {:.2} samples/sec/worker",
            if profiling { "on " } else { "off" },
            r.samples_per_sec_per_worker
        );
        rates.push(r.samples_per_sec_per_worker);
    }
    let loss_pct = 100.0 * (1.0 - rates[1] / rates[0]);
    println!(
        "\nTable II: profiling costs {loss_pct:.2}% of local training speed \
         (paper: ≤ 1.33%)"
    );
    dynacomm::figures::write_result(
        "table2_profiling",
        Json::obj(vec![
            ("off_samples_per_sec", Json::Num(rates[0])),
            ("on_samples_per_sec", Json::Num(rates[1])),
            ("overhead_pct", Json::Num(loss_pct)),
        ]),
    )
    .unwrap();
}
