//! Fig. 12 — scheduling-algorithm wall-clock versus network depth on
//! randomly generated profiling results, DynaComm (O(L^3) DP) vs iBatch
//! (greedy), forward and backward. Also fits the growth exponent.

mod common;

use dynacomm::figures;
use dynacomm::util::json::Json;
use dynacomm::util::stats;

fn main() {
    let depths: &[usize] = if common::fast_mode() {
        &[10, 20, 40, 80]
    } else {
        &[10, 20, 40, 80, 160, 320]
    };
    let reps = if common::fast_mode() { 3 } else { 10 };
    println!("Fig. 12: scheduling overhead vs number of layers ({reps} reps)");
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>14}",
        "layers", "dyna-fwd(ms)", "dyna-bwd(ms)", "ibatch-fwd", "ibatch-bwd"
    );
    let mut rows = Vec::new();
    let mut ls = Vec::new();
    let mut ts = Vec::new();
    for &depth in depths {
        let t = common::timed(&format!("depth {depth}"), || {
            figures::time_schedulers(depth, reps, 42)
        });
        println!(
            "{:<8} {:>14.4} {:>14.4} {:>14.4} {:>14.4}",
            depth,
            t.dynacomm_fwd_ms.mean,
            t.dynacomm_bwd_ms.mean,
            t.ibatch_fwd_ms.mean,
            t.ibatch_bwd_ms.mean
        );
        ls.push(depth as f64);
        ts.push(t.dynacomm_fwd_ms.mean.max(1e-6));
        rows.push(Json::obj(vec![
            ("layers", Json::Num(depth as f64)),
            ("dynacomm_fwd_ms", Json::Num(t.dynacomm_fwd_ms.mean)),
            ("dynacomm_bwd_ms", Json::Num(t.dynacomm_bwd_ms.mean)),
            ("ibatch_fwd_ms", Json::Num(t.ibatch_fwd_ms.mean)),
            ("ibatch_bwd_ms", Json::Num(t.ibatch_bwd_ms.mean)),
        ]));
    }
    let k = stats::power_law_exponent(&ls, &ts);
    println!("\nfitted DynaComm growth exponent: L^{k:.2} (paper: O(L^3))");
    figures::write_result(
        "fig12_sched_overhead",
        Json::obj(vec![("exponent", Json::Num(k)), ("rows", Json::Arr(rows))]),
    )
    .unwrap();
}
