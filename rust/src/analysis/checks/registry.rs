//! Check 4 — registry completeness.
//!
//! Every pluggable registry (schedulers, sync policies, wire codecs)
//! exports a `NAMES` const listing its canonical entries. Each entry must
//! also appear in the CLI `HELP` banner (so `--help` never lies about what
//! exists) and on the registry's doc page (so a new entry lands with
//! documentation). The manifest (`[[registry.entries]]`) maps each
//! registry to its source file and doc page.

use std::path::Path;

use super::super::manifest::Manifest;
use super::super::report::Finding;
use super::super::source::{CodeTok, SrcFile};
use crate::analysis::lexer::TokKind;

pub fn check(root: &Path, files: &[SrcFile], manifest: &Manifest) -> Vec<Finding> {
    let mut out = Vec::new();
    let help = files
        .iter()
        .find(|f| f.path == manifest.help_source)
        .and_then(|f| extract_help(&f.code));
    if help.is_none() {
        out.push(Finding::new(
            "registry",
            &manifest.help_source,
            0,
            "no `const HELP` string found — the banner check cannot run".to_string(),
        ));
    }
    for entry in &manifest.registries {
        let Some(src) = files.iter().find(|f| f.path == entry.source) else {
            out.push(Finding::new(
                "registry",
                &entry.source,
                0,
                format!("registry `{}` source was not scanned", entry.name),
            ));
            continue;
        };
        let Some((names, line)) = extract_names(&src.code) else {
            out.push(Finding::new(
                "registry",
                &entry.source,
                0,
                format!(
                    "registry `{}` has no `const NAMES` string array",
                    entry.name
                ),
            ));
            continue;
        };
        if names.is_empty() {
            out.push(Finding::new(
                "registry",
                &entry.source,
                line,
                format!("registry `{}` NAMES is empty", entry.name),
            ));
            continue;
        }
        if let Some((help_text, help_line)) = &help {
            for name in &names {
                if !help_text.contains(name.as_str()) {
                    out.push(Finding::new(
                        "registry",
                        &manifest.help_source,
                        *help_line,
                        format!(
                            "{} registry entry `{name}` missing from the CLI \
                             HELP banner",
                            entry.name
                        ),
                    ));
                }
            }
        }
        match std::fs::read_to_string(root.join(&entry.doc)) {
            Ok(doc) => {
                for name in &names {
                    if !doc.contains(name.as_str()) {
                        out.push(Finding::new(
                            "registry",
                            &entry.doc,
                            0,
                            format!(
                                "{} registry entry `{name}` is undocumented here",
                                entry.name
                            ),
                        ));
                    }
                }
            }
            Err(_) => out.push(Finding::new(
                "registry",
                &entry.doc,
                0,
                format!("doc page for registry `{}` is missing", entry.name),
            )),
        }
    }
    out
}

/// The string contents of `const NAMES: [&str; N] = ["…", …];` and the
/// line the const sits on.
pub fn extract_names(code: &[CodeTok]) -> Option<(Vec<String>, u32)> {
    for j in 1..code.len() {
        if !(code[j].is_ident("NAMES") && code[j - 1].is_ident("const")) {
            continue;
        }
        // Skip the type annotation to the `=`, then collect the array.
        let mut k = j + 1;
        while k < code.len() && !code[k].is_punct('=') {
            k += 1;
        }
        while k < code.len() && !code[k].is_punct('[') {
            k += 1;
        }
        let mut names = Vec::new();
        while k < code.len() && !code[k].is_punct(']') {
            if code[k].kind == TokKind::Str {
                names.push(code[k].text.clone());
            }
            k += 1;
        }
        return Some((names, code[j].line));
    }
    None
}

/// The `const HELP: &str = "…";` banner text and its line.
pub fn extract_help(code: &[CodeTok]) -> Option<(String, u32)> {
    for j in 1..code.len() {
        if !(code[j].is_ident("HELP") && code[j - 1].is_ident("const")) {
            continue;
        }
        for k in j + 1..code.len() {
            if code[k].kind == TokKind::Str {
                return Some((code[k].text.clone(), code[j].line));
            }
            if code[k].is_punct(';') {
                break;
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::source::SrcFile;

    fn parse(src: &str) -> SrcFile {
        SrcFile::parse("fixture.rs", src.to_string())
    }

    #[test]
    fn good_fixture_names_all_appear_in_its_help() {
        let f = parse(include_str!("../tests/registry_good.rs"));
        let (names, _) = extract_names(&f.code).unwrap();
        assert_eq!(names, vec!["alpha", "beta", "gamma"]);
        let (help, _) = extract_help(&f.code).unwrap();
        for name in &names {
            assert!(help.contains(name.as_str()), "{name} in banner");
        }
    }

    #[test]
    fn bad_fixture_banner_misses_an_entry() {
        let f = parse(include_str!("../tests/registry_bad.rs"));
        let (names, _) = extract_names(&f.code).unwrap();
        let (help, _) = extract_help(&f.code).unwrap();
        let missing: Vec<&String> =
            names.iter().filter(|n| !help.contains(n.as_str())).collect();
        assert_eq!(missing.len(), 1, "exactly the seeded gap");
        assert_eq!(missing[0], "gamma");
    }

    #[test]
    fn extraction_ignores_non_const_uses_of_the_names() {
        let f = parse(
            "pub const NAMES: [&str; 2] = [\"a\", \"b\"];\n\
             fn list() -> String { NAMES.join(\", \") }\n",
        );
        let (names, line) = extract_names(&f.code).unwrap();
        assert_eq!(names, vec!["a", "b"]);
        assert_eq!(line, 1);
    }

    #[test]
    fn missing_consts_are_reported_as_none() {
        assert!(extract_names(&parse("fn f() {}").code).is_none());
        assert!(extract_help(&parse("fn f() {}").code).is_none());
    }
}
