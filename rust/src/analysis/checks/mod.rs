//! The five dynalint passes. Each is a pure function from parsed sources
//! (plus the manifest) to findings; the runner in [`crate::analysis`]
//! walks the tree and concatenates their output.

pub mod alloc;
pub mod locks;
pub mod metrics;
pub mod registry;
pub mod wire;
