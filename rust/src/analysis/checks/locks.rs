//! Check 2 — lock/condvar discipline.
//!
//! Three passes over every file except the poisoning-policy helper
//! (`manifest [locks] policy_file`) and `#[cfg(test)]` modules:
//!
//! 1. **Bare sites** — any `.lock()` call, or `.wait()` on a declared
//!    condvar identifier, must route through `util::sync::{lock_or_die,
//!    wait_or_die}` so a poisoning abort names the lock.
//! 2. **Predicate re-check** — every condvar wait (a `wait_or_die(..)`
//!    call or a bare `cv.wait(..)`) must sit lexically inside a
//!    `while`/`loop` body: condvar wakeups are spurious by contract.
//! 3. **Partial order** — an intra-procedural walk tracks which locks are
//!    held (let-bound guards until their block closes or `drop(guard)`;
//!    temporaries until the next `;`/`,` at their own nesting depth) and
//!    flags any nested acquisition that re-takes a held lock or acquires
//!    against the declared outermost-first order.
//!
//! The walk is lexical, not a borrow analysis: guard lifetimes are
//! approximated (see docs/ANALYSIS.md for the exact rules and their known
//! over/under-approximations), and nesting across function calls is out
//! of scope — the declared order is what makes cross-function nesting
//! safe by construction.

use super::super::manifest::Manifest;
use super::super::report::Finding;
use super::super::source::{find_fn_bodies, find_loop_spans, CodeTok, SrcFile};
use crate::analysis::lexer::TokKind;

pub fn check(files: &[SrcFile], manifest: &Manifest) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in files {
        if file.path == manifest.policy_file {
            continue;
        }
        bare_sites(file, manifest, &mut out);
        wait_loops(file, manifest, &mut out);
        order_pass(file, manifest, &mut out);
    }
    out
}

/// Pass 1: flag raw `.lock()` / condvar `.wait()` call sites.
fn bare_sites(file: &SrcFile, manifest: &Manifest, out: &mut Vec<Finding>) {
    let code = &file.code;
    for j in 0..code.len() {
        if file.in_test(j) {
            continue;
        }
        if j >= 1
            && code[j].is_ident("lock")
            && code[j - 1].is_punct('.')
            && j + 1 < code.len()
            && code[j + 1].is_punct('(')
        {
            let recv = receiver_ident(code, j - 1);
            let name = recv
                .and_then(|r| manifest.lock_for_ident(r))
                .unwrap_or("<lock name>");
            out.push(Finding::new(
                "locks",
                &file.path,
                code[j].line,
                format!(
                    "bare `.lock()` call — route through `util::sync::{}(&.., \
                     \"{name}\")` so a poisoning abort names the lock",
                    manifest.lock_helper
                ),
            ));
        }
        if j >= 2
            && code[j].is_ident("wait")
            && code[j - 1].is_punct('.')
            && j + 1 < code.len()
            && code[j + 1].is_punct('(')
        {
            if let Some(cv) = receiver_ident(code, j - 1) {
                if manifest.is_condvar(cv) {
                    out.push(Finding::new(
                        "locks",
                        &file.path,
                        code[j].line,
                        format!(
                            "bare `.wait()` on condvar `{cv}` — route through \
                             `util::sync::{}`",
                            manifest.wait_helper
                        ),
                    ));
                }
            }
        }
    }
}

/// The identifier a `.method` chain hangs off: the ident before the dot.
fn receiver_ident(code: &[CodeTok], dot: usize) -> Option<&str> {
    if dot == 0 {
        return None;
    }
    let prev = &code[dot - 1];
    if prev.kind == TokKind::Ident {
        Some(&prev.text)
    } else {
        None
    }
}

/// Pass 2: every condvar wait must sit inside a `while`/`loop` body.
fn wait_loops(file: &SrcFile, manifest: &Manifest, out: &mut Vec<Finding>) {
    let code = &file.code;
    let spans = find_loop_spans(code);
    let inside = |idx: usize| spans.iter().any(|&(open, close)| idx > open && idx < close);
    for j in 0..code.len() {
        if file.in_test(j) {
            continue;
        }
        let is_helper_wait = code[j].is_ident(&manifest.wait_helper)
            && j + 1 < code.len()
            && code[j + 1].is_punct('(');
        let is_bare_wait = j >= 2
            && code[j].is_ident("wait")
            && code[j - 1].is_punct('.')
            && j + 1 < code.len()
            && code[j + 1].is_punct('(')
            && receiver_ident(code, j - 1).is_some_and(|r| manifest.is_condvar(r));
        if !(is_helper_wait || is_bare_wait) {
            continue;
        }
        let line = code[j].line;
        if inside(j) || file.directives.allowed("condvar", line) {
            continue;
        }
        out.push(Finding::new(
            "locks",
            &file.path,
            line,
            "condvar wait outside a `while`/`loop` predicate re-check body — \
             wakeups are spurious by contract, re-test the predicate around \
             the wait"
                .to_string(),
        ));
    }
}

/// A lock the intra-procedural walk currently believes is held.
struct Held {
    name: String,
    guard: Option<String>,
    brace: i64,
    paren: i64,
    temp: bool,
    line: u32,
}

/// Pass 3: nested acquisitions must follow the declared partial order.
fn order_pass(file: &SrcFile, manifest: &Manifest, out: &mut Vec<Finding>) {
    let code = &file.code;
    let bodies = find_fn_bodies(code);
    for body in &bodies {
        if file.in_test(body.fn_idx) {
            continue;
        }
        // Skip nested named fns: they run in their own call context.
        let children: Vec<(usize, usize)> = bodies
            .iter()
            .filter(|c| c.fn_idx > body.open && c.close < body.close)
            .map(|c| (c.fn_idx, c.close))
            .collect();
        walk_fn(file, manifest, body.open, body.close, &children, out);
    }
}

fn walk_fn(
    file: &SrcFile,
    manifest: &Manifest,
    open: usize,
    close: usize,
    skip: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let code = &file.code;
    let mut held: Vec<Held> = Vec::new();
    let mut brace = 1i64; // inside the body's `{`
    let mut paren = 0i64;
    let mut j = open + 1;
    while j < close {
        if let Some(&(_, child_close)) = skip.iter().find(|&&(start, _)| start == j) {
            j = child_close + 1;
            continue;
        }
        let t = &code[j];
        if t.is_punct('{') {
            brace += 1;
        } else if t.is_punct('}') {
            brace -= 1;
            held.retain(|h| h.brace <= brace);
        } else if t.is_punct('(') || t.is_punct('[') {
            paren += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            paren -= 1;
        } else if t.is_punct(';') || t.is_punct(',') {
            held.retain(|h| !(h.temp && h.brace == brace && h.paren == paren));
        } else if t.is_ident("drop")
            && j + 3 < close
            && code[j + 1].is_punct('(')
            && code[j + 2].kind == TokKind::Ident
            && code[j + 3].is_punct(')')
        {
            let g = code[j + 2].text.clone();
            held.retain(|h| h.guard.as_deref() != Some(g.as_str()));
        } else if let Some(name) = acquisition_at(code, j, close, manifest) {
            let line = t.line;
            for h in &held {
                report_nesting(file, manifest, h, &name, line, out);
            }
            let guard = let_bound_guard(code, open, j);
            held.push(Held {
                name,
                temp: guard.is_none(),
                guard,
                brace,
                paren,
                line,
            });
        }
        j += 1;
    }
}

/// If the token at `j` starts a lock acquisition, its canonical name.
fn acquisition_at(
    code: &[CodeTok],
    j: usize,
    close: usize,
    manifest: &Manifest,
) -> Option<String> {
    // `lock_or_die(&path.to.lock, "canonical.name")` — the string literal
    // names the lock, no receiver mapping needed.
    if code[j].is_ident(&manifest.lock_helper)
        && j + 1 < close
        && code[j + 1].is_punct('(')
    {
        let mut depth = 0i64;
        for k in j + 1..close {
            if code[k].is_punct('(') {
                depth += 1;
            } else if code[k].is_punct(')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if code[k].kind == TokKind::Str && depth == 1 {
                return Some(code[k].text.clone());
            }
        }
        return Some("<unnamed>".to_string());
    }
    // Bare `recv.lock(` with a receiver the manifest can name.
    if j >= 2
        && code[j].is_ident("lock")
        && code[j - 1].is_punct('.')
        && j + 1 < close
        && code[j + 1].is_punct('(')
    {
        if let Some(name) =
            receiver_ident(code, j - 1).and_then(|r| manifest.lock_for_ident(r))
        {
            return Some(name.to_string());
        }
    }
    None
}

fn report_nesting(
    file: &SrcFile,
    manifest: &Manifest,
    held: &Held,
    name: &str,
    line: u32,
    out: &mut Vec<Finding>,
) {
    if file.directives.allowed("lock-order", line) {
        return;
    }
    if held.name == name {
        out.push(Finding::new(
            "locks",
            &file.path,
            line,
            format!(
                "re-acquires `{name}` already held since line {} — self-deadlock",
                held.line
            ),
        ));
        return;
    }
    match (manifest.lock_rank(&held.name), manifest.lock_rank(name)) {
        (Some(outer), Some(inner)) if inner <= outer => {
            out.push(Finding::new(
                "locks",
                &file.path,
                line,
                format!(
                    "acquires `{name}` while holding `{}` (line {}) — violates \
                     the declared order {:?}",
                    held.name, held.line, manifest.lock_order
                ),
            ));
        }
        (Some(_), Some(_)) => {}
        _ => {
            out.push(Finding::new(
                "locks",
                &file.path,
                line,
                format!(
                    "nested acquisition of `{name}` under `{}` involves a lock \
                     missing from the declared order — add it to the manifest",
                    held.name
                ),
            ));
        }
    }
}

/// If the statement enclosing the acquisition at `j` is a simple
/// `let [mut] guard = …`, the guard identifier.
fn let_bound_guard(code: &[CodeTok], body_open: usize, j: usize) -> Option<String> {
    let mut k = j;
    while k > body_open + 1 {
        let t = &code[k - 1];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') || t.is_punct(',') {
            break;
        }
        k -= 1;
    }
    if !code[k].is_ident("let") {
        return None;
    }
    let mut g = k + 1;
    if g < j && code[g].is_ident("mut") {
        g += 1;
    }
    if g + 1 < j && code[g].kind == TokKind::Ident && code[g + 1].is_punct('=') {
        return Some(code[g].text.clone());
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::manifest::Manifest;
    use crate::analysis::source::SrcFile;

    fn manifest() -> Manifest {
        Manifest::from_text(include_str!("../dynalint.toml")).unwrap()
    }

    fn run_on(src: &str) -> Vec<Finding> {
        let file = SrcFile::parse("fixture.rs", src.to_string());
        check(&[file], &manifest())
    }

    #[test]
    fn bad_fixture_trips_all_three_passes() {
        let findings = run_on(include_str!("../tests/locks_bad.rs"));
        let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
        assert_eq!(findings.len(), 3, "{rendered:?}");
        assert!(
            rendered.iter().any(|r| r.contains("violates the declared order")),
            "{rendered:?}"
        );
        assert!(rendered.iter().any(|r| r.contains("bare `.lock()`")), "{rendered:?}");
        assert!(
            rendered.iter().any(|r| r.contains("predicate re-check")),
            "{rendered:?}"
        );
    }

    #[test]
    fn good_fixture_is_clean() {
        let findings = run_on(include_str!("../tests/locks_good.rs"));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn reacquisition_of_a_held_lock_is_a_self_deadlock() {
        let findings = run_on(
            "fn f(p: &Pool) {\n  let a = lock_or_die(&p.free, \"pool.free\");\n  \
             let b = lock_or_die(&p.free, \"pool.free\");\n  drop(b); drop(a);\n}\n",
        );
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("self-deadlock"));
    }

    #[test]
    fn drop_releases_the_guard_for_later_acquisitions() {
        let findings = run_on(
            "fn f(p: &Pool, s: &Srv) {\n  let free = lock_or_die(&p.free, \"pool.free\");\n  \
             drop(free);\n  let conns = lock_or_die(&s.conns, \"server.conns\");\n  drop(conns);\n}\n",
        );
        assert!(findings.is_empty(), "drop released pool.free: {findings:?}");
    }

    #[test]
    fn block_scoped_guards_release_at_the_closing_brace() {
        let findings = run_on(
            "fn f(p: &Pool, s: &Srv) {\n  {\n    let free = lock_or_die(&p.free, \"pool.free\");\n    \
             free.push(1);\n  }\n  let conns = lock_or_die(&s.conns, \"server.conns\");\n  drop(conns);\n}\n",
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn statement_temporaries_release_at_the_semicolon() {
        let findings = run_on(
            "fn f(p: &Pool, s: &Srv) {\n  lock_or_die(&p.free, \"pool.free\").push(1);\n  \
             let conns = lock_or_die(&s.conns, \"server.conns\");\n  drop(conns);\n}\n",
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn cfg_test_modules_are_exempt() {
        let findings = run_on(
            "#[cfg(test)]\nmod tests {\n  fn t(p: &Pool) { let g = p.free.lock().unwrap(); drop(g); }\n}\n",
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn an_allow_annotation_suppresses_an_order_finding() {
        let findings = run_on(
            "fn f(p: &Pool, s: &Srv) {\n  let free = lock_or_die(&p.free, \"pool.free\");\n  \
             // dynalint: allow(lock-order, provably unreachable concurrently)\n  \
             let conns = lock_or_die(&s.conns, \"server.conns\");\n  drop(conns); drop(free);\n}\n",
        );
        assert!(findings.is_empty(), "{findings:?}");
    }
}
