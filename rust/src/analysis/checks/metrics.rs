//! Check 5 — metric-catalog discipline.
//!
//! Every series registered through the obs macros (`obs_counter!`,
//! `obs_gauge!`, `obs_histogram!` — the list comes from `[metrics]` in the
//! manifest) must:
//!
//! 1. pass its name as a **string literal**, so the catalog is statically
//!    enumerable;
//! 2. be registered at exactly **one lexical call site** — multi-instance
//!    series share a site (a constructor or closure) and disambiguate via
//!    labels, never by re-registering the name elsewhere;
//! 3. carry the namespace **prefix** (`dynacomm_`);
//! 4. appear verbatim on the **catalog page** (docs/OBSERVABILITY.md), so
//!    dashboards and runbooks can trust the doc to be exhaustive.
//!
//! Macro *definition* sites (`macro_rules! obs_counter { ... }`) do not
//! match the `name!(` usage pattern and are naturally skipped, as is
//! anything inside `#[cfg(test)]`.
//!
//! The **span taxonomy** rides along as the check's second half: every
//! string entry in the `span_table` const (`SPAN_NAMES` in
//! `obs::trace`) must be globally unique and documented backtick-quoted
//! on the same catalog page, so trace viewers and the critical-path
//! report always resolve to a documented hop name. Only the const's
//! *definition* site matches (`SPAN_NAMES:` — ident followed by a type
//! colon); usage sites (`SPAN_NAMES.get(..)`) do not.

use std::collections::BTreeMap;
use std::path::Path;

use super::super::lexer::TokKind;
use super::super::manifest::Manifest;
use super::super::report::Finding;
use super::super::source::SrcFile;

pub fn check(root: &Path, files: &[SrcFile], manifest: &Manifest) -> Vec<Finding> {
    match std::fs::read_to_string(root.join(&manifest.metrics.doc)) {
        Ok(doc_text) => {
            let mut out = check_files(files, &doc_text, manifest);
            out.extend(check_spans(files, &doc_text, manifest));
            out
        }
        Err(_) => vec![Finding::new(
            "metrics",
            &manifest.metrics.doc,
            0,
            "metric catalog page is missing — every obs series must be \
             documented there"
                .to_string(),
        )],
    }
}

/// Core pass over already-lexed files, with the catalog page supplied as
/// text so fixture tests can pin their own synthetic doc.
pub fn check_files(
    files: &[SrcFile],
    doc_text: &str,
    manifest: &Manifest,
) -> Vec<Finding> {
    let mut out = Vec::new();
    // name -> first registration site, for duplicate reporting.
    let mut seen: BTreeMap<String, (String, u32)> = BTreeMap::new();
    for file in files {
        let code = &file.code;
        if code.len() < 4 {
            continue;
        }
        for i in 0..code.len() - 3 {
            let is_obs_macro = code[i].kind == TokKind::Ident
                && manifest.metrics.macros.iter().any(|m| m == &code[i].text);
            if !is_obs_macro
                || !code[i + 1].is_punct('!')
                || !code[i + 2].is_punct('(')
                || file.in_test(i)
            {
                continue;
            }
            let name_tok = &code[i + 3];
            if name_tok.kind != TokKind::Str {
                out.push(Finding::new(
                    "metrics",
                    &file.path,
                    code[i].line,
                    format!(
                        "`{}!` called with a non-literal series name — names \
                         must be string literals so the catalog stays \
                         statically checkable",
                        code[i].text
                    ),
                ));
                continue;
            }
            let name = name_tok.text.clone();
            if let Some((first_file, first_line)) = seen.get(&name) {
                out.push(Finding::new(
                    "metrics",
                    &file.path,
                    name_tok.line,
                    format!(
                        "series `{name}` registered twice (first at \
                         {first_file}:{first_line}) — multi-instance series \
                         must share one lexical call site and disambiguate \
                         via labels"
                    ),
                ));
                continue;
            }
            seen.insert(name.clone(), (file.path.clone(), name_tok.line));
            if !name.starts_with(&manifest.metrics.prefix) {
                out.push(Finding::new(
                    "metrics",
                    &file.path,
                    name_tok.line,
                    format!(
                        "series `{name}` lacks the `{}` namespace prefix",
                        manifest.metrics.prefix
                    ),
                ));
            }
            if !doc_text.contains(&name) {
                out.push(Finding::new(
                    "metrics",
                    &file.path,
                    name_tok.line,
                    format!(
                        "series `{name}` is not documented in {}",
                        manifest.metrics.doc
                    ),
                ));
            }
        }
    }
    out
}

/// Span-taxonomy half of the check: collect every string entry of the
/// manifest's `span_table` const across all files, then enforce global
/// uniqueness and backtick-quoted documentation on the catalog page.
/// Public (like [`check_files`]) so fixture tests can pin their own doc.
pub fn check_spans(
    files: &[SrcFile],
    doc_text: &str,
    manifest: &Manifest,
) -> Vec<Finding> {
    let mut out = Vec::new();
    // span name -> first declaration site, for duplicate reporting.
    let mut seen: BTreeMap<String, (String, u32)> = BTreeMap::new();
    for file in files {
        let code = &file.code;
        for i in 0..code.len().saturating_sub(1) {
            // Definition site only: `SPAN_NAMES` followed by the type
            // colon. Usage sites (`SPAN_NAMES.get`, `SPAN_NAMES.len()`)
            // have `.` or `;` next and fall through.
            if code[i].kind != TokKind::Ident
                || code[i].text != manifest.metrics.span_table
                || !code[i + 1].is_punct(':')
            {
                continue;
            }
            // Collect the string entries up to the terminating `;`.
            let mut j = i + 2;
            while j < code.len() && !code[j].is_punct(';') {
                if code[j].kind == TokKind::Str {
                    let name = code[j].text.clone();
                    if let Some((first_file, first_line)) = seen.get(&name) {
                        out.push(Finding::new(
                            "metrics",
                            &file.path,
                            code[j].line,
                            format!(
                                "span `{name}` declared twice (first at \
                                 {first_file}:{first_line}) — span names \
                                 must be globally unique so trace and \
                                 critical-path rows are unambiguous"
                            ),
                        ));
                    } else {
                        seen.insert(name.clone(), (file.path.clone(), code[j].line));
                        if !doc_text.contains(&format!("`{name}`")) {
                            out.push(Finding::new(
                                "metrics",
                                &file.path,
                                code[j].line,
                                format!(
                                    "span `{name}` is not documented in {} — \
                                     add it to the span taxonomy table",
                                    manifest.metrics.doc
                                ),
                            ));
                        }
                    }
                }
                j += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::from_text(include_str!("../dynalint.toml")).unwrap()
    }

    fn parse(src: &str) -> SrcFile {
        SrcFile::parse("fixture.rs", src.to_string())
    }

    #[test]
    fn good_fixture_is_clean() {
        let files = vec![parse(include_str!("../tests/metrics_good.rs"))];
        let doc = "dynacomm_fixture_hits_total dynacomm_fixture_depth \
                   dynacomm_fixture_latency_ms";
        let findings = check_files(&files, doc, &manifest());
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn bad_fixture_seeds_exactly_the_three_violations() {
        let files = vec![parse(include_str!("../tests/metrics_bad.rs"))];
        // The prefix-violating name IS documented so it trips only the
        // prefix rule, and the duplicated name is documented and prefixed
        // so it trips only the duplicate rule: exactly one finding each.
        let doc = "dynacomm_fixture_hits_total fixture_depth";
        let findings = check_files(&files, doc, &manifest());
        assert_eq!(findings.len(), 3, "{findings:?}");
        assert!(findings[0].message.contains("registered twice"));
        assert!(findings[1].message.contains("namespace prefix"));
        assert!(findings[2].message.contains("not documented"));
        for f in &findings {
            assert_eq!(f.check, "metrics");
            assert!(f.line > 0, "findings carry source positions: {f:?}");
        }
    }

    #[test]
    fn non_literal_names_are_flagged_and_test_code_is_skipped() {
        let src = "fn f() { let _ = obs_counter!(NAME_CONST); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { let _ = obs_counter!(\"zzz_unprefixed\"); }\n\
                   }\n";
        let findings = check_files(&[parse(src)], "", &manifest());
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("non-literal"));
    }

    #[test]
    fn span_taxonomy_good_fixture_is_clean() {
        let files = vec![parse(include_str!("../tests/spans_good.rs"))];
        let doc = "| `fixture-iteration` | `fixture-push` | `fixture-apply` |";
        let findings = check_spans(&files, doc, &manifest());
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn span_taxonomy_bad_fixture_seeds_duplicate_and_undocumented() {
        let files = vec![parse(include_str!("../tests/spans_bad.rs"))];
        let doc = "`fixture-iteration`";
        let findings = check_spans(&files, doc, &manifest());
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(findings[0].message.contains("declared twice"));
        assert!(findings[1].message.contains("not documented"));
        for f in &findings {
            assert_eq!(f.check, "metrics");
            assert!(f.line > 0, "findings carry source positions: {f:?}");
        }
    }

    #[test]
    fn span_doc_match_requires_backticks() {
        // A bare substring match is not documentation: short span names
        // ("apply", "loss") would collide with ordinary prose.
        let files = vec![parse(include_str!("../tests/spans_good.rs"))];
        let doc = "fixture-iteration fixture-push fixture-apply";
        let findings = check_spans(&files, doc, &manifest());
        assert_eq!(findings.len(), 3, "{findings:?}");
    }

    #[test]
    fn macro_definition_sites_do_not_match() {
        let src = "macro_rules! obs_counter {\n\
                       ($name:literal) => { register($name) };\n\
                   }\n";
        let findings = check_files(&[parse(src)], "", &manifest());
        assert!(findings.is_empty(), "{findings:?}");
    }
}
