//! Check 1 — hot-path allocation lint.
//!
//! A function annotated `// dynalint: hot-path` sits on the per-iteration
//! wire path, where the zero-alloc steady state (pooled slabs, reused
//! scratch buffers) is a measured property the benches depend on. Inside
//! such a function every pattern in the manifest `[alloc] banned` list is
//! a finding unless the line (or the line above) carries
//! `// dynalint: allow(alloc, reason)`.
//!
//! The match is lexical over code tokens: `A::B` path calls, `.m` method
//! calls (requiring a following `(` or turbofish `::`), and `m!` macros.
//! Nested items inside a hot function are scanned too — a conservative
//! over-approximation; hoist genuinely cold helpers out of hot functions.

use super::super::manifest::Manifest;
use super::super::report::Finding;
use super::super::source::{find_fn_bodies, SrcFile};

enum Needle {
    Path(String, String),
    Method(String),
    Macro(String),
}

impl Needle {
    fn parse(pattern: &str) -> Option<Needle> {
        if let Some((a, b)) = pattern.split_once("::") {
            return Some(Needle::Path(a.to_string(), b.to_string()));
        }
        if let Some(m) = pattern.strip_prefix('.') {
            return Some(Needle::Method(m.to_string()));
        }
        if let Some(m) = pattern.strip_suffix('!') {
            return Some(Needle::Macro(m.to_string()));
        }
        None
    }

    fn display(&self) -> String {
        match self {
            Needle::Path(a, b) => format!("{a}::{b}"),
            Needle::Method(m) => format!(".{m}()"),
            Needle::Macro(m) => format!("{m}!"),
        }
    }
}

pub fn check(files: &[SrcFile], manifest: &Manifest) -> Vec<Finding> {
    let needles: Vec<Needle> =
        manifest.banned.iter().filter_map(|p| Needle::parse(p)).collect();
    let mut out = Vec::new();
    for file in files {
        if file.directives.hot_path.is_empty() {
            continue;
        }
        let bodies = find_fn_bodies(&file.code);
        for &hot_line in &file.directives.hot_path {
            // The annotation attaches to the next `fn` at or below it.
            let target = bodies
                .iter()
                .filter(|b| file.code[b.fn_idx].line >= hot_line)
                .min_by_key(|b| file.code[b.fn_idx].line);
            let Some(body) = target else {
                out.push(Finding::new(
                    "alloc",
                    &file.path,
                    hot_line,
                    "dangling `dynalint: hot-path` annotation: no fn follows it"
                        .to_string(),
                ));
                continue;
            };
            scan_body(file, body.open, body.close, &body.name, &needles, &mut out);
        }
    }
    out
}

fn scan_body(
    file: &SrcFile,
    open: usize,
    close: usize,
    fn_name: &str,
    needles: &[Needle],
    out: &mut Vec<Finding>,
) {
    let code = &file.code;
    for j in open..=close {
        for needle in needles {
            let hit_line = match needle {
                Needle::Path(a, b) => {
                    if code[j].is_ident(a)
                        && j + 3 <= close
                        && code[j + 1].is_punct(':')
                        && code[j + 2].is_punct(':')
                        && code[j + 3].is_ident(b)
                    {
                        Some(code[j].line)
                    } else {
                        None
                    }
                }
                Needle::Method(m) => {
                    if code[j].is_punct('.')
                        && j + 2 <= close
                        && code[j + 1].is_ident(m)
                        && (code[j + 2].is_punct('(') || code[j + 2].is_punct(':'))
                    {
                        Some(code[j + 1].line)
                    } else {
                        None
                    }
                }
                Needle::Macro(m) => {
                    if code[j].is_ident(m) && j + 1 <= close && code[j + 1].is_punct('!')
                    {
                        Some(code[j].line)
                    } else {
                        None
                    }
                }
            };
            if let Some(line) = hit_line {
                if file.directives.allowed("alloc", line) {
                    continue;
                }
                out.push(Finding::new(
                    "alloc",
                    &file.path,
                    line,
                    format!(
                        "hot-path fn `{fn_name}` uses banned `{}` — hoist it off \
                         the hot path or justify with \
                         `// dynalint: allow(alloc, reason)`",
                        needle.display()
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::manifest::Manifest;
    use crate::analysis::source::SrcFile;

    fn manifest() -> Manifest {
        Manifest::from_text(include_str!("../dynalint.toml")).unwrap()
    }

    fn run_on(src: &str) -> Vec<Finding> {
        let file = SrcFile::parse("fixture.rs", src.to_string());
        check(&[file], &manifest())
    }

    #[test]
    fn bad_fixture_trips_each_pattern_shape() {
        let findings = run_on(include_str!("../tests/alloc_bad.rs"));
        assert_eq!(findings.len(), 3, "{findings:?}");
        let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
        assert!(rendered.iter().any(|r| r.contains(".clone()")), "{rendered:?}");
        assert!(rendered.iter().any(|r| r.contains("Vec::new")), "{rendered:?}");
        assert!(rendered.iter().any(|r| r.contains("format!")), "{rendered:?}");
        for f in &findings {
            assert_eq!(f.check, "alloc");
            assert!(f.line > 0);
            assert!(f.message.contains("hot_send"), "names the fn: {}", f.message);
        }
    }

    #[test]
    fn good_fixture_is_clean_including_the_allow() {
        let findings = run_on(include_str!("../tests/alloc_good.rs"));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn cold_functions_may_allocate() {
        let findings =
            run_on("fn cold() -> Vec<u8> { let v = Vec::new(); v.clone() }");
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn dangling_annotation_is_itself_a_finding() {
        let findings = run_on("fn a() {}\n// dynalint: hot-path\n");
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("dangling"));
    }

    #[test]
    fn pattern_strings_in_cold_code_do_not_match() {
        // The banned patterns appear here only inside a string literal.
        let findings = run_on(
            "// dynalint: hot-path\nfn hot() { let s = \"Vec::new .clone() format!\"; drop(s); }",
        );
        assert!(findings.is_empty(), "{findings:?}");
    }
}
