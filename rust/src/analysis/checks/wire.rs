//! Check 3 — wire-protocol invariants.
//!
//! The v4 frame vocabulary is pinned in the manifest (`[wire.frames]`)
//! and must agree everywhere it is spelled:
//!
//! - `MessageRef::opcode()` arms: unique tags, exactly the manifest table;
//! - `decode()` arms: one numeric arm per opcode plus a `_ => bail!(..)`
//!   wildcard, no arm for a tag the protocol does not define;
//! - `PROTOCOL_VERSION` equals the manifest `protocol_version`;
//! - `docs/WIRE.md` mentions the current version (`**v{N}**` in its
//!   version-history table) and every frame name;
//! - the fuzz generators (`tests/fuzz_substrates.rs`) reference
//!   `PROTOCOL_VERSION` so version drift breaks a test, not a worker.

use std::path::Path;

use super::super::manifest::Manifest;
use super::super::report::Finding;
use super::super::source::{find_fn_bodies, CodeTok, SrcFile};
use crate::analysis::lexer::TokKind;

pub fn check(root: &Path, files: &[SrcFile], manifest: &Manifest) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(transport) = files.iter().find(|f| f.path == manifest.wire.transport)
    else {
        out.push(Finding::new(
            "wire",
            &manifest.wire.transport,
            0,
            "transport source named in the manifest was not scanned".to_string(),
        ));
        return out;
    };
    check_transport(transport, manifest, &mut out);

    let doc_path = root.join(&manifest.wire.doc);
    match std::fs::read_to_string(&doc_path) {
        Ok(doc) => {
            let version = manifest.wire.protocol_version;
            if !doc.contains(&format!("**v{version}**")) {
                out.push(Finding::new(
                    "wire",
                    &manifest.wire.doc,
                    0,
                    format!(
                        "version-history table has no `**v{version}**` entry for \
                         the current PROTOCOL_VERSION"
                    ),
                ));
            }
            for (name, tag) in &manifest.wire.frames {
                if !doc.contains(name.as_str()) {
                    out.push(Finding::new(
                        "wire",
                        &manifest.wire.doc,
                        0,
                        format!("frame `{name}` (opcode {tag}) is not documented"),
                    ));
                }
            }
        }
        Err(_) => out.push(Finding::new(
            "wire",
            &manifest.wire.doc,
            0,
            "wire doc named in the manifest is missing".to_string(),
        )),
    }

    match std::fs::read_to_string(root.join(&manifest.wire.fuzz)) {
        Ok(fuzz) => {
            if !fuzz.contains("PROTOCOL_VERSION") {
                out.push(Finding::new(
                    "wire",
                    &manifest.wire.fuzz,
                    0,
                    "fuzz generators never reference PROTOCOL_VERSION — version \
                     drift would go unfuzzed"
                        .to_string(),
                ));
            }
        }
        Err(_) => out.push(Finding::new(
            "wire",
            &manifest.wire.fuzz,
            0,
            "fuzz substrate named in the manifest is missing".to_string(),
        )),
    }
    out
}

/// The transport-source portion of the check, separated so fixture tests
/// can drive it without a fake repo on disk.
pub fn check_transport(file: &SrcFile, manifest: &Manifest, out: &mut Vec<Finding>) {
    let code = &file.code;
    let bodies = find_fn_bodies(code);
    let mut opcode_arms: Vec<(String, u8, u32)> = Vec::new(); // (variant, tag, line)
    let mut decode_tags: Vec<(u8, u32)> = Vec::new();
    let mut wildcard_bails = false;
    for body in &bodies {
        if file.in_test(body.fn_idx) {
            continue;
        }
        if body.name == "opcode" {
            collect_opcode_arms(code, body.open, body.close, &mut opcode_arms);
        } else if body.name == "decode" {
            collect_decode_arms(
                code,
                body.open,
                body.close,
                &mut decode_tags,
                &mut wildcard_bails,
            );
        }
    }

    // Tag uniqueness in opcode().
    for (i, (variant, tag, line)) in opcode_arms.iter().enumerate() {
        if let Some((other, _, _)) =
            opcode_arms[..i].iter().find(|(_, t, _)| t == tag)
        {
            out.push(Finding::new(
                "wire",
                &file.path,
                *line,
                format!("frame tag {tag} assigned to both `{other}` and `{variant}`"),
            ));
        }
    }

    // opcode() arms ↔ manifest frame table, both directions.
    for (name, tag) in &manifest.wire.frames {
        match opcode_arms.iter().find(|(v, _, _)| v == name) {
            None => out.push(Finding::new(
                "wire",
                &file.path,
                0,
                format!("declared frame `{name}` (opcode {tag}) has no opcode() arm"),
            )),
            Some((_, code_tag, line)) if code_tag != tag => out.push(Finding::new(
                "wire",
                &file.path,
                *line,
                format!(
                    "frame `{name}`: opcode() says {code_tag}, manifest says {tag}"
                ),
            )),
            Some(_) => {}
        }
    }
    for (variant, tag, line) in &opcode_arms {
        if !manifest.wire.frames.iter().any(|(n, _)| n == variant) {
            out.push(Finding::new(
                "wire",
                &file.path,
                *line,
                format!(
                    "opcode() arm `{variant}` => {tag} is not in the manifest \
                     frame table — declare it (and document it) or remove it"
                ),
            ));
        }
    }

    // decode() coverage: every defined tag, nothing undefined, a bail arm.
    if opcode_arms.is_empty() {
        out.push(Finding::new(
            "wire",
            &file.path,
            0,
            "no opcode() arms found — the wire check cannot see the frame table"
                .to_string(),
        ));
        return;
    }
    let mut defined: Vec<u8> = opcode_arms.iter().map(|(_, t, _)| *t).collect();
    defined.sort_unstable();
    defined.dedup();
    for tag in &defined {
        if !decode_tags.iter().any(|(t, _)| t == tag) {
            let name = manifest
                .wire
                .frames
                .iter()
                .find(|(_, t)| t == tag)
                .map(|(n, _)| n.as_str())
                .unwrap_or("?");
            out.push(Finding::new(
                "wire",
                &file.path,
                0,
                format!("decode() has no arm for tag {tag} (`{name}`)"),
            ));
        }
    }
    for (tag, line) in &decode_tags {
        if !defined.contains(tag) {
            out.push(Finding::new(
                "wire",
                &file.path,
                *line,
                format!("decode() arm for tag {tag} which opcode() never produces"),
            ));
        }
    }
    if !decode_tags.is_empty() && !wildcard_bails {
        out.push(Finding::new(
            "wire",
            &file.path,
            0,
            "decode() has no `_ => bail!(..)` wildcard — unknown opcodes must \
             error, not fall through"
                .to_string(),
        ));
    }

    // PROTOCOL_VERSION const.
    match protocol_version_const(code) {
        Some((version, line)) if version != manifest.wire.protocol_version => {
            out.push(Finding::new(
                "wire",
                &file.path,
                line,
                format!(
                    "PROTOCOL_VERSION is {version} but the manifest pins {} — \
                     bump both (and docs/WIRE.md) together",
                    manifest.wire.protocol_version
                ),
            ));
        }
        Some(_) => {}
        None => out.push(Finding::new(
            "wire",
            &file.path,
            0,
            "no `PROTOCOL_VERSION: u16 = N` const found".to_string(),
        )),
    }
}

/// `MessageRef::Variant { .. } => N` arms inside an `opcode()` body.
fn collect_opcode_arms(
    code: &[CodeTok],
    open: usize,
    close: usize,
    out: &mut Vec<(String, u8, u32)>,
) {
    for j in open..close.saturating_sub(2) {
        if !(code[j].is_punct('=') && code[j + 1].is_punct('>')) {
            continue;
        }
        let num = &code[j + 2];
        if num.kind != TokKind::Num {
            continue;
        }
        let Ok(tag) = num.text.parse::<u8>() else { continue };
        // Walk back over the arm pattern for `MessageRef::Variant`.
        let mut k = j;
        let mut variant: Option<String> = None;
        while k > open {
            k -= 1;
            let t = &code[k];
            if t.is_punct(',') || (t.is_punct('{') && k == open) {
                break;
            }
            if t.kind == TokKind::Ident
                && k >= 3
                && code[k - 1].is_punct(':')
                && code[k - 2].is_punct(':')
                && code[k - 3].is_ident("MessageRef")
            {
                variant = Some(t.text.clone());
                break;
            }
        }
        if let Some(variant) = variant {
            out.push((variant, tag, num.line));
        }
    }
}

/// `N => …` arms (and the `_ => bail!` wildcard) inside a `decode()` body.
fn collect_decode_arms(
    code: &[CodeTok],
    open: usize,
    close: usize,
    out: &mut Vec<(u8, u32)>,
    wildcard_bails: &mut bool,
) {
    let mut has_wildcard = false;
    let mut has_bail = false;
    for j in open..close.saturating_sub(2) {
        if code[j].kind == TokKind::Num
            && code[j + 1].is_punct('=')
            && code[j + 2].is_punct('>')
        {
            if let Ok(tag) = code[j].text.parse::<u8>() {
                out.push((tag, code[j].line));
            }
        }
        if code[j].is_ident("_")
            && code[j + 1].is_punct('=')
            && code[j + 2].is_punct('>')
        {
            has_wildcard = true;
        }
        if code[j].is_ident("bail") {
            has_bail = true;
        }
    }
    if has_wildcard && has_bail {
        *wildcard_bails = true;
    }
}

/// The `pub const PROTOCOL_VERSION: u16 = N;` value and its line.
fn protocol_version_const(code: &[CodeTok]) -> Option<(u16, u32)> {
    for j in 0..code.len().saturating_sub(4) {
        if code[j].is_ident("PROTOCOL_VERSION")
            && code[j + 1].is_punct(':')
            && code[j + 2].is_ident("u16")
            && code[j + 3].is_punct('=')
            && code[j + 4].kind == TokKind::Num
        {
            if let Ok(v) = code[j + 4].text.parse::<u16>() {
                return Some((v, code[j + 4].line));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::manifest::Manifest;
    use crate::analysis::source::SrcFile;

    /// A three-frame manifest matching the wire fixtures.
    fn fixture_manifest() -> Manifest {
        let text = include_str!("../dynalint.toml")
            .lines()
            .filter(|l| {
                // Drop the full v7 table; re-pin a minimal one below.
                let in_frames = [
                    "PullReply", "PushAck", "Hello", "HelloAck", "Codec", "Sync",
                    "Agg", "Snapshot", "Clock",
                ]
                .iter()
                .any(|p| l.starts_with(p));
                !in_frames
            })
            .collect::<Vec<_>>()
            .join("\n");
        Manifest::from_text(&text).unwrap()
    }

    fn run_transport(src: &str) -> Vec<Finding> {
        let file = SrcFile::parse("fixture.rs", src.to_string());
        let mut out = Vec::new();
        check_transport(&file, &fixture_manifest(), &mut out);
        out
    }

    #[test]
    fn fixture_manifest_pins_exactly_the_fixture_frames() {
        let m = fixture_manifest();
        let names: Vec<&str> =
            m.wire.frames.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["Pull", "Push", "Shutdown"]);
    }

    #[test]
    fn bad_fixture_trips_duplicate_mismatch_coverage_and_version() {
        let findings = run_transport(include_str!("../tests/wire_bad.rs"));
        let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
        assert_eq!(findings.len(), 4, "{rendered:?}");
        assert!(rendered.iter().any(|r| r.contains("assigned to both")), "{rendered:?}");
        assert!(
            rendered.iter().any(|r| r.contains("opcode() says 1, manifest says 3")),
            "{rendered:?}"
        );
        assert!(
            rendered.iter().any(|r| r.contains("no arm for tag 7")),
            "{rendered:?}"
        );
        assert!(
            rendered.iter().any(|r| r.contains("PROTOCOL_VERSION is 3")),
            "{rendered:?}"
        );
    }

    #[test]
    fn good_fixture_is_clean() {
        let findings = run_transport(include_str!("../tests/wire_good.rs"));
        assert!(findings.is_empty(), "{findings:?}");
    }

    /// A frame with opcode and decoder arms but no manifest entry — the
    /// drift a half-landed protocol bump (like the v5 `AggHello`) leaves
    /// behind — is exactly one missing-manifest-entry finding.
    #[test]
    fn undeclared_frame_is_a_missing_manifest_entry() {
        let findings = run_transport(include_str!("../tests/wire_bad_agghello.rs"));
        let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
        assert_eq!(findings.len(), 1, "{rendered:?}");
        assert!(
            rendered[0].contains("`AggHello` => 12 is not in the manifest frame table"),
            "{rendered:?}"
        );
    }

    /// Same drift for the v6 fault-tolerance frames: a `SnapshotReq`
    /// with opcode and decoder arms but no manifest entry is exactly one
    /// missing-manifest-entry finding.
    #[test]
    fn undeclared_snapshot_frame_is_a_missing_manifest_entry() {
        let findings = run_transport(include_str!("../tests/wire_bad_snapshot.rs"));
        let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
        assert_eq!(findings.len(), 1, "{rendered:?}");
        assert!(
            rendered[0]
                .contains("`SnapshotReq` => 13 is not in the manifest frame table"),
            "{rendered:?}"
        );
    }

    #[test]
    fn a_missing_wildcard_is_a_finding() {
        let src = include_str!("../tests/wire_good.rs")
            .replace("_ => bail!(\"unknown opcode {op}\"),", "");
        let findings = run_transport(&src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("wildcard"));
    }

    #[test]
    fn the_real_tree_satisfies_the_committed_manifest() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let manifest =
            Manifest::from_text(include_str!("../dynalint.toml")).unwrap();
        let path = root.join(&manifest.wire.transport);
        let text = std::fs::read_to_string(&path).unwrap();
        let file = SrcFile::parse(&manifest.wire.transport, text);
        let findings = check(root, &[file], &manifest);
        assert!(findings.is_empty(), "{findings:?}");
    }
}
