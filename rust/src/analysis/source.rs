//! Per-file source model: lexed code tokens, dynalint directives pulled
//! from comments, `#[cfg(test)]` spans, and structural helpers (function
//! bodies, loop bodies, brace matching) shared by all checks.

use super::lexer::{self, TokKind, Token};

/// A non-comment token. Checks pattern-match over these, so comment
/// placement can never perturb a match; comments are distilled into
/// [`Directives`] instead.
#[derive(Debug, Clone)]
pub struct CodeTok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl CodeTok {
    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text == name
    }

    pub fn is_punct(&self, ch: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(ch)
    }
}

/// dynalint annotations extracted from comments.
///
/// Grammar (anywhere in a `//` comment's own line):
/// - `dynalint: hot-path` — the next `fn` is allocation-checked.
/// - `dynalint: allow(<kind>, <reason>)` — suppress a `<kind>` finding on
///   this line or the line directly below.
#[derive(Debug, Default)]
pub struct Directives {
    /// Lines bearing a `hot-path` annotation.
    pub hot_path: Vec<u32>,
    /// `(line, kind)` of each `allow(kind, reason)` annotation.
    pub allows: Vec<(u32, String)>,
    /// `(line, text)` of comments that look like directives but parse as
    /// neither form — surfaced as findings so typos cannot silently
    /// disable a check.
    pub malformed: Vec<(u32, String)>,
}

impl Directives {
    /// Is a `kind` finding at `line` covered by an allow on the same line
    /// or the line above?
    pub fn allowed(&self, kind: &str, line: u32) -> bool {
        self.allows
            .iter()
            .any(|(l, k)| k == kind && (*l == line || *l + 1 == line))
    }
}

/// One lexed source file plus its precomputed structure.
pub struct SrcFile {
    /// Repo-root-relative path, forward slashes.
    pub path: String,
    pub text: String,
    pub code: Vec<CodeTok>,
    pub directives: Directives,
    /// Code-token index ranges `[open, close]` of `#[cfg(test)] mod` bodies.
    pub test_spans: Vec<(usize, usize)>,
}

impl SrcFile {
    pub fn parse(path: &str, text: String) -> SrcFile {
        let tokens = lexer::lex(&text);
        let directives = extract_directives(&tokens);
        let code: Vec<CodeTok> = tokens
            .into_iter()
            .filter(|t| t.kind != TokKind::Comment)
            .map(|t| CodeTok { kind: t.kind, text: t.text, line: t.line })
            .collect();
        let test_spans = find_cfg_test_spans(&code);
        SrcFile { path: path.to_string(), text, code, directives, test_spans }
    }

    /// Is the code token at `idx` inside a `#[cfg(test)] mod` body?
    pub fn in_test(&self, idx: usize) -> bool {
        self.test_spans.iter().any(|&(open, close)| idx >= open && idx <= close)
    }
}

fn extract_directives(tokens: &[Token]) -> Directives {
    let mut out = Directives::default();
    for t in tokens {
        if t.kind != TokKind::Comment {
            continue;
        }
        let Some(rest) = t.text.trim().strip_prefix("dynalint:") else {
            continue;
        };
        let rest = rest.trim();
        if rest == "hot-path" {
            out.hot_path.push(t.line);
        } else if let Some(args) =
            rest.strip_prefix("allow(").and_then(|s| s.strip_suffix(')'))
        {
            let kind = args.split(',').next().unwrap_or("").trim();
            let has_reason =
                args.split_once(',').map(|(_, r)| !r.trim().is_empty()).unwrap_or(false);
            if kind.is_empty() || !has_reason {
                out.malformed.push((t.line, t.text.trim().to_string()));
            } else {
                out.allows.push((t.line, kind.to_string()));
            }
        } else {
            out.malformed.push((t.line, t.text.trim().to_string()));
        }
    }
    out
}

/// Find `#[cfg(test)] mod name { … }` spans over code tokens.
fn find_cfg_test_spans(code: &[CodeTok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 6 < code.len() {
        let is_attr = code[i].is_punct('#')
            && code[i + 1].is_punct('[')
            && code[i + 2].is_ident("cfg")
            && code[i + 3].is_punct('(')
            && code[i + 4].is_ident("test")
            && code[i + 5].is_punct(')')
            && code[i + 6].is_punct(']');
        if !is_attr {
            i += 1;
            continue;
        }
        // Look a short distance past the attribute for `mod name {`;
        // `#[cfg(test)]` on functions or `mod x;` declarations is skipped.
        let mut j = i + 7;
        let limit = (i + 16).min(code.len());
        while j < limit && !code[j].is_ident("mod") {
            j += 1;
        }
        if j + 2 < code.len()
            && code[j].is_ident("mod")
            && code[j + 1].kind == TokKind::Ident
            && code[j + 2].is_punct('{')
        {
            if let Some(close) = match_brace(code, j + 2) {
                spans.push((j + 2, close));
                i = close + 1;
                continue;
            }
        }
        i += 7;
    }
    spans
}

/// Index of the `}` matching the `{` at `open`, or `None` if unbalanced.
pub fn match_brace(code: &[CodeTok], open: usize) -> Option<usize> {
    debug_assert!(code[open].is_punct('{'));
    let mut depth = 0i64;
    for (i, t) in code.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// A named `fn` with a body.
#[derive(Debug, Clone)]
pub struct FnBody {
    pub name: String,
    /// Code-token index of the `fn` keyword.
    pub fn_idx: usize,
    /// Code-token indices of the body `{` and its matching `}`.
    pub open: usize,
    pub close: usize,
}

/// Every named function with a body, in source order. Bodyless trait
/// methods and `fn(...)` pointer types are skipped.
pub fn find_fn_bodies(code: &[CodeTok]) -> Vec<FnBody> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if !code[i].is_ident("fn") {
            i += 1;
            continue;
        }
        let Some(name_tok) = code.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            i += 1; // `fn(usize) -> T` pointer type
            continue;
        }
        let name = name_tok.text.clone();
        // Scan past generics/params/return type for the body `{` (or `;`
        // for a bodyless signature) at paren/bracket depth zero.
        let mut j = i + 2;
        let mut depth = 0i64;
        let mut found: Option<usize> = None;
        while j < code.len() {
            let t = &code[j];
            if t.is_punct('(') || t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                depth -= 1;
            } else if depth == 0 && t.is_punct(';') {
                break;
            } else if depth == 0 && t.is_punct('{') {
                found = Some(j);
                break;
            }
            j += 1;
        }
        match found.and_then(|open| match_brace(code, open).map(|close| (open, close)))
        {
            Some((open, close)) => {
                out.push(FnBody { name, fn_idx: i, open, close });
                i += 2; // nested fns are discovered by the linear scan
            }
            None => i = j.max(i + 2),
        }
    }
    out
}

/// Code-token spans `[open, close]` of every `while`/`loop` body —
/// the predicate re-check regions a condvar wait must sit inside.
pub fn find_loop_spans(code: &[CodeTok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for i in 0..code.len() {
        let t = &code[i];
        if !(t.is_ident("while") || t.is_ident("loop")) {
            continue;
        }
        // Find the body `{` at paren depth 0; a `while` condition may
        // contain call parens, a `loop` is followed by its brace directly.
        let mut depth = 0i64;
        let mut j = i + 1;
        let mut open: Option<usize> = None;
        while j < code.len() && j <= i + 256 {
            let u = &code[j];
            if u.is_punct('(') || u.is_punct('[') {
                depth += 1;
            } else if u.is_punct(')') || u.is_punct(']') {
                depth -= 1;
            } else if depth == 0 && u.is_punct('{') {
                open = Some(j);
                break;
            } else if depth == 0 && u.is_punct(';') {
                break;
            }
            j += 1;
        }
        if let Some(open) = open {
            if let Some(close) = match_brace(code, open) {
                spans.push((open, close));
            }
        }
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SrcFile {
        SrcFile::parse("test.rs", src.to_string())
    }

    #[test]
    fn directives_parse_and_reject_typos() {
        let f = parse(
            "// dynalint: hot-path\nfn a() {}\n\
             // dynalint: allow(alloc, refcount bump only)\nlet x = 1;\n\
             // dynalint: allow(alloc)\n// dynalint: hotpath\n",
        );
        assert_eq!(f.directives.hot_path, vec![1]);
        assert_eq!(f.directives.allows, vec![(3, "alloc".to_string())]);
        assert_eq!(f.directives.malformed.len(), 2, "missing reason + typo flagged");
        assert!(f.directives.allowed("alloc", 4), "line below the comment");
        assert!(!f.directives.allowed("alloc", 6));
        assert!(!f.directives.allowed("lock-order", 4), "kind-scoped");
    }

    #[test]
    fn cfg_test_mod_spans_cover_their_bodies() {
        let f = parse(
            "fn live() { x.lock(); }\n\
             #[cfg(test)]\nmod tests {\n    fn t() { y.lock(); }\n}\nfn after() {}\n",
        );
        assert_eq!(f.test_spans.len(), 1);
        let lock_sites: Vec<usize> = f
            .code
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_ident("lock"))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(lock_sites.len(), 2);
        assert!(!f.in_test(lock_sites[0]), "live code outside the span");
        assert!(f.in_test(lock_sites[1]), "test code inside the span");
        let after = f.code.iter().position(|t| t.is_ident("after")).unwrap();
        assert!(!f.in_test(after));
    }

    #[test]
    fn fn_bodies_skip_signatures_and_pointer_types() {
        let f = parse(
            "trait T { fn sig(&self) -> u8; }\n\
             struct S { build: fn(usize) -> usize }\n\
             fn real<A>(xs: &[A]) -> usize { xs.len() }\n",
        );
        let bodies = find_fn_bodies(&f.code);
        assert_eq!(bodies.len(), 1);
        assert_eq!(bodies[0].name, "real");
        assert!(f.code[bodies[0].open].is_punct('{'));
        assert!(f.code[bodies[0].close].is_punct('}'));
    }

    #[test]
    fn loop_spans_cover_while_and_loop_bodies() {
        let f = parse(
            "fn f() {\n  while a.b(c) < d { wait(); }\n  loop { wait(); break; }\n  wait();\n}\n",
        );
        let spans = find_loop_spans(&f.code);
        assert_eq!(spans.len(), 2);
        let waits: Vec<usize> = f
            .code
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_ident("wait"))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(waits.len(), 3);
        let inside = |idx: usize| spans.iter().any(|&(o, c)| idx > o && idx < c);
        assert!(inside(waits[0]) && inside(waits[1]));
        assert!(!inside(waits[2]), "the bare wait is outside every loop body");
    }
}
