//! `dynalint` — the in-repo static-analysis pass.
//!
//! Five checks over `rust/`, driven by the declarative manifest at
//! `rust/src/analysis/dynalint.toml` (see `docs/ANALYSIS.md`):
//!
//! 1. **alloc** — `// dynalint: hot-path` functions stay allocation-free;
//! 2. **locks** — lock/condvar discipline: poisoning policy, predicate
//!    re-check loops, and a declared lock partial order;
//! 3. **wire** — the frame table, decoder coverage, `PROTOCOL_VERSION`,
//!    `docs/WIRE.md`, and the fuzz generators agree;
//! 4. **registry** — every sched/sync/codec registry entry is in `NAMES`,
//!    the CLI help banner, and its doc page;
//! 5. **metrics** — every obs series name is a unique, `dynacomm_`-prefixed
//!    string literal documented in `docs/OBSERVABILITY.md`.
//!
//! Everything is hand-rolled (lexer included) because the offline build
//! environment bans crates.io; the analyzer compiles into the library so
//! `cargo test` exercises it, and `cargo run --bin dynalint` gates CI.

pub mod checks;
pub mod lexer;
pub mod manifest;
pub mod report;
pub mod source;

use std::path::Path;

use anyhow::{Context, Result};

use manifest::Manifest;
use report::{Finding, Report};
use source::SrcFile;

/// Repo-relative path of the manifest.
pub const MANIFEST_PATH: &str = "rust/src/analysis/dynalint.toml";

/// Directories under the scan roots whose `.rs` files are deliberately
/// broken examples, not code: the analyzer's own fixture snippets.
const FIXTURE_DIR: &str = "rust/src/analysis/tests";

/// Source roots walked for `.rs` files, relative to the repo root.
const SCAN_ROOTS: [&str; 2] = ["rust/src", "rust/tests"];

/// Run all five checks over the tree rooted at `root` (the directory
/// holding `Cargo.toml`).
pub fn run(root: &Path) -> Result<Report> {
    let started = std::time::Instant::now();
    let manifest = Manifest::load(&root.join(MANIFEST_PATH))?;
    let files = load_sources(root)?;
    let mut findings: Vec<Finding> = Vec::new();
    for file in &files {
        for (line, text) in &file.directives.malformed {
            findings.push(Finding::new(
                "directive",
                &file.path,
                *line,
                format!(
                    "unrecognized dynalint directive `{text}` — expected \
                     `hot-path` or `allow(kind, reason)`"
                ),
            ));
        }
    }
    findings.extend(checks::alloc::check(&files, &manifest));
    findings.extend(checks::locks::check(&files, &manifest));
    findings.extend(checks::wire::check(root, &files, &manifest));
    findings.extend(checks::registry::check(root, &files, &manifest));
    findings.extend(checks::metrics::check(root, &files, &manifest));
    Ok(Report {
        findings,
        files_scanned: files.len(),
        checks_run: vec!["alloc", "locks", "wire", "registry", "metrics"],
        elapsed_ms: started.elapsed().as_secs_f64() * 1e3,
    })
}

/// Walk the scan roots and lex every `.rs` file, skipping the fixture
/// directory. Paths are repo-relative with forward slashes, sorted for
/// deterministic reports.
fn load_sources(root: &Path) -> Result<Vec<SrcFile>> {
    let mut paths: Vec<String> = Vec::new();
    for scan_root in SCAN_ROOTS {
        let dir = root.join(scan_root);
        if dir.is_dir() {
            collect_rs_files(&dir, scan_root, &mut paths)?;
        }
    }
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for rel in paths {
        let text = std::fs::read_to_string(root.join(&rel))
            .with_context(|| format!("reading {rel}"))?;
        files.push(SrcFile::parse(&rel, text));
    }
    Ok(files)
}

fn collect_rs_files(dir: &Path, rel: &str, out: &mut Vec<String>) -> Result<()> {
    if rel == FIXTURE_DIR {
        return Ok(());
    }
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("walking {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let child_rel = format!("{rel}/{name}");
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, &child_rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(child_rel);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The self-hosting gate: dynalint over the real tree is clean. Any
    /// new hot-path allocation, lock misuse, wire drift, or undocumented
    /// registry entry fails this test before it fails in CI.
    #[test]
    fn dynalint_is_clean_on_the_real_tree() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run(root).expect("dynalint runs");
        assert!(
            report.findings.is_empty(),
            "expected zero findings on the committed tree:\n{}",
            report.render_text()
        );
        assert!(
            report.files_scanned > 30,
            "walker saw the tree ({} files)",
            report.files_scanned
        );
        assert_eq!(report.checks_run.len(), 5);
    }

    #[test]
    fn the_walker_skips_the_fixture_directory() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = load_sources(root).unwrap();
        assert!(
            files.iter().all(|f| !f.path.starts_with(FIXTURE_DIR)),
            "fixtures are deliberately broken and must not be scanned"
        );
        assert!(files.iter().any(|f| f.path == "rust/src/net/transport.rs"));
        assert!(files.iter().any(|f| f.path == "rust/tests/fuzz_substrates.rs"));
    }

    /// Seeded violations end-to-end: running the checks over the bad
    /// fixtures (as if they were tree files) produces findings with
    /// `file:line` positions — the non-zero-exit path the CI gate relies
    /// on.
    #[test]
    fn seeded_fixture_violations_surface_with_positions() {
        let manifest =
            Manifest::from_text(include_str!("dynalint.toml")).unwrap();
        let files = vec![
            SrcFile::parse(
                "rust/src/analysis/tests/alloc_bad.rs",
                include_str!("tests/alloc_bad.rs").to_string(),
            ),
            SrcFile::parse(
                "rust/src/analysis/tests/locks_bad.rs",
                include_str!("tests/locks_bad.rs").to_string(),
            ),
        ];
        let mut findings = checks::alloc::check(&files, &manifest);
        findings.extend(checks::locks::check(&files, &manifest));
        assert_eq!(findings.len(), 6, "{findings:?}");
        for f in &findings {
            assert!(f.line > 0, "positioned: {f:?}");
            assert!(f.file.contains("_bad.rs"));
        }
    }
}
