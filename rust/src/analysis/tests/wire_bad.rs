// Fixture: four seeded wire violations against the test manifest
// (Pull = 1, Push = 3, Shutdown = 7, version 4): a duplicate frame tag,
// a tag diverging from the manifest, a decoder arm gap, and a stale
// PROTOCOL_VERSION. Never compiled — loaded via include_str! by tests.

pub const PROTOCOL_VERSION: u16 = 3;

impl MessageRef<'_> {
    pub fn opcode(&self) -> u8 {
        match self {
            MessageRef::Pull { .. } => 1,
            MessageRef::Push { .. } => 1,
            MessageRef::Shutdown => 7,
        }
    }

    pub fn decode(b: &[u8]) -> Result<MessageRef<'_>> {
        let op = b[0];
        Ok(match op {
            1 => MessageRef::Pull { iter: 0 },
            _ => bail!("unknown opcode {op}"),
        })
    }
}
