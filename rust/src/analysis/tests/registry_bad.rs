// Fixture: the HELP banner advertises only two of the three registry
// entries — `gamma` is the seeded gap. Never compiled — loaded via
// include_str! by the registry check's tests.

pub const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];

const HELP: &str = "\
usage: tool [options]
  --strategy S   alpha|beta (registry names)
";
