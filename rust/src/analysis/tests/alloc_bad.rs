// Fixture: a hot-path function with three banned allocation patterns
// (method call, path call, macro). Never compiled — loaded via
// include_str! by rust/src/analysis/checks/alloc.rs tests.

// dynalint: hot-path
fn hot_send(buf: &mut Vec<u8>) -> Vec<u8> {
    let copy = buf.clone();
    let mut staged = Vec::new();
    staged.extend_from_slice(&copy);
    let label = format!("{} bytes", staged.len());
    drop(label);
    staged
}
