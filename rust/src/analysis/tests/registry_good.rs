// Fixture: a registry whose NAMES all appear in its HELP banner. Never
// compiled — loaded via include_str! by the registry check's tests.

pub const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];

const HELP: &str = "\
usage: tool [options]
  --strategy S   alpha|beta|gamma (registry names)
";
