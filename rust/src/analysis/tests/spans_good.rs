//! Fixture: a well-formed span taxonomy. Every name is unique and the
//! paired doc snippet in the test quotes each one in backticks.

pub const SPAN_NAMES: &[&str] = &[
    "fixture-iteration",
    "fixture-push",
    "fixture-apply",
];

pub fn lookup(id: usize) -> &'static str {
    // Usage site: `SPAN_NAMES` followed by `.` must not re-trigger the
    // definition matcher.
    SPAN_NAMES.get(id).copied().unwrap_or("?")
}
