// Fixture: the disciplined twins of locks_bad.rs — acquisition in the
// declared order, helper-routed locking, and a predicate re-check loop
// around the wait. Never compiled — loaded via include_str! by tests.

fn ordered_nesting(p: &Pool, s: &Server) {
    let conns = lock_or_die(&s.conns, "server.conns");
    let free = lock_or_die(&p.free, "pool.free");
    drop(free);
    drop(conns);
}

fn guarded_wait(s: &Server) {
    let mut entries = lock_or_die(&s.entries, "reply_cache.entries");
    while entries.building() {
        entries = wait_or_die(&s.ready, entries, "reply_cache.entries");
    }
    drop(entries);
}

fn scoped_then_reacquire(p: &Pool) {
    {
        let free = lock_or_die(&p.free, "pool.free");
        drop(free);
    }
    let free = lock_or_die(&p.free, "pool.free");
    drop(free);
}
