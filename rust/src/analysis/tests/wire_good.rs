// Fixture: a consistent three-frame protocol matching the test manifest
// (Pull = 1, Push = 3, Shutdown = 7, version 6) — unique tags, full
// decoder coverage with a bail wildcard, aligned PROTOCOL_VERSION.
// Never compiled — loaded via include_str! by tests.

pub const PROTOCOL_VERSION: u16 = 7;

impl MessageRef<'_> {
    pub fn opcode(&self) -> u8 {
        match self {
            MessageRef::Pull { .. } => 1,
            MessageRef::Push { .. } => 3,
            MessageRef::Shutdown => 7,
        }
    }

    pub fn decode(b: &[u8]) -> Result<MessageRef<'_>> {
        let op = b[0];
        Ok(match op {
            1 => MessageRef::Pull { iter: 0 },
            3 => MessageRef::Push { iter: 0 },
            7 => MessageRef::Shutdown,
            _ => bail!("unknown opcode {op}"),
        })
    }
}
