// Fixture: a hot-path function that stays on pooled buffers, with one
// justified allow; cold functions below it may allocate freely. Never
// compiled — loaded via include_str! by the alloc check's tests.

// dynalint: hot-path
fn hot_send(buf: &[u8], scratch: &mut Vec<u8>, slab: &Arc<PooledSlab>) -> usize {
    scratch.clear();
    scratch.extend_from_slice(buf);
    // dynalint: allow(alloc, Arc refcount bump only — shares the pooled slab)
    let shared = slab.clone();
    shared.len() + scratch.len()
}

fn cold_rebuild(parts: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::new();
    for p in parts {
        out.extend_from_slice(p);
    }
    out
}
