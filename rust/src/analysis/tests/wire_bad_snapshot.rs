// Fixture: the wire_good protocol plus an undeclared fault-tolerance
// frame — `SnapshotReq` has opcode and decoder arms (tag 13, full
// coverage, aligned version) but no entry in the test manifest's frame
// table, the exact drift a half-landed v6 bump leaves behind. Exactly
// one finding: the missing-manifest-entry report for `SnapshotReq`.
// Never compiled — loaded via include_str! by tests.

pub const PROTOCOL_VERSION: u16 = 7;

impl MessageRef<'_> {
    pub fn opcode(&self) -> u8 {
        match self {
            MessageRef::Pull { .. } => 1,
            MessageRef::Push { .. } => 3,
            MessageRef::Shutdown => 7,
            MessageRef::SnapshotReq { .. } => 13,
        }
    }

    pub fn decode(b: &[u8]) -> Result<MessageRef<'_>> {
        let op = b[0];
        Ok(match op {
            1 => MessageRef::Pull { iter: 0 },
            3 => MessageRef::Push { iter: 0 },
            7 => MessageRef::Shutdown,
            13 => MessageRef::SnapshotReq { lo: 0 },
            _ => bail!("unknown opcode {op}"),
        })
    }
}
