//! Fixture: seeded metric-catalog violations, one per rule the check
//! enforces — a duplicated series name, a name without the `dynacomm_`
//! namespace prefix, and a name absent from the catalog page. Never
//! compiled — lexed by the metrics check's tests via `include_str!`.

pub fn register_everything() {
    // Fine: literal, prefixed, documented (in the test's synthetic doc).
    let _ok = obs_counter!("dynacomm_fixture_hits_total");
    // Violation 1: same series registered at a second lexical site.
    let _dup = obs_counter!("dynacomm_fixture_hits_total");
    // Violation 2: documented, but missing the namespace prefix.
    let _bare = obs_gauge!("fixture_depth");
    // Violation 3: prefixed, but nowhere on the catalog page.
    let _undoc = obs_histogram!("dynacomm_fixture_latency_ms");
}
