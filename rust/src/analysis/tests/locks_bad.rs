// Fixture: one violation per locks pass — an order inversion (pool.free
// is declared inner to server.conns), a bare `.lock()` site, and a
// condvar wait with no predicate re-check loop. Never compiled — loaded
// via include_str! by rust/src/analysis/checks/locks.rs tests.

fn nested_inversion(p: &Pool, s: &Server) {
    let free = lock_or_die(&p.free, "pool.free");
    let conns = lock_or_die(&s.conns, "server.conns");
    drop(conns);
    drop(free);
}

fn bare_site(s: &Server) {
    let conns = s.conns.lock().unwrap();
    drop(conns);
}

fn naked_wait(s: &Server) {
    let mut entries = lock_or_die(&s.entries, "reply_cache.entries");
    entries = wait_or_die(&s.ready, entries, "reply_cache.entries");
    drop(entries);
}
