//! Fixture: a span taxonomy seeding exactly two violations — one
//! duplicated name and one name missing from the catalog page.

pub const SPAN_NAMES: &[&str] = &[
    "fixture-iteration",
    "fixture-iteration",
    "fixture-undocumented",
];
