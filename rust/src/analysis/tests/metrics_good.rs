//! Fixture: a well-behaved metrics module. Every obs series name is a
//! string literal, registered at one lexical site, carries the
//! `dynacomm_` prefix, and (per the synthetic doc text the unit test
//! supplies) is documented. Never compiled — lexed by the metrics check's
//! tests via `include_str!`.

pub struct FixtureCounters {
    hits: Counter,
    depth: Gauge,
    latency: Histogram,
}

impl FixtureCounters {
    /// One lexical call site per series; a multi-instance type would take
    /// a label argument here instead of re-registering the name. Related
    /// series share one `Inst` so they join on the `inst` label.
    pub fn new() -> FixtureCounters {
        let inst = crate::obs::next_inst();
        FixtureCounters {
            hits: obs_counter!("dynacomm_fixture_hits_total", "", inst),
            depth: obs_gauge!("dynacomm_fixture_depth", "", inst),
            latency: obs_histogram!("dynacomm_fixture_latency_ms", "", inst),
        }
    }
}

#[cfg(test)]
mod tests {
    // Test-only registrations are exempt: scratch names here must not
    // force catalog entries.
    #[test]
    fn scratch_names_are_fine_in_tests() {
        let _ = obs_counter!("scratch_only_in_tests");
    }
}
