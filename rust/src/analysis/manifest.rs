//! Declarative manifest for the dynalint checks.
//!
//! The manifest lives at `rust/src/analysis/dynalint.toml` and is parsed
//! by a hand-rolled TOML-subset reader (the offline build bans crates.io,
//! so no `toml`/`serde`). The subset is exactly what the manifest needs:
//!
//! ```text
//! # comment
//! [section]            # nested as [section.sub]
//! [[section.entries]]  # array-of-tables
//! key = "string"
//! key = ["a", "b"]     # single-line string arrays
//! ```
//!
//! Every scalar is a quoted string (numbers included) so the value grammar
//! stays one rule. See `docs/ANALYSIS.md` for the semantics of each key.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed `key = value` table.
pub type Table = BTreeMap<String, Value>;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    List(Vec<String>),
}

impl Value {
    fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            Value::List(_) => None,
        }
    }

    fn as_list(&self) -> Option<&[String]> {
        match self {
            Value::Str(_) => None,
            Value::List(items) => Some(items),
        }
    }
}

/// Raw parse result: plain tables by dotted path, plus array-of-tables.
#[derive(Debug, Default)]
pub struct Toml {
    pub tables: BTreeMap<String, Table>,
    pub arrays: BTreeMap<String, Vec<Table>>,
}

/// Parse the TOML subset. Unknown syntax is an error, not a silent skip —
/// a typo in the manifest must not quietly disable a check.
pub fn parse_toml(text: &str) -> Result<Toml> {
    #[derive(PartialEq)]
    enum Target {
        Table(String),
        Array(String),
    }
    let mut out = Toml::default();
    let mut target = Target::Table(String::new());
    out.tables.insert(String::new(), Table::new());
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let body = raw.trim();
        if body.is_empty() || body.starts_with('#') {
            continue;
        }
        if let Some(inner) = body.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let path = inner.trim().to_string();
            if path.is_empty() {
                bail!("line {lineno}: empty [[...]] header");
            }
            out.arrays.entry(path.clone()).or_default().push(Table::new());
            target = Target::Array(path);
            continue;
        }
        if let Some(inner) = body.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let path = inner.trim().to_string();
            if path.is_empty() {
                bail!("line {lineno}: empty [...] header");
            }
            out.tables.entry(path.clone()).or_default();
            target = Target::Table(path);
            continue;
        }
        let Some((key, value)) = body.split_once('=') else {
            bail!("line {lineno}: expected `key = value`, got: {body}");
        };
        let key = key.trim().trim_matches('"').to_string();
        if key.is_empty() {
            bail!("line {lineno}: empty key");
        }
        let value = parse_value(value.trim())
            .with_context(|| format!("line {lineno}: bad value for '{key}'"))?;
        let table = match &target {
            Target::Table(path) => out
                .tables
                .get_mut(path)
                .expect("current table always exists"),
            Target::Array(path) => out
                .arrays
                .get_mut(path)
                .and_then(|v| v.last_mut())
                .expect("current array entry always exists"),
        };
        table.insert(key, value);
    }
    Ok(out)
}

fn parse_value(text: &str) -> Result<Value> {
    if let Some(inner) = text.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("arrays must close on the same line");
        };
        let mut items = Vec::new();
        for part in split_top_level_commas(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_quoted(part)?);
        }
        return Ok(Value::List(items));
    }
    Ok(Value::Str(parse_quoted(text)?))
}

/// Split on commas that are not inside quotes (values may contain commas).
fn split_top_level_commas(text: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut escaped = false;
    for c in text.chars() {
        if escaped {
            cur.push(c);
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => {
                cur.push(c);
                escaped = true;
            }
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

fn parse_quoted(text: &str) -> Result<String> {
    let t = text.trim();
    let Some(inner) = t.strip_prefix('"').and_then(|s| s.strip_suffix('"')) else {
        bail!("expected a quoted string, got: {t}");
    };
    Ok(inner.to_string())
}

// ---------------------------------------------------------------------------
// Typed manifest
// ---------------------------------------------------------------------------

/// One `[[registry.entries]]` block: a named registry, the source file its
/// `NAMES` const lives in, and the doc page that must list every entry.
#[derive(Debug, Clone)]
pub struct RegistryEntry {
    pub name: String,
    pub source: String,
    pub doc: String,
}

/// Wire-protocol expectations: the transport source, the frame-name → tag
/// table the code must match, the pinned protocol version, and the doc and
/// fuzz files that must track it.
#[derive(Debug, Clone)]
pub struct WireManifest {
    pub transport: String,
    pub frames: Vec<(String, u8)>,
    pub protocol_version: u16,
    pub doc: String,
    pub fuzz: String,
}

/// Metric-catalog expectations: which macros register series, the
/// namespace prefix every name must carry, and the doc page that must
/// list every name.
#[derive(Debug, Clone)]
pub struct MetricsManifest {
    /// Catalog page every series name must appear on.
    pub doc: String,
    /// Macro names whose first argument is a series name.
    pub macros: Vec<String>,
    /// Required namespace prefix (e.g. `dynacomm_`).
    pub prefix: String,
    /// Const ident whose string entries form the span-name taxonomy; every
    /// entry must be globally unique and documented (backtick-quoted) on
    /// the catalog page.
    pub span_table: String,
}

/// The full typed manifest consumed by the five checks.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Banned call patterns inside hot-path functions. Shape selects the
    /// matcher: `A::B` path call, `.m` method call, `m!` macro.
    pub banned: Vec<String>,
    /// Canonical lock names, outermost-first: a thread holding lock at
    /// position `i` may only acquire locks at positions `> i`.
    pub lock_order: Vec<String>,
    /// Receiver-identifier → canonical lock name, for `ident.lock()` sites
    /// that predate (or bypass) the `lock_or_die` helper.
    pub lock_idents: Vec<(String, String)>,
    /// Condvar identifier → the lock its predicate lives under.
    pub condvars: Vec<(String, String)>,
    /// The one file allowed to touch `Mutex::lock`/`Condvar::wait` raw:
    /// the poisoning-policy helper itself.
    pub policy_file: String,
    pub lock_helper: String,
    pub wait_helper: String,
    pub wire: WireManifest,
    pub registries: Vec<RegistryEntry>,
    /// File holding the CLI `HELP` banner every registry name must appear in.
    pub help_source: String,
    pub metrics: MetricsManifest,
}

impl Manifest {
    pub fn load(path: &std::path::Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Manifest::from_text(&text)
            .with_context(|| format!("parsing manifest {}", path.display()))
    }

    pub fn from_text(text: &str) -> Result<Manifest> {
        let toml = parse_toml(text)?;
        let str_key = |table: &str, key: &str| -> Result<String> {
            toml.tables
                .get(table)
                .and_then(|t| t.get(key))
                .and_then(Value::as_str)
                .map(str::to_string)
                .with_context(|| format!("manifest missing [{table}] {key}"))
        };
        let list_key = |table: &str, key: &str| -> Result<Vec<String>> {
            toml.tables
                .get(table)
                .and_then(|t| t.get(key))
                .and_then(Value::as_list)
                .map(|v| v.to_vec())
                .with_context(|| format!("manifest missing [{table}] {key} array"))
        };
        let pairs = |table: &str| -> Vec<(String, String)> {
            toml.tables
                .get(table)
                .map(|t| {
                    t.iter()
                        .filter_map(|(k, v)| {
                            v.as_str().map(|s| (k.clone(), s.to_string()))
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let mut frames = Vec::new();
        for (name, value) in pairs("wire.frames") {
            let tag: u8 = value
                .parse()
                .with_context(|| format!("frame {name}: tag '{value}' is not a u8"))?;
            frames.push((name, tag));
        }
        frames.sort_by_key(|(_, tag)| *tag);
        if frames.is_empty() {
            bail!("manifest [wire.frames] is empty");
        }
        let version_text = str_key("wire", "protocol_version")?;
        let protocol_version: u16 = version_text
            .parse()
            .with_context(|| format!("protocol_version '{version_text}'"))?;
        let mut registries = Vec::new();
        for table in toml.arrays.get("registry.entries").map(Vec::as_slice).unwrap_or(&[])
        {
            let field = |key: &str| -> Result<String> {
                table
                    .get(key)
                    .and_then(Value::as_str)
                    .map(str::to_string)
                    .with_context(|| format!("[[registry.entries]] missing {key}"))
            };
            registries.push(RegistryEntry {
                name: field("name")?,
                source: field("source")?,
                doc: field("doc")?,
            });
        }
        if registries.is_empty() {
            bail!("manifest has no [[registry.entries]]");
        }
        Ok(Manifest {
            banned: list_key("alloc", "banned")?,
            lock_order: list_key("locks", "order")?,
            lock_idents: pairs("locks.idents"),
            condvars: pairs("locks.condvars"),
            policy_file: str_key("locks", "policy_file")?,
            lock_helper: str_key("locks", "lock_helper")?,
            wait_helper: str_key("locks", "wait_helper")?,
            wire: WireManifest {
                transport: str_key("wire", "transport")?,
                frames,
                protocol_version,
                doc: str_key("wire", "doc")?,
                fuzz: str_key("wire", "fuzz")?,
            },
            registries,
            help_source: str_key("registry", "help_source")?,
            metrics: MetricsManifest {
                doc: str_key("metrics", "doc")?,
                macros: list_key("metrics", "macros")?,
                prefix: str_key("metrics", "prefix")?,
                span_table: str_key("metrics", "span_table")?,
            },
        })
    }

    /// Rank of a canonical lock name in the declared partial order.
    pub fn lock_rank(&self, name: &str) -> Option<usize> {
        self.lock_order.iter().position(|n| n == name)
    }

    /// Canonical lock name for a `.lock()` receiver identifier.
    pub fn lock_for_ident(&self, ident: &str) -> Option<&str> {
        self.lock_idents
            .iter()
            .find(|(k, _)| k == ident)
            .map(|(_, v)| v.as_str())
    }

    /// Is `ident` a declared condvar?
    pub fn is_condvar(&self, ident: &str) -> bool {
        self.condvars.iter().any(|(k, _)| k == ident)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample manifest
[alloc]
banned = ["Vec::new", ".clone", "format!"]

[locks]
order = ["a.outer", "b.inner"]
policy_file = "rust/src/util/sync.rs"
lock_helper = "lock_or_die"
wait_helper = "wait_or_die"

[locks.idents]
conns = "a.outer"

[locks.condvars]
cv = "b.inner"

[wire]
transport = "rust/src/net/transport.rs"
doc = "docs/WIRE.md"
fuzz = "rust/tests/fuzz_substrates.rs"
protocol_version = "4"

[wire.frames]
Pull = "1"
Push = "3"

[registry]
help_source = "rust/src/main.rs"

[[registry.entries]]
name = "sched"
source = "rust/src/sched/registry.rs"
doc = "docs/SCHEDULER.md"

[[registry.entries]]
name = "sync"
source = "rust/src/ps/sync/mod.rs"
doc = "docs/SYNC.md"

[metrics]
doc = "docs/OBSERVABILITY.md"
macros = ["obs_counter", "obs_gauge", "obs_histogram"]
prefix = "dynacomm_"
span_table = "SPAN_NAMES"
"#;

    #[test]
    fn parses_the_full_shape() {
        let m = Manifest::from_text(SAMPLE).unwrap();
        assert_eq!(m.banned, vec!["Vec::new", ".clone", "format!"]);
        assert_eq!(m.lock_order, vec!["a.outer", "b.inner"]);
        assert_eq!(m.lock_rank("b.inner"), Some(1));
        assert_eq!(m.lock_for_ident("conns"), Some("a.outer"));
        assert!(m.is_condvar("cv"));
        assert_eq!(m.wire.protocol_version, 4);
        assert_eq!(m.wire.frames, vec![("Pull".to_string(), 1), ("Push".to_string(), 3)]);
        assert_eq!(m.registries.len(), 2);
        assert_eq!(m.registries[1].doc, "docs/SYNC.md");
        assert_eq!(m.metrics.doc, "docs/OBSERVABILITY.md");
        assert_eq!(m.metrics.macros.len(), 3);
        assert_eq!(m.metrics.prefix, "dynacomm_");
    }

    #[test]
    fn typos_error_instead_of_disabling_checks() {
        assert!(Manifest::from_text("not a manifest").is_err());
        assert!(parse_toml("key = [\"unterminated\"").is_err());
        assert!(parse_toml("key = bare").is_err());
        let missing = SAMPLE.replace("lock_helper", "lock_helper_typo");
        assert!(Manifest::from_text(&missing).is_err());
    }

    #[test]
    fn the_committed_manifest_parses() {
        let text = include_str!("dynalint.toml");
        let m = Manifest::from_text(text).expect("committed manifest is valid");
        assert_eq!(m.wire.frames.len(), 16, "one frame per v7 opcode");
        assert_eq!(m.registries.len(), 3, "sched, sync, codec");
        assert_eq!(m.metrics.macros.len(), 3, "counter, gauge, histogram");
    }
}
