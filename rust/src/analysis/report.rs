//! Diagnostics: `file:line` text rendering plus machine-readable JSON.

use crate::util::json::Json;

/// One diagnostic from one check.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Check identifier: `alloc`, `locks`, `wire`, `registry`, or `metrics`.
    pub check: &'static str,
    /// Repo-root-relative path with forward slashes.
    pub file: String,
    /// 1-based line; 0 when the finding is about a whole file.
    pub line: u32,
    pub message: String,
}

impl Finding {
    pub fn new(
        check: &'static str,
        file: &str,
        line: u32,
        message: String,
    ) -> Finding {
        Finding { check, file: file.to_string(), line, message }
    }

    pub fn render(&self) -> String {
        if self.line == 0 {
            format!("{}: [{}] {}", self.file, self.check, self.message)
        } else {
            format!("{}:{}: [{}] {}", self.file, self.line, self.check, self.message)
        }
    }
}

/// A full run: every finding, plus enough metadata for CI artifacts.
#[derive(Debug)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub checks_run: Vec<&'static str>,
    pub elapsed_ms: f64,
}

impl Report {
    /// Human-readable rendering: one `file:line: [check] message` line per
    /// finding (sorted for stable diffs), then a one-line summary.
    pub fn render_text(&self) -> String {
        let mut sorted: Vec<&Finding> = self.findings.iter().collect();
        sorted.sort_by(|a, b| {
            (&a.file, a.line, a.check).cmp(&(&b.file, b.line, b.check))
        });
        let mut out = String::new();
        for f in &sorted {
            out.push_str(&f.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "dynalint: {} finding(s) across {} file(s), {} check(s) in {:.0} ms\n",
            self.findings.len(),
            self.files_scanned,
            self.checks_run.len(),
            self.elapsed_ms,
        ));
        out
    }

    /// JSON artifact for CI upload. Schema documented in docs/ANALYSIS.md.
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("check", Json::Str(f.check.to_string())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("tool", Json::Str("dynalint".to_string())),
            ("schema_version", Json::Num(1.0)),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("checks_run", Json::arr_str(&self.checks_run)),
            ("finding_count", Json::Num(self.findings.len() as f64)),
            ("findings", Json::Arr(findings)),
            ("elapsed_ms", Json::Num(self.elapsed_ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            findings: vec![
                Finding::new("locks", "rust/src/b.rs", 9, "inversion".to_string()),
                Finding::new("alloc", "rust/src/a.rs", 3, "banned call".to_string()),
            ],
            files_scanned: 2,
            checks_run: vec!["alloc", "locks", "wire", "registry"],
            elapsed_ms: 12.0,
        }
    }

    #[test]
    fn text_rendering_is_sorted_and_clickable() {
        let text = sample().render_text();
        let a = text.find("rust/src/a.rs:3: [alloc] banned call").unwrap();
        let b = text.find("rust/src/b.rs:9: [locks] inversion").unwrap();
        assert!(a < b, "findings sorted by file: {text}");
        assert!(text.contains("2 finding(s)"));
    }

    #[test]
    fn json_artifact_round_trips_through_the_parser() {
        let json = sample().to_json();
        let back = Json::parse(&json.to_string()).unwrap();
        assert_eq!(back.get("tool").and_then(Json::as_str), Some("dynalint"));
        assert_eq!(back.get("finding_count").and_then(Json::as_usize), Some(2));
        let findings = back.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(findings.len(), 2);
        assert_eq!(findings[0].get("check").and_then(Json::as_str), Some("locks"));
        assert_eq!(findings[0].get("line").and_then(Json::as_usize), Some(9));
    }
}
