//! A lightweight Rust lexer for `dynalint` — no `syn`, no `proc-macro2`.
//!
//! The checks in [`crate::analysis::checks`] are token-pattern matchers,
//! not semantic analyses, so the lexer only needs to classify source text
//! into the categories that matter for pattern safety: identifiers,
//! numbers, string/char literals (so a pattern string inside a check's own
//! source never matches itself), lifetimes, comments (the annotation
//! carrier), and single-character punctuation. Every token carries the
//! 1-based line it starts on for `file:line` diagnostics.

/// Token category. Punctuation is one token per character; multi-char
/// operators (`=>`, `::`, `..=`) are matched as adjacent `Punct` tokens by
/// the checks, which is unambiguous because the lexer never merges them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    /// String literal (plain, raw, or byte); `text` is the inner content
    /// with quotes stripped and escape sequences left as written.
    Str,
    /// Character or byte-character literal, quotes stripped.
    CharLit,
    /// Lifetime such as `'a` or `'static`; `text` excludes the tick.
    Lifetime,
    /// Line or block comment; `text` is the content after `//` or between
    /// `/*` and `*/`.
    Comment,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text == name
    }

    pub fn is_punct(&self, ch: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(ch)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. The lexer is total: unrecognized bytes become `Punct`
/// tokens rather than errors, so a partially exotic file degrades to
/// weaker checking instead of a crash.
pub fn lex(src: &str) -> Vec<Token> {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let n = chars.len();
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            out.push(Token {
                kind: TokKind::Comment,
                text: chars[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let tok_line = line;
            let start = i + 2;
            let mut j = start;
            let mut depth = 1u32;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let end = if depth == 0 { j.saturating_sub(2) } else { j };
            out.push(Token {
                kind: TokKind::Comment,
                text: chars[start..end.max(start)].iter().collect(),
                line: tok_line,
            });
            i = j;
            continue;
        }
        // Raw / byte string prefixes: r"..", r#".."#, b"..", br"..".
        if (c == 'r' || c == 'b') && is_string_prefix(&chars, i) {
            let (tok, next, lines) = lex_prefixed_string(&chars, i, line);
            out.push(tok);
            line += lines;
            i = next;
            continue;
        }
        if c == '"' {
            let (tok, next, lines) = lex_plain_string(&chars, i, line);
            out.push(tok);
            line += lines;
            i = next;
            continue;
        }
        if c == '\'' {
            // Disambiguate char literal from lifetime: a backslash is
            // always a char literal; otherwise a closing tick right after
            // one content char marks a literal, anything else a lifetime.
            let is_char = if i + 1 < n && chars[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && chars[i + 2] == '\''
            };
            if is_char {
                let start = i + 1;
                let mut j = start;
                let mut guard = 0;
                while j < n && guard < 16 {
                    if chars[j] == '\\' {
                        j += 2;
                    } else if chars[j] == '\'' {
                        break;
                    } else {
                        j += 1;
                    }
                    guard += 1;
                }
                out.push(Token {
                    kind: TokKind::CharLit,
                    text: chars[start..j.min(n)].iter().collect(),
                    line,
                });
                i = (j + 1).min(n);
            } else {
                let start = i + 1;
                let mut j = start;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                out.push(Token {
                    kind: TokKind::Lifetime,
                    text: chars[start..j].iter().collect(),
                    line,
                });
                i = j;
            }
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            let mut seen_dot = false;
            while j < n {
                let d = chars[j];
                if is_ident_continue(d) {
                    j += 1;
                } else if d == '.'
                    && !seen_dot
                    && j + 1 < n
                    && chars[j + 1].is_ascii_digit()
                {
                    seen_dot = true;
                    j += 1;
                } else {
                    break;
                }
            }
            out.push(Token {
                kind: TokKind::Num,
                text: chars[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            let mut j = i;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            out.push(Token {
                kind: TokKind::Ident,
                text: chars[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        out.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// Is the `r`/`b` at `i` the start of a (raw/byte) string or char literal
/// rather than an ordinary identifier?
fn is_string_prefix(chars: &[char], i: usize) -> bool {
    let n = chars.len();
    let c = chars[i];
    if c == 'b' {
        if i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '\'') {
            return true;
        }
        if i + 2 < n && chars[i + 1] == 'r' && (chars[i + 2] == '"' || chars[i + 2] == '#') {
            return true;
        }
        return false;
    }
    // c == 'r'
    if i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '#') {
        // `r#ident` raw identifiers exist but the repo does not use them;
        // require the `#`s to be followed by a quote to avoid misfiring.
        let mut j = i + 1;
        while j < n && chars[j] == '#' {
            j += 1;
        }
        return j < n && chars[j] == '"';
    }
    false
}

/// Lex a string that begins with an `r`/`b`/`br` prefix at `i`.
/// Returns (token, index after the literal, newlines consumed).
fn lex_prefixed_string(chars: &[char], i: usize, line: u32) -> (Token, usize, u32) {
    let n = chars.len();
    let mut j = i;
    let mut raw = false;
    while j < n && (chars[j] == 'r' || chars[j] == 'b') {
        if chars[j] == 'r' {
            raw = true;
        }
        j += 1;
    }
    if j < n && chars[j] == '\'' {
        // Byte char literal `b'x'`.
        let start = j + 1;
        let mut k = start;
        while k < n && chars[k] != '\'' {
            if chars[k] == '\\' {
                k += 1;
            }
            k += 1;
        }
        let tok = Token {
            kind: TokKind::CharLit,
            text: chars[start..k.min(n)].iter().collect(),
            line,
        };
        return (tok, (k + 1).min(n), 0);
    }
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        // Not actually a string; emit the prefix as an identifier-ish punct.
        let tok = Token { kind: TokKind::Punct, text: chars[i].to_string(), line };
        return (tok, i + 1, 0);
    }
    let start = j + 1;
    let mut k = start;
    let mut newlines = 0u32;
    while k < n {
        if chars[k] == '\n' {
            newlines += 1;
            k += 1;
            continue;
        }
        if !raw && chars[k] == '\\' {
            if k + 1 < n && chars[k + 1] == '\n' {
                newlines += 1;
            }
            k += 2;
            continue;
        }
        if chars[k] == '"' {
            // For raw strings the quote must be followed by `hashes` #s.
            let mut h = 0usize;
            while k + 1 + h < n && h < hashes && chars[k + 1 + h] == '#' {
                h += 1;
            }
            if h == hashes {
                let tok = Token {
                    kind: TokKind::Str,
                    text: chars[start..k].iter().collect(),
                    line,
                };
                return (tok, k + 1 + hashes, newlines);
            }
        }
        k += 1;
    }
    let tok =
        Token { kind: TokKind::Str, text: chars[start..n].iter().collect(), line };
    (tok, n, newlines)
}

/// Lex a plain `"…"` string starting at the opening quote.
fn lex_plain_string(chars: &[char], i: usize, line: u32) -> (Token, usize, u32) {
    let n = chars.len();
    let start = i + 1;
    let mut j = start;
    let mut newlines = 0u32;
    while j < n {
        match chars[j] {
            '\\' => {
                // Escaped line continuations still advance the line count.
                if j + 1 < n && chars[j + 1] == '\n' {
                    newlines += 1;
                }
                j += 2;
            }
            '\n' => {
                newlines += 1;
                j += 1;
            }
            '"' => {
                let tok = Token {
                    kind: TokKind::Str,
                    text: chars[start..j].iter().collect(),
                    line,
                };
                return (tok, j + 1, newlines);
            }
            _ => j += 1,
        }
    }
    let tok =
        Token { kind: TokKind::Str, text: chars[start..n].iter().collect(), line };
    (tok, n, newlines)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn classifies_the_core_categories() {
        let toks = kinds("fn f(x: u32) -> &'a str { x.clone() }");
        assert!(toks.contains(&(TokKind::Ident, "fn".into())));
        assert!(toks.contains(&(TokKind::Lifetime, "a".into())));
        assert!(toks.contains(&(TokKind::Ident, "clone".into())));
        assert!(toks.contains(&(TokKind::Punct, ".".into())));
    }

    #[test]
    fn pattern_text_inside_strings_is_not_ident_tokens() {
        let toks = lex("let s = \"Vec::new and .clone()\";");
        assert!(toks.iter().all(|t| !(t.kind == TokKind::Ident && t.text == "clone")));
        assert!(toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn comments_carry_their_text_and_line() {
        let toks = lex("let a = 1;\n// dynalint: hot-path\nfn g() {}\n");
        let c = toks.iter().find(|t| t.kind == TokKind::Comment).unwrap();
        assert_eq!(c.text.trim(), "dynalint: hot-path");
        assert_eq!(c.line, 2);
        let f = toks.iter().find(|t| t.is_ident("fn")).unwrap();
        assert_eq!(f.line, 3);
    }

    #[test]
    fn char_literals_and_lifetimes_disambiguate() {
        let toks = kinds("let c = 'x'; let t: &'static str = s; let e = '\\n';");
        assert!(toks.contains(&(TokKind::CharLit, "x".into())));
        assert!(toks.contains(&(TokKind::Lifetime, "static".into())));
        assert!(toks.contains(&(TokKind::CharLit, "\\n".into())));
    }

    #[test]
    fn raw_and_escaped_strings_terminate_correctly() {
        let toks = lex("let a = r#\"quote \" inside\"#; let b = \"esc\\\"aped\"; b");
        let strs: Vec<&Token> =
            toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 2);
        assert_eq!(strs[0].text, "quote \" inside");
        assert_eq!(strs[1].text, "esc\\\"aped");
        assert!(toks.last().unwrap().is_ident("b"), "lexing continued past strings");
    }

    #[test]
    fn block_comments_nest_and_count_lines() {
        let toks = lex("/* outer /* inner */ still */ fn after() {}\nx");
        let f = toks.iter().find(|t| t.is_ident("fn"));
        assert!(f.is_some(), "ident after nested block comment survives");
        let x = toks.iter().find(|t| t.is_ident("x")).unwrap();
        assert_eq!(x.line, 2);
    }

    #[test]
    fn numbers_absorb_suffixes_and_float_dots() {
        let toks = kinds("let a = 2u8; let b = 0.125; let r = 0..n;");
        assert!(toks.contains(&(TokKind::Num, "2u8".into())));
        assert!(toks.contains(&(TokKind::Num, "0.125".into())));
        // Range dots stay punctuation.
        assert!(toks.contains(&(TokKind::Num, "0".into())));
        assert!(toks.contains(&(TokKind::Ident, "n".into())));
    }
}
