//! The end-to-end trainer: boots parameter-server shards and edge workers
//! in one process (threads + loopback TCP through the link shaper), trains
//! EdgeCNN through the PJRT artifacts, and reports loss/accuracy — the
//! Fig. 10 / Table II driver.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::config::{Strategy, Tier};
use crate::net::codec::CodecId;
use crate::net::{LinkShaper, ShaperSpec};
use crate::ps::{
    agg::{AggConfig, RegionalAggregator},
    server::{ParamServer, ServerConfig, ServerOptions},
    sharding::ShardMap,
    sync::{SyncConfig, SyncMode},
    worker::{EdgeWorker, WorkerConfig, WorkerReport},
};
use crate::runtime::{ArtifactManifest, RuntimeClient, Tensor};
use crate::training::data::SyntheticDataset;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifacts_dir: String,
    pub strategy: Strategy,
    pub workers: usize,
    pub servers: usize,
    pub epochs: usize,
    pub iters_per_epoch: usize,
    pub lr: f32,
    /// Emulated per-message setup cost (Δt), ms. Scaled-down edge network:
    /// the absolute numbers are smaller than the paper's testbed so a full
    /// training run stays minutes, but the Δt-vs-transfer structure is the
    /// same.
    pub setup_ms: f64,
    /// Emulated one-way latency, ms.
    pub latency_ms: f64,
    /// Emulated link rate, bytes per ms.
    pub bytes_per_ms: f64,
    /// Real-time profiling switch (Table II).
    pub profiling: bool,
    pub seed: u64,
    /// Validation batches for the epoch-end accuracy measurement.
    pub val_batches: usize,
    /// DynaComm re-plan gain threshold, ms: skip the O(L^3) DP at an epoch
    /// boundary when a fresh plan cannot gain more than this over the
    /// cached one. 0 re-plans every epoch (the paper's Section IV-C loop);
    /// negative (the default, `sched::dynacomm::GAIN_THRESHOLD_AUTO`)
    /// auto-tunes the threshold from the measured DP wall-clock vs the
    /// iteration's comm idle window. An explicit value overrides AUTO.
    pub gain_threshold_ms: f64,
    /// Wire codec for parameter/gradient transfers (`--codec`): every
    /// worker proposes it at registration and the whole fleet falls back
    /// to fp32 on any mismatch (`net::codec`).
    pub codec: CodecId,
    /// Parameter-server synchronization mode (`--sync {bsp,ssp,asp}`,
    /// `ps::sync`): the shards are started with it and every worker
    /// verifies it at registration.
    pub sync: SyncMode,
    /// SSP staleness bound (`--staleness-bound`, iterations a worker may
    /// run ahead of the slowest); must be 0 outside SSP.
    pub staleness_bound: u32,
    /// Per-shard handler-thread cap (`--handler-threads`): connections
    /// past it wait in the accept backlog instead of spawning threads.
    pub handler_threads: usize,
    /// EF-SGD error feedback for lossy codecs (`--no-error-feedback` to
    /// disable): workers carry per-layer quantization-error residuals
    /// into the next iteration's gradient (`net::codec::ef`).
    pub error_feedback: bool,
    /// Fleet topology (`--tier {flat,regional}`, docs/TOPOLOGY.md):
    /// `regional` boots `⌈workers / group_size⌉` aggregators (`ps::agg`)
    /// between the edge fleet and the cloud shards. Workers then speak
    /// `sync`/`codec` to their group's aggregator; the regional→cloud hop
    /// runs `agg_sync`/`agg_codec` and the shards are started with
    /// `agg_sync`.
    pub tier: Tier,
    /// Edge workers per regional aggregator (`--group-size`).
    pub group_size: usize,
    /// Regional→cloud hop sync mode (`--agg-sync`); shares
    /// `staleness_bound` when it runs SSP.
    pub agg_sync: SyncMode,
    /// Regional→cloud hop wire codec (`--agg-codec`).
    pub agg_codec: CodecId,
    /// Pull/push I/O deadline, ms (`--io-timeout-ms`, `docs/FAULTS.md`);
    /// 0 disables. Applied to every worker→shard and aggregator→cloud
    /// socket so a dead peer fails the blocked read within the window.
    pub io_timeout_ms: u64,
    /// Shard checkpointing (`--checkpoint-dir`): each shard `s` writes
    /// `shard-{s}.ckpt` here every `checkpoint_every_ms` and once more on
    /// shutdown (`ps::checkpoint`).
    pub checkpoint_dir: Option<String>,
    /// Periodic checkpoint interval, ms (`--checkpoint-every-ms`).
    pub checkpoint_every_ms: u64,
    /// Resume shards from the `shard-{s}.ckpt` files in this directory
    /// (`--restore`) instead of the artifact init files; parameters,
    /// version clocks, and sync clocks pick up byte-identically where the
    /// checkpoint captured them.
    pub restore_dir: Option<String>,
    /// Prometheus scrape listener for the run (`--metrics-addr`,
    /// docs/OBSERVABILITY.md); `None` disables it.
    pub metrics_addr: Option<String>,
    /// Chrome trace-event JSON output path (`--trace-out`): arms span
    /// tracing for the run and exports the merged fleet trace here on
    /// shutdown — one file, one process lane per node, offset-corrected
    /// timestamps, flow arrows across lanes (docs/OBSERVABILITY.md). A
    /// critical-path report (`{path}.critpath.json` + a printed breakdown
    /// table) is derived from it in the same pass.
    pub trace_out: Option<String>,
    /// Worker clock-probe cadence, iterations (`--clock-probe-every`;
    /// 0 keeps only the establish-time burst).
    pub clock_probe_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts_dir: "artifacts".to_string(),
            strategy: Strategy::DynaComm,
            workers: 2,
            servers: 2,
            epochs: 3,
            iters_per_epoch: 30,
            lr: 0.01,
            setup_ms: 2.0,
            latency_ms: 1.0,
            bytes_per_ms: 100_000.0, // 100 MB/s emulated goodput
            profiling: true,
            seed: 0,
            val_batches: 4,
            gain_threshold_ms: crate::sched::dynacomm::GAIN_THRESHOLD_AUTO,
            codec: CodecId::Fp32,
            sync: SyncMode::Bsp,
            staleness_bound: 0,
            handler_threads: ServerOptions::default().handler_threads,
            error_feedback: true,
            tier: Tier::Flat,
            group_size: 4,
            agg_sync: SyncMode::Bsp,
            agg_codec: CodecId::Fp32,
            io_timeout_ms: 0,
            checkpoint_dir: None,
            checkpoint_every_ms: 1_000,
            restore_dir: None,
            metrics_addr: None,
            trace_out: None,
            clock_probe_every: 64,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub per_worker: Vec<WorkerReport>,
    /// Mean loss per epoch (averaged across workers and iterations).
    pub epoch_loss: Vec<f64>,
    /// Mean training-batch top-1 per epoch.
    pub epoch_train_acc: Vec<f64>,
    /// Validation top-1 per epoch-end snapshot... final epoch only unless
    /// val_batches > 0 (computing it requires a monolithic forward pass).
    pub val_acc: f64,
    /// Mean iteration wall-clock (ms) per epoch, worker-averaged.
    pub epoch_iter_ms: Vec<f64>,
    /// Samples/sec per worker over the whole run (Table II metric).
    pub samples_per_sec_per_worker: f64,
    pub final_params: Vec<(Tensor, Tensor)>,
}

/// Run a full training job; blocks until all workers finish.
pub fn train(cfg: &TrainConfig) -> Result<TrainResult> {
    // Observability plane (docs/OBSERVABILITY.md): arm span tracing and
    // boot the scrape listener before any shard registers its counters so
    // the first scrape already sees the full namespace.
    if cfg.trace_out.is_some() {
        crate::obs::trace::set_enabled(true);
    }
    // One trace id per logical iteration fleet-wide: every node hashes the
    // same run seed, so cross-process span links agree on their trace ids.
    crate::obs::trace::set_run_seed(cfg.seed);
    let mut metrics_srv = match &cfg.metrics_addr {
        Some(addr) => Some(crate::obs::expo::MetricsServer::bind(addr)?),
        None => None,
    };
    let manifest = ArtifactManifest::load(&cfg.artifacts_dir)?;
    let depth = manifest.depth();
    let shard = ShardMap::new(cfg.servers, depth);
    let batch = manifest.batch;

    // Initial parameters (flat w‖b per layer) from the exported init files.
    let mut init: Vec<Vec<f32>> = Vec::with_capacity(depth);
    for l in &manifest.layers {
        let w = Tensor::from_bin_file(&manifest.path(&l.w_init), l.w_shape.clone())?;
        let b = Tensor::from_bin_file(&manifest.path(&l.b_init), l.b_shape.clone())?;
        let mut flat = w.data;
        flat.extend_from_slice(&b.data);
        init.push(flat);
    }

    // Boot one shard per server with its owned layers.
    let downlink = ShaperSpec {
        setup_ms: cfg.setup_ms,
        latency_ms: cfg.latency_ms,
        bytes_per_ms: cfg.bytes_per_ms,
    };
    let sync = SyncConfig::new(cfg.sync, cfg.staleness_bound)?;
    let agg_sync = SyncConfig::new(
        cfg.agg_sync,
        if cfg.agg_sync == SyncMode::Ssp { cfg.staleness_bound } else { 0 },
    )?;
    // Under the regional tier the cloud shards speak to aggregators, so
    // they run the regional→cloud hop's mode; the workers' mode governs
    // the edge→regional hop at the aggregators instead.
    let shard_sync = if cfg.tier == Tier::Regional { agg_sync } else { sync };
    let mut servers = Vec::with_capacity(cfg.servers);
    for s in 0..cfg.servers {
        let scfg = ServerConfig { workers: cfg.workers, lr: cfg.lr };
        let opts = ServerOptions { sync: shard_sync, handler_threads: cfg.handler_threads };
        let mut srv = match &cfg.restore_dir {
            Some(dir) => {
                let path = std::path::Path::new(dir).join(format!("shard-{s}.ckpt"));
                let ck = crate::ps::Checkpoint::read_from(&path)
                    .with_context(|| format!("restoring shard {s}"))?;
                ParamServer::start_restored(scfg, Some(downlink), opts, &ck)?
            }
            None => {
                let layers: HashMap<usize, Vec<f32>> = shard
                    .owned_by(s)
                    .into_iter()
                    .map(|l| (l, init[l].clone()))
                    .collect();
                ParamServer::start_with(scfg, layers, Some(downlink), opts)?
            }
        };
        if let Some(dir) = &cfg.checkpoint_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {dir}"))?;
            srv.enable_checkpointing(
                std::path::Path::new(dir).join(format!("shard-{s}.ckpt")),
                std::time::Duration::from_millis(cfg.checkpoint_every_ms.max(1)),
            );
        }
        servers.push(srv);
    }
    let addrs: Vec<std::net::SocketAddr> =
        servers.iter().map(|s| s.handle().addr).collect();

    // Regional tier (ps::agg, docs/TOPOLOGY.md): one aggregator per
    // group of `group_size` workers, fronting every shard. Each worker
    // then speaks only to its group's aggregator; the cloud sees one
    // combined push per group (weighted by the group's worker count, so
    // the shards' `lr / workers` scaling is unchanged).
    let mut aggs: Vec<RegionalAggregator> = Vec::new();
    if cfg.tier == Tier::Regional {
        anyhow::ensure!(cfg.group_size >= 1, "group_size must be >= 1");
        let layer_elems: Vec<usize> = init.iter().map(Vec::len).collect();
        let mut assigned = 0;
        while assigned < cfg.workers {
            let chunk = cfg.group_size.min(cfg.workers - assigned);
            aggs.push(RegionalAggregator::start(AggConfig {
                // Group identities live past the worker-id space.
                group: (cfg.workers + aggs.len()) as u32,
                workers: chunk as u32,
                upstream_addrs: addrs.clone(),
                layer_elems: layer_elems.clone(),
                downstream_sync: sync,
                upstream_sync: agg_sync,
                upstream_codec: cfg.agg_codec,
                handler_threads: cfg.handler_threads,
                io_timeout_ms: cfg.io_timeout_ms,
            })?);
            assigned += chunk;
        }
    }

    let dataset = SyntheticDataset::new(
        cfg.seed,
        manifest.input_shape.clone(),
        manifest.num_classes,
    );
    let total_iters = (cfg.epochs * cfg.iters_per_epoch) as u64;

    // Spawn workers. Each thread owns its PJRT client (the xla crate's
    // client is Rc-based and not Send).
    let mut handles = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        // A tiered worker sees a single "server": its group's aggregator,
        // which fronts the full layer range and fans its traffic in/out.
        let worker_addrs = if cfg.tier == Tier::Regional {
            vec![aggs[w / cfg.group_size].addr()]
        } else {
            addrs.clone()
        };
        let wcfg = WorkerConfig {
            id: w,
            strategy: cfg.strategy,
            artifacts_dir: cfg.artifacts_dir.clone(),
            server_addrs: worker_addrs,
            shaper: Some(LinkShaper::new(
                cfg.setup_ms,
                cfg.latency_ms,
                cfg.bytes_per_ms,
            )),
            profiling: cfg.profiling,
            reschedule_every: cfg.iters_per_epoch,
            gain_threshold_ms: cfg.gain_threshold_ms,
            codec: cfg.codec,
            sync: cfg.sync,
            staleness_bound: cfg.staleness_bound,
            error_feedback: cfg.error_feedback,
            io_timeout_ms: cfg.io_timeout_ms,
            clock_probe_every: cfg.clock_probe_every,
        };
        let ds = dataset.clone();
        let want_params = w == 0;
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || -> Result<(WorkerReport, Option<Vec<(Tensor, Tensor)>>)> {
                    let mut worker = EdgeWorker::connect(wcfg)?;
                    let report = worker
                        .run(total_iters, |i| ds.batch(w as u64, i, batch))?;
                    let params = if want_params {
                        Some(worker.pull_params(total_iters)?)
                    } else {
                        None
                    };
                    Ok((report, params))
                })?,
        );
    }

    let mut per_worker = Vec::with_capacity(cfg.workers);
    let mut final_params = None;
    for (w, h) in handles.into_iter().enumerate() {
        let (report, params) = h
            .join()
            .map_err(|_| anyhow::anyhow!("worker thread panicked"))?
            .context("worker failed")?;
        // Federation (docs/OBSERVABILITY.md): re-export each member's
        // end-of-run metrics snapshot from the trainer's scrape endpoint,
        // relabelled with its node, so one scrape sees the whole fleet.
        crate::obs::expo::note_federated(&format!("worker-{w}"), report.metrics.clone());
        per_worker.push(report);
        if params.is_some() {
            final_params = params;
        }
    }
    for a in &mut aggs {
        a.shutdown();
    }
    for s in &mut servers {
        s.shutdown();
    }
    // Quiescent point: every span-producing thread has joined or been
    // shut down, so the ring export is complete and race-free.
    if let Some(path) = &cfg.trace_out {
        crate::obs::trace::write_chrome_trace(path)
            .with_context(|| format!("writing trace to {path}"))?;
        // Critical-path pass over the merged trace (obs::critpath): the
        // per-hop breakdown lands next to the trace as JSON, prints as a
        // table, and registers the `dynacomm_critical_path_ms` gauges.
        let trace = std::fs::read_to_string(path)
            .with_context(|| format!("re-reading trace {path}"))?;
        let report = crate::obs::critpath::analyze(&trace)
            .with_context(|| format!("critical-path analysis of {path}"))?;
        let report_path = format!("{path}.critpath.json");
        std::fs::write(&report_path, report.to_json().to_string())
            .with_context(|| format!("writing critical-path report {report_path}"))?;
        print!("{}", report.table());
    }
    if let Some(srv) = metrics_srv.as_mut() {
        srv.shutdown();
    }
    let final_params = final_params.context("no worker returned params")?;

    // Aggregate per-epoch metrics.
    let ipe = cfg.iters_per_epoch;
    let mut epoch_loss = Vec::with_capacity(cfg.epochs);
    let mut epoch_train_acc = Vec::with_capacity(cfg.epochs);
    let mut epoch_iter_ms = Vec::with_capacity(cfg.epochs);
    for e in 0..cfg.epochs {
        let (mut l, mut a, mut t, mut n) = (0.0, 0.0, 0.0, 0);
        for rep in &per_worker {
            for i in e * ipe..((e + 1) * ipe).min(rep.losses.len()) {
                l += rep.losses[i] as f64;
                a += rep.batch_top1[i];
                t += rep.iter_ms[i];
                n += 1;
            }
        }
        epoch_loss.push(l / n as f64);
        epoch_train_acc.push(a / n as f64);
        epoch_iter_ms.push(t / n as f64);
    }

    // Validation accuracy on held-out batches via the monolithic forward.
    let val_acc = if cfg.val_batches > 0 {
        let rt = RuntimeClient::load(&cfg.artifacts_dir)?;
        let mut acc = 0.0;
        for vb in 0..cfg.val_batches {
            let (x, onehot) = dataset.batch(u64::MAX - 1, vb as u64, batch);
            let logits = rt.full_fwd(&final_params, &x)?;
            acc += crate::ps::worker::batch_top1(&logits, &onehot);
        }
        acc / cfg.val_batches as f64
    } else {
        f64::NAN
    };

    let total_ms: f64 = per_worker
        .iter()
        .map(|r| r.iter_ms.iter().sum::<f64>())
        .sum::<f64>()
        / cfg.workers as f64;
    let samples_per_sec_per_worker =
        (total_iters as f64 * batch as f64) / (total_ms / 1e3);

    Ok(TrainResult {
        per_worker,
        epoch_loss,
        epoch_train_acc,
        val_acc,
        epoch_iter_ms,
        samples_per_sec_per_worker,
        final_params,
    })
}
