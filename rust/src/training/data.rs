//! Synthetic CIFAR-10-like dataset.
//!
//! The paper's accuracy experiment (Fig. 10) only needs a learnable
//! classification task: scheduling must not change the computed math, so
//! identical update sequences give identical curves. Each class gets a
//! fixed random spatial pattern; samples are the pattern plus Gaussian
//! noise and a random global intensity jitter. A CNN reaches high accuracy
//! on it within a few hundred steps.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    /// One flat base image per class.
    bases: Vec<Vec<f32>>,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub noise: f32,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(seed: u64, input_shape: Vec<usize>, classes: usize) -> SyntheticDataset {
        let n: usize = input_shape.iter().product();
        let mut rng = Rng::new(seed);
        let bases = (0..classes)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        SyntheticDataset { bases, input_shape, classes, noise: 0.4, seed }
    }

    /// Deterministic batch `(x, onehot)` for a (worker, iteration) pair.
    /// Different `stream` values give disjoint sample streams.
    pub fn batch(&self, stream: u64, iter: u64, batch: usize) -> (Tensor, Tensor) {
        let n: usize = self.input_shape.iter().product();
        let mut rng = Rng::new(
            self.seed ^ (stream.wrapping_mul(0x9e37_79b9)) ^ (iter.wrapping_mul(0x85eb_ca6b)),
        );
        let mut x = Vec::with_capacity(batch * n);
        let mut onehot = vec![0.0f32; batch * self.classes];
        for s in 0..batch {
            let c = rng.below(self.classes);
            onehot[s * self.classes + c] = 1.0;
            let gain = 1.0 + 0.2 * rng.normal() as f32;
            let base = &self.bases[c];
            for v in base {
                x.push(gain * v + self.noise * rng.normal() as f32);
            }
        }
        let mut shape = vec![batch];
        shape.extend(&self.input_shape);
        (Tensor::new(shape, x), Tensor::new(vec![batch, self.classes], onehot))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticDataset {
        SyntheticDataset::new(7, vec![4, 4, 3], 10)
    }

    #[test]
    fn batch_shapes() {
        let (x, y) = ds().batch(0, 0, 8);
        assert_eq!(x.shape, vec![8, 4, 4, 3]);
        assert_eq!(y.shape, vec![8, 10]);
        // one-hot rows sum to 1.
        for r in 0..8 {
            let s: f32 = y.data[r * 10..(r + 1) * 10].iter().sum();
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn deterministic_batches() {
        let a = ds().batch(1, 5, 4);
        let b = ds().batch(1, 5, 4);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        let c = ds().batch(2, 5, 4);
        assert_ne!(a.0, c.0, "streams must differ");
    }

    #[test]
    fn classes_are_separable() {
        // Mean distance between same-class samples must be far below
        // between-class distance (otherwise nothing is learnable).
        let d = ds();
        let (x, y) = d.batch(0, 0, 64);
        let n = 4 * 4 * 3;
        let label = |r: usize| -> usize {
            (0..10).find(|c| y.data[r * 10 + c] == 1.0).unwrap()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for a in 0..32 {
            for b in (a + 1)..32 {
                let dist: f32 = (0..n)
                    .map(|i| (x.data[a * n + i] - x.data[b * n + i]).powi(2))
                    .sum();
                if label(a) == label(b) {
                    same.push(dist);
                } else {
                    diff.push(dist);
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(mean(&same) < 0.7 * mean(&diff), "{} vs {}", mean(&same), mean(&diff));
    }
}
