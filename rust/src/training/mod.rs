//! End-to-end training: synthetic dataset, in-process cluster bootstrap,
//! and the trainer that drives the real three-layer stack (Pallas/JAX
//! artifacts under PJRT, orchestrated by the Rust PS framework over the
//! shaped loopback network).

pub mod data;
pub mod trainer;

pub use data::SyntheticDataset;
pub use trainer::{train, TrainConfig, TrainResult};
