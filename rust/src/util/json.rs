//! Minimal JSON parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to read the artifact manifest written
//! by `python/compile/aot.py`, the experiment configs, and to emit result
//! files consumed by the bench reports.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so emitted
/// JSON is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]`, failing on any non-number element.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Str(x.to_string())).collect())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs: only BMP escapes are emitted by
                            // our own writers; map lone surrogates to U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-sync on UTF-8 boundaries: collect continuation bytes.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact serialization (no whitespace).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = s.to_string();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse("\"π≈3.14159\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "π≈3.14159");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str().unwrap(), "Aé");
    }

    #[test]
    fn display_roundtrip_nested() {
        let text = r#"{"batch":16,"layers":[{"name":"conv1","w_shape":[3,3,3,16]}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(
            v.get("layers").unwrap().as_arr().unwrap()[0]
                .get("w_shape")
                .unwrap()
                .as_usize_vec()
                .unwrap(),
            vec![3, 3, 3, 16]
        );
    }

    #[test]
    fn integers_display_without_fraction() {
        assert_eq!(Json::Num(16.0).to_string(), "16");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
