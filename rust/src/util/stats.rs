//! Small statistics helpers used by the bench harnesses and tables:
//! mean / stddev / percentiles / min / max, and a log-log slope fit used to
//! verify the O(L^3) complexity claim of Fig. 12 empirically.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize of empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
    }
}

/// Nearest-rank percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Least-squares slope+intercept of y over x.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let sx = x.iter().sum::<f64>();
    let sy = y.iter().sum::<f64>();
    let sxx = x.iter().map(|v| v * v).sum::<f64>();
    let sxy = x.iter().zip(y).map(|(a, b)| a * b).sum::<f64>();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    (slope, intercept)
}

/// Fit `time ~ c * L^k` by regressing log(time) on log(L); returns `k`.
/// Used by `fig12` to check the scheduling algorithms' growth exponent.
pub fn power_law_exponent(sizes: &[f64], times: &[f64]) -> f64 {
    let lx: Vec<f64> = sizes.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = times.iter().map(|v| v.ln()).collect();
    linear_fit(&lx, &ly).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn single_sample() {
        let s = summarize(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn fit_recovers_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [3.0, 5.0, 7.0, 9.0];
        let (m, b) = linear_fit(&x, &y);
        assert!((m - 2.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cubic_exponent_detected() {
        let l: Vec<f64> = (1..=8).map(|i| (i * 40) as f64).collect();
        let t: Vec<f64> = l.iter().map(|v| 2e-9 * v * v * v).collect();
        let k = power_law_exponent(&l, &t);
        assert!((k - 3.0).abs() < 1e-6, "k={k}");
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        summarize(&[]);
    }
}
