//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used by the workload generators (Fig. 12 random profiles), the synthetic
//! dataset, and the property tests (scheduler-vs-bruteforce). No `rand`
//! crate in the offline cache, so this is the project's randomness source.

/// xoshiro256** seeded through SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (n > 0). Lemire-style rejection-free
    /// multiply-shift is enough for non-cryptographic use.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample with the given log-space mean and sigma — matches
    /// the heavy-tailed layer-cost distributions seen in real CNN profiles.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
