//! The repo-wide lock poisoning policy.
//!
//! Every `Mutex` in the server, sync policies, slab pool, and link shaper
//! guards state that is meaningless after a holder panicked mid-update
//! (a half-applied gradient, a half-built reply slab, a torn clock table).
//! Recovery is therefore never attempted: a poisoned lock aborts the
//! process, but through these helpers the abort message **names the lock**
//! instead of the anonymous `PoisonError` that `lock().unwrap()` prints.
//!
//! `dynalint` (see `docs/ANALYSIS.md`) enforces the policy lexically: any
//! bare `.lock()` outside this file and `#[cfg(test)]` modules is a
//! finding, as is any condvar wait that does not route through
//! [`wait_or_die`] inside a predicate re-check loop.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Acquire `m` or abort with a diagnostic naming the poisoned lock.
///
/// `name` is the canonical lock name from the dynalint lock-order manifest
/// (e.g. `"server.conns"`, `"pool.free"`), so a poisoning abort in a
/// production log identifies the exact lock without a backtrace.
pub fn lock_or_die<'a, T>(m: &'a Mutex<T>, name: &str) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(_) => panic!(
            "lock '{name}' poisoned: a holder panicked mid-update; \
             guarded state is unrecoverable by policy"
        ),
    }
}

/// Block on `cv` with `guard` or abort, naming the lock that poisoned.
///
/// Callers must re-check their predicate around the wait (condvar wakeups
/// are spurious by contract); dynalint verifies every call site sits
/// inside a `while`/`loop` body.
pub fn wait_or_die<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    name: &str,
) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(guard) => guard,
        Err(_) => panic!(
            "condvar wait on '{name}': lock poisoned by a panicking holder"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar};

    #[test]
    fn lock_or_die_passes_through_healthy_locks() {
        let m = Mutex::new(7);
        assert_eq!(*lock_or_die(&m, "test.healthy"), 7);
        *lock_or_die(&m, "test.healthy") = 8;
        assert_eq!(*lock_or_die(&m, "test.healthy"), 8);
    }

    #[test]
    fn lock_or_die_names_the_poisoned_lock() {
        let m = Arc::new(Mutex::new(0));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = lock_or_die(&m, "test.poisoned");
        }))
        .expect_err("poisoned lock must abort");
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("test.poisoned"), "diagnostic names the lock: {msg}");
    }

    #[test]
    fn wait_or_die_returns_the_guard_on_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = pair.clone();
        let t = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut ready = lock_or_die(m, "test.pair");
            while !*ready {
                ready = wait_or_die(cv, ready, "test.pair");
            }
            *ready
        });
        {
            let (m, cv) = &*pair;
            *lock_or_die(m, "test.pair") = true;
            cv.notify_all();
        }
        assert!(t.join().unwrap());
    }
}
