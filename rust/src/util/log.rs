//! Leveled stderr logger with a global level, monotonic timestamps, and a
//! per-line component tag. `DYNACOMM_LOG=debug|info|warn|error` overrides.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    if let Ok(v) = std::env::var("DYNACOMM_LOG") {
        match v.to_ascii_lowercase().as_str() {
            "debug" => set_level(Level::Debug),
            "info" => set_level(Level::Info),
            "warn" => set_level(Level::Warn),
            "error" => set_level(Level::Error),
            _ => {}
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{t:9.3}s {tag} {component}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($c:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $c, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($c:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $c, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($c:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $c, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
