//! Hand-rolled substrates. The offline build environment ships only the
//! `xla` and `anyhow` crates, so everything a framework normally pulls from
//! crates.io (JSON, PRNG, CLI parsing, stats, logging) is implemented here.

pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod sync;
