//! Tiny CLI flag parser (no `clap` offline): `--key value`, `--key=value`,
//! bare `--flag` booleans, and positional arguments.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus a key/value flag map.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // NOTE: a bare flag followed by a non-flag token consumes it as a
        // value ("--verbose run" ⇒ verbose=run), so boolean flags go last
        // or use the `--flag=true` form.
        let a = parse(&["run", "--model", "resnet152", "--batch=32", "--verbose"]);
        assert_eq!(a.get("model"), Some("resnet152"));
        assert_eq!(a.usize("batch", 0), 32);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize("workers", 8), 8);
        assert_eq!(a.f64("rtt-ms", 10.0), 10.0);
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse(&["--fast", "--model", "vgg19"]);
        assert!(a.bool("fast"));
        assert_eq!(a.get("model"), Some("vgg19"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--seed=-5"]);
        assert_eq!(a.get("seed"), Some("-5"));
    }
}
