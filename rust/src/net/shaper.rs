//! Link shaping: make loopback behave like the paper's edge↔cloud network.
//!
//! Every message through a shaped [`super::Connection`] is delayed by
//! `setup + one-way latency + bytes/bandwidth` before hitting the socket —
//! the same cost structure (`Δt` + flight time) the paper's testbed
//! exhibits, scaled down so hundreds of training iterations stay cheap in
//! CI. The shaper is shared (Arc) per worker link so that concurrent
//! senders on the same link serialize, like a real NIC.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::lock_or_die;

/// Parameters for building per-link shapers (e.g. one downlink per worker
/// connection on the server side).
#[derive(Debug, Clone, Copy)]
pub struct ShaperSpec {
    pub setup_ms: f64,
    pub latency_ms: f64,
    pub bytes_per_ms: f64,
}

impl ShaperSpec {
    pub fn build(&self) -> LinkShaper {
        LinkShaper::new(self.setup_ms, self.latency_ms, self.bytes_per_ms)
    }
}

/// Token-bucket-ish serializing shaper for one worker↔cloud link.
#[derive(Debug, Clone)]
pub struct LinkShaper {
    inner: Arc<Mutex<ShaperState>>,
    /// Per-message setup cost (the Δt the paper models), ms.
    pub setup_ms: f64,
    /// One-way latency, ms.
    pub latency_ms: f64,
    /// Link rate, bytes per ms.
    pub bytes_per_ms: f64,
}

#[derive(Debug)]
struct ShaperState {
    /// Time at which the link becomes free (serialization point).
    free_at: Option<Instant>,
}

impl LinkShaper {
    pub fn new(setup_ms: f64, latency_ms: f64, bytes_per_ms: f64) -> LinkShaper {
        assert!(bytes_per_ms > 0.0);
        LinkShaper {
            inner: Arc::new(Mutex::new(ShaperState { free_at: None })),
            setup_ms,
            latency_ms,
            bytes_per_ms,
        }
    }

    /// An unshaped link (zero cost) — useful in tests.
    pub fn unshaped() -> LinkShaper {
        LinkShaper::new(0.0, 0.0, f64::INFINITY)
    }

    /// The emulated cost of transmitting `bytes`, in ms.
    pub fn cost_ms(&self, bytes: usize) -> f64 {
        self.setup_ms + self.latency_ms + bytes as f64 / self.bytes_per_ms
    }

    /// Block until the link is free, then occupy it for the message's
    /// serialization time and sleep through it.
    pub fn delay_for(&self, bytes: usize) {
        let cost = self.cost_ms(bytes);
        if cost <= 0.0 || !cost.is_finite() {
            return;
        }
        let dur = Duration::from_secs_f64(cost / 1e3);
        let wake = {
            let mut st = lock_or_die(&self.inner, "shaper.state");
            let now = Instant::now();
            let start = match st.free_at {
                Some(t) if t > now => t,
                _ => now,
            };
            let wake = start + dur;
            st.free_at = Some(wake);
            wake
        };
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model() {
        let s = LinkShaper::new(2.0, 5.0, 1000.0);
        assert!((s.cost_ms(0) - 7.0).abs() < 1e-9);
        assert!((s.cost_ms(10_000) - 17.0).abs() < 1e-9);
    }

    #[test]
    fn unshaped_is_free() {
        let s = LinkShaper::unshaped();
        let t0 = Instant::now();
        s.delay_for(1 << 20);
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn delay_roughly_matches_cost() {
        let s = LinkShaper::new(1.0, 2.0, 10_000.0); // 10 MB/s
        let t0 = Instant::now();
        s.delay_for(50_000); // 1 + 2 + 5 = 8 ms
        let el = t0.elapsed().as_secs_f64() * 1e3;
        assert!((7.0..40.0).contains(&el), "elapsed {el} ms");
    }

    #[test]
    fn concurrent_senders_serialize() {
        // Two 10 ms messages on one link: total ≥ 20 ms even if sent from
        // two threads at once.
        let s = LinkShaper::new(0.0, 0.0, 1000.0); // 1 MB/s → 10 KB = 10 ms
        let s2 = s.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || s2.delay_for(10_000));
        s.delay_for(10_000);
        h.join().unwrap();
        let el = t0.elapsed().as_secs_f64() * 1e3;
        assert!(el >= 19.0, "elapsed {el} ms — link did not serialize");
    }
}
