//! Edge-network substrate: framed TCP transport plus a link shaper that
//! emulates the paper's edge↔cloud conditions (RTT, bandwidth, per-message
//! setup cost Δt) on loopback. Tensor payloads travel as contiguous
//! little-endian byte slabs ([`slab`]) — optionally compressed by a
//! negotiated wire codec ([`codec`]: fp32/fp16/int8) — carried in pooled,
//! reference-counted buffers ([`pool`]) and framed with scatter-gather I/O
//! ([`transport`]); `docs/WIRE.md` specifies the frame format and codec
//! negotiation, `docs/PERF.md` the pooling and copy discipline.

pub mod codec;
pub mod fault;
pub mod pool;
pub mod shaper;
pub mod slab;
pub mod transport;

pub use codec::{CodecId, CodecStats, WireCodec};
pub use fault::{FaultAction, FaultEvent, FaultProxy, FaultSpec};
pub use pool::{PoolStats, PooledSlab, SlabCheckout, SlabPool, SlabSlice};
pub use shaper::{LinkShaper, ShaperSpec};
pub use transport::{
    Connection, Message, MessageRef, PeerRole, RecvMsg, TraceCtx, PROTOCOL_VERSION,
};
