//! Edge-network substrate: framed TCP transport plus a link shaper that
//! emulates the paper's edge↔cloud conditions (RTT, bandwidth, per-message
//! setup cost Δt) on loopback. Tensor payloads travel as contiguous
//! little-endian byte slabs ([`slab`]); `docs/WIRE.md` specifies the frame
//! format.

pub mod shaper;
pub mod slab;
pub mod transport;

pub use shaper::{LinkShaper, ShaperSpec};
pub use transport::{Connection, Message, PROTOCOL_VERSION};
