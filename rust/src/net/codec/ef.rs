//! Error-feedback accumulation (EF-SGD) for lossy wire codecs.
//!
//! Quantizing gradients biases every step by that step's rounding error;
//! over a training run the bias accumulates and the loss floors above the
//! full-precision optimum. EF-SGD removes the bias: the worker keeps a
//! per-layer **residual** `e_l`, transmits `q(g_l + e_l)` instead of
//! `q(g_l)`, and stores the new quantization error
//! `e_l ← (g_l + e_l) − dequant(q(g_l + e_l))` for the next iteration —
//! the error is *fed back*, so nothing is ever silently dropped, only
//! delayed. For the identity codec the residual is exactly zero and
//! [`ErrorFeedback::encode`] degenerates to a plain encode.
//!
//! Convergence is covered end-to-end in `tests/sync_integration.rs`: the
//! int8+EF least-squares run must end at a loss no worse than plain int8.

use anyhow::Result;

use super::WireCodec;
use crate::net::slab;

/// Per-layer residual state for one worker. Survives re-plans (the layer
/// set is fixed for a training run) and is independent of the wire path —
/// callers hand it the raw gradient slab right before encoding.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    /// One residual per layer, sized to the layer's element count.
    residual: Vec<Vec<f32>>,
    /// Decode scratch for the error update (recycled across calls).
    scratch: Vec<u8>,
}

impl ErrorFeedback {
    /// `layer_elems[l]` is layer `l`'s flat `w‖b` element count.
    pub fn new(layer_elems: &[usize]) -> ErrorFeedback {
        ErrorFeedback {
            residual: layer_elems.iter().map(|&n| vec![0.0; n]).collect(),
            scratch: Vec::new(),
        }
    }

    pub fn depth(&self) -> usize {
        self.residual.len()
    }

    /// Layer `l`'s current residual (test/observability support).
    pub fn residual(&self, l: usize) -> &[f32] {
        &self.residual[l]
    }

    /// Encode layer `l`'s raw gradient slab with error feedback: add the
    /// carried residual into `raw` in place, append the codec encoding of
    /// the sum to `dst`, and update the residual with this call's
    /// quantization error. Returns the codec's reported max absolute
    /// error (of the fed-back sum, matching what actually hit the wire).
    pub fn encode(
        &mut self,
        l: usize,
        codec: &dyn WireCodec,
        raw: &mut [u8],
        dst: &mut Vec<u8>,
    ) -> Result<f32> {
        let res = &mut self.residual[l];
        anyhow::ensure!(
            raw.len() == slab::ELEM * res.len(),
            "layer {l}: got {} gradient bytes, residual holds {} elements",
            raw.len(),
            res.len()
        );
        slab::zip_map_f32s(raw, res, |g, e| g + e);
        let wire_at = dst.len();
        let err = codec.encode(raw, dst);
        if err == 0.0 {
            // Lossless: the residual is identically zero — skip the
            // decode pass entirely.
            return Ok(err);
        }
        // e ← (g + e) − dequant(wire): whatever the wire dropped.
        self.scratch.clear();
        codec.decode(&dst[wire_at..], &mut self.scratch)?;
        anyhow::ensure!(
            self.scratch.len() == raw.len(),
            "layer {l}: codec decoded {} bytes from its own encoding of {}",
            self.scratch.len(),
            raw.len()
        );
        for (e, (sent, got)) in res
            .iter_mut()
            .zip(slab::f32_iter(raw).zip(slab::f32_iter(&self.scratch)))
        {
            *e = sent - got;
        }
        Ok(err)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{codec, CodecId};
    use crate::util::rng::Rng;

    #[test]
    fn fp32_keeps_a_zero_residual_and_identical_wire() {
        let mut ef = ErrorFeedback::new(&[4]);
        let g = [1.25f32, -3.0, 0.5, 2.0];
        let mut raw = slab::from_f32s(&g);
        let mut wire = Vec::new();
        let err = ef.encode(0, codec(CodecId::Fp32), &mut raw, &mut wire).unwrap();
        assert_eq!(err, 0.0);
        assert_eq!(wire, slab::from_f32s(&g), "identity codec, identity wire");
        assert!(ef.residual(0).iter().all(|&e| e == 0.0));
    }

    /// The defining EF invariant: after every encode,
    /// `residual == fed-back gradient − what the wire carries`, so the sum
    /// of everything ever put on the wire plus the final residual equals
    /// the sum of all raw gradients (nothing is lost, only delayed).
    #[test]
    fn residual_carries_exactly_the_quantization_error() {
        let mut rng = Rng::new(23);
        for id in [CodecId::Fp16, CodecId::Int8] {
            let n = 1500; // crosses an int8 chunk boundary
            let mut ef = ErrorFeedback::new(&[n]);
            let c = codec(id);
            let mut sum_raw = vec![0.0f64; n];
            let mut sum_wire = vec![0.0f64; n];
            for _ in 0..5 {
                let g: Vec<f32> =
                    (0..n).map(|_| (rng.normal() * 0.3) as f32).collect();
                for (s, v) in sum_raw.iter_mut().zip(&g) {
                    *s += *v as f64;
                }
                let mut raw = slab::from_f32s(&g);
                let mut wire = Vec::new();
                ef.encode(0, c, &mut raw, &mut wire).unwrap();
                let mut dec = Vec::new();
                c.decode(&wire, &mut dec).unwrap();
                for (s, v) in sum_wire.iter_mut().zip(slab::f32_iter(&dec)) {
                    *s += v as f64;
                }
            }
            for (j, ((sr, sw), e)) in
                sum_raw.iter().zip(&sum_wire).zip(ef.residual(0)).enumerate()
            {
                assert!(
                    (sr - (sw + *e as f64)).abs() < 1e-4,
                    "{}: element {j}: raw {sr} != wire {sw} + residual {e}",
                    id.name()
                );
            }
        }
    }

    /// With a constant gradient, plain int8 repeats the same rounding
    /// error forever while EF's transmitted values average out to the true
    /// gradient — the mechanism behind the convergence-floor win.
    #[test]
    fn feedback_averages_out_a_constant_bias() {
        let n = 64;
        let c = codec(CodecId::Int8);
        // A gradient that quantizes coarsely: big range, off-grid values.
        let g: Vec<f32> = (0..n).map(|j| (j as f32 * 0.77).sin() * 3.0 + 0.013).collect();
        let rounds = 40;
        let mut plain_sum = vec![0.0f64; n];
        let mut ef_sum = vec![0.0f64; n];
        let mut ef = ErrorFeedback::new(&[n]);
        for _ in 0..rounds {
            let mut wire = Vec::new();
            c.encode(&slab::from_f32s(&g), &mut wire);
            let mut dec = Vec::new();
            c.decode(&wire, &mut dec).unwrap();
            for (s, v) in plain_sum.iter_mut().zip(slab::f32_iter(&dec)) {
                *s += v as f64;
            }
            let mut raw = slab::from_f32s(&g);
            let mut wire = Vec::new();
            ef.encode(0, c, &mut raw, &mut wire).unwrap();
            let mut dec = Vec::new();
            c.decode(&wire, &mut dec).unwrap();
            for (s, v) in ef_sum.iter_mut().zip(slab::f32_iter(&dec)) {
                *s += v as f64;
            }
        }
        let bias = |sum: &[f64]| -> f64 {
            sum.iter()
                .zip(&g)
                .map(|(s, v)| (s / rounds as f64 - *v as f64).abs())
                .sum::<f64>()
                / n as f64
        };
        let (pb, eb) = (bias(&plain_sum), bias(&ef_sum));
        assert!(
            eb < pb * 0.2,
            "EF mean bias {eb:.2e} not well under plain {pb:.2e}"
        );
    }

    #[test]
    fn size_mismatches_are_refused() {
        let mut ef = ErrorFeedback::new(&[4]);
        let mut raw = slab::from_f32s(&[1.0; 3]);
        let mut wire = Vec::new();
        assert!(ef.encode(0, codec(CodecId::Int8), &mut raw, &mut wire).is_err());
    }
}
