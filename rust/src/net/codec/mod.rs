//! Pluggable quantized wire codecs (protocol v3, AccEPT-style — arXiv
//! 2311.05827).
//!
//! DynaComm's overlap gains are bounded by how long each parameter or
//! gradient slab spends on the wire; shrinking bytes-on-wire directly
//! widens the overlap window the DP scheduler exploits. A [`WireCodec`]
//! transforms a **raw slab** (contiguous little-endian f32, the v2 wire
//! format) into a **wire slab** and back:
//!
//! * [`Fp32Codec`] — identity. Byte-for-byte today's format; a v3 fp32
//!   session puts exactly the v2 bytes on the wire (property-tested).
//! * [`Fp16Codec`] — IEEE 754 half precision ([`fp16`]), 2 bytes/element
//!   (50% of fp32). Round-to-nearest-even; finite values past the fp16
//!   range saturate to ±65504 instead of overflowing to infinity, which is
//!   the training-friendly choice for stray large gradients.
//! * [`Int8Codec`] — per-chunk affine quantization ([`int8`]): every
//!   [`int8::CHUNK`]-element chunk carries an 8-byte `f32 scale ‖ f32
//!   zero-point` header followed by one `u8` per element
//!   (`x ≈ zero + scale·q`), ~26% of fp32 asymptotically. Per-chunk max
//!   absolute error is bounded by `range/254` (actually `range/510`:
//!   256 levels ⇒ step `range/255`, round-half ⇒ `step/2`).
//!
//! Codecs apply **per layer slab** (each layer's flat `w‖b` is encoded
//! independently and the encodings concatenated), so both endpoints can
//! compute every offset from the immutable per-layer byte tables —
//! [`WireCodec::wire_len`] is an exact pure function of the raw size —
//! and int8 chunking restarts at each layer boundary.
//!
//! Lossy codecs pair with EF-SGD error feedback ([`ef::ErrorFeedback`]):
//! the worker folds a per-layer residual into each gradient before
//! quantizing and banks the new quantization error, so rounding bias is
//! delayed instead of dropped.
//!
//! The codec in effect is negotiated per session at registration time
//! (`CodecPropose`/`CodecAgree` frames, see `docs/WIRE.md`): the worker
//! proposes its preference, the server answers with that codec if it
//! supports it and falls back to [`CodecId::Fp32`] otherwise — every v3
//! endpoint must support fp32, so any preference pair converges
//! ([`negotiate`], property-tested). Tensor frames then carry the codec id
//! in the top 2 bits of the slab-length field, which keeps fp32 frames
//! byte-identical to v2.

pub mod ef;
pub mod fp16;
pub mod int8;

use anyhow::Result;

use crate::obs::{Counter, Gauge};

/// Identifier of a wire codec; also the 2-bit tag carried in the slab
/// length field of `PullReply`/`Push` frames (`docs/WIRE.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecId {
    /// Identity: raw little-endian f32 (the v2 format). Tag 0, so fp32
    /// frames are byte-identical to protocol v2.
    Fp32,
    /// IEEE 754 binary16, round-to-nearest-even, saturating.
    Fp16,
    /// Per-chunk affine u8 quantization with f32 scale/zero-point headers.
    Int8,
}

impl CodecId {
    /// All codecs, fp32 first (the mandatory fallback).
    pub const ALL: [CodecId; 3] = [CodecId::Fp32, CodecId::Fp16, CodecId::Int8];

    /// The 2-bit wire tag.
    pub fn tag(self) -> u8 {
        match self {
            CodecId::Fp32 => 0,
            CodecId::Fp16 => 1,
            CodecId::Int8 => 2,
        }
    }

    /// Parse a wire tag (the top 2 bits of a slab length field).
    pub fn from_tag(tag: u8) -> Option<CodecId> {
        match tag {
            0 => Some(CodecId::Fp32),
            1 => Some(CodecId::Fp16),
            2 => Some(CodecId::Int8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecId::Fp32 => "fp32",
            CodecId::Fp16 => "fp16",
            CodecId::Int8 => "int8",
        }
    }

    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<CodecId> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "none" => Some(CodecId::Fp32),
            "fp16" | "f16" | "half" => Some(CodecId::Fp16),
            "int8" | "i8" | "q8" => Some(CodecId::Int8),
            _ => None,
        }
    }

    /// The codec implementation behind this id.
    pub fn codec(self) -> &'static dyn WireCodec {
        codec(self)
    }

    /// Exact wire bytes for a raw f32 slab of `raw_len` bytes.
    pub fn wire_len(self, raw_len: usize) -> usize {
        self.codec().wire_len(raw_len)
    }

    /// Cheap frame-level sanity check for a tensor payload of `len` bytes
    /// tagged with this codec. A frame carries a **concatenation of
    /// per-layer encodings**, so only invariants that survive
    /// concatenation can be checked here: fp32 stays 4-aligned and fp16
    /// 2-aligned, but int8 slabs (9 bytes minimum each, arbitrary many)
    /// sum to almost any length — per-layer framing is validated by the
    /// endpoint that walks the payload with its byte tables
    /// ([`WireCodec::raw_len`] on each per-layer slice).
    pub fn valid_frame_len(self, len: usize) -> bool {
        match self {
            CodecId::Fp32 => len % 4 == 0,
            CodecId::Fp16 => len % 2 == 0,
            CodecId::Int8 => true,
        }
    }

    /// [`CodecId::wire_len`] over fractional byte counts — what the
    /// scheduler cost model feeds its transmission-time estimates
    /// (`sched::cost::transmission_ms`).
    pub fn wire_bytes_f64(self, raw_bytes: f64) -> f64 {
        match self {
            CodecId::Fp32 => raw_bytes,
            CodecId::Fp16 => raw_bytes / 2.0,
            CodecId::Int8 => {
                let elems = raw_bytes / 4.0;
                elems + int8::HEADER_BYTES as f64 * (elems / int8::CHUNK as f64).ceil()
            }
        }
    }
}

/// A wire codec: raw little-endian f32 slab ⇄ wire slab.
///
/// `wire_len`/`raw_len` are exact pure functions of the opposite size, so
/// both endpoints derive every offset from the per-layer byte tables they
/// already hold and nothing about sizes needs to travel out of band.
pub trait WireCodec: Send + Sync {
    fn id(&self) -> CodecId;

    /// Exact encoded size of a raw slab of `raw_len` bytes
    /// (`raw_len % 4 == 0`).
    fn wire_len(&self, raw_len: usize) -> usize;

    /// Exact raw size a wire slab of `wire_len` bytes decodes to; `Err` if
    /// no raw slab encodes to that length (framing validation).
    fn raw_len(&self, wire_len: usize) -> Result<usize>;

    /// Append the encoding of `raw` (LE f32 slab) to `dst`; returns the
    /// maximum absolute quantization error over the slab (0 for lossless
    /// codecs).
    fn encode(&self, raw: &[u8], dst: &mut Vec<u8>) -> f32;

    /// Append the decoded LE f32 slab to `dst`.
    fn decode(&self, wire: &[u8], dst: &mut Vec<u8>) -> Result<()>;

    /// `acc[i] += decode(wire)[i]` without materializing the decoded slab
    /// — the server's gradient-accumulation path.
    fn accumulate(&self, acc: &mut [f32], wire: &[u8]) -> Result<()>;
}

/// The identity codec: the wire slab *is* the raw slab.
pub struct Fp32Codec;

impl WireCodec for Fp32Codec {
    fn id(&self) -> CodecId {
        CodecId::Fp32
    }

    fn wire_len(&self, raw_len: usize) -> usize {
        raw_len
    }

    fn raw_len(&self, wire_len: usize) -> Result<usize> {
        anyhow::ensure!(wire_len % 4 == 0, "fp32 slab length {wire_len} not f32-aligned");
        Ok(wire_len)
    }

    fn encode(&self, raw: &[u8], dst: &mut Vec<u8>) -> f32 {
        debug_assert!(raw.len() % 4 == 0);
        dst.extend_from_slice(raw);
        0.0
    }

    fn decode(&self, wire: &[u8], dst: &mut Vec<u8>) -> Result<()> {
        self.raw_len(wire.len())?;
        dst.extend_from_slice(wire);
        Ok(())
    }

    fn accumulate(&self, acc: &mut [f32], wire: &[u8]) -> Result<()> {
        anyhow::ensure!(
            acc.len() * 4 == wire.len(),
            "fp32 slab/accumulator length mismatch: {} vs {}",
            wire.len(),
            acc.len() * 4
        );
        crate::net::slab::add_assign_f32s(acc, wire);
        Ok(())
    }
}

static FP32: Fp32Codec = Fp32Codec;
static FP16: fp16::Fp16Codec = fp16::Fp16Codec;
static INT8: int8::Int8Codec = int8::Int8Codec;

/// Look a codec implementation up by id.
pub fn codec(id: CodecId) -> &'static dyn WireCodec {
    match id {
        CodecId::Fp32 => &FP32,
        CodecId::Fp16 => &FP16,
        CodecId::Int8 => &INT8,
    }
}

/// The codecs this build supports (servers advertise-by-construction).
pub const SUPPORTED: [CodecId; 3] = CodecId::ALL;

/// Registry names, aligned with [`CodecId::ALL`] — what `--codec` parses,
/// the CLI help banner advertises, and `docs/WIRE.md` documents (the
/// `dynalint` registry check pins all three together).
pub const NAMES: [&str; 3] = ["fp32", "fp16", "int8"];

/// Session-codec negotiation: the first of the proposer's `prefs` that the
/// answerer supports, falling back to [`CodecId::Fp32`] — which every v3
/// endpoint must support, so any preference pair converges on a codec both
/// sides speak (property-tested in `tests/codec_train.rs`).
pub fn negotiate(prefs: &[CodecId], supported: &[CodecId]) -> CodecId {
    prefs
        .iter()
        .copied()
        .find(|c| supported.contains(c))
        .unwrap_or(CodecId::Fp32)
}

/// Per-codec wire-path counters: bytes before/after encoding, time spent
/// encoding/decoding, and the worst quantization error observed — exported
/// through `ps::server::WireStats` / `EdgeWorker::codec_stats` and the
/// `ps_throughput` bench rows in `results/BENCH_wire.json`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CodecStats {
    /// Raw f32 bytes fed into `encode` (or produced by decode paths).
    pub raw_bytes: u64,
    /// Encoded bytes that actually hit (or came off) the wire.
    pub wire_bytes: u64,
    /// `encode` calls and their total wall-clock.
    pub encodes: u64,
    pub encode_ns: u64,
    /// `decode`/`accumulate` calls and their total wall-clock.
    pub decodes: u64,
    pub decode_ns: u64,
    /// Max absolute quantization error observed by any `encode`.
    pub max_quant_error: f32,
}

impl CodecStats {
    /// Bytes the codec kept off the wire relative to raw fp32.
    pub fn bytes_saved(&self) -> u64 {
        self.raw_bytes.saturating_sub(self.wire_bytes)
    }
}

struct CodecCounters {
    raw_bytes: Counter,
    wire_bytes: Counter,
    bytes_saved: Counter,
    encodes: Counter,
    encode_ns: Counter,
    decodes: Counter,
    decode_ns: Counter,
    /// High-watermark quantization error (CAS-max gauge; f32 values
    /// roundtrip exactly through the gauge's f64 storage).
    max_err: Gauge,
}

impl CodecCounters {
    /// One obs-registry row per codec. Each metric name has exactly one
    /// lexical registration site (the dynalint `metrics` check audits
    /// that), so the per-codec fan-out happens here via the label; the
    /// row's series share one `inst` so they join per table entry.
    fn for_codec(codec: &'static str) -> CodecCounters {
        let lbl = format!("codec=\"{codec}\"");
        let inst = crate::obs::next_inst();
        CodecCounters {
            raw_bytes: crate::obs_counter!("dynacomm_codec_raw_bytes_total", lbl, inst),
            wire_bytes: crate::obs_counter!("dynacomm_codec_wire_bytes_total", lbl, inst),
            bytes_saved: crate::obs_counter!("dynacomm_codec_bytes_saved", lbl, inst),
            encodes: crate::obs_counter!("dynacomm_codec_encodes_total", lbl, inst),
            encode_ns: crate::obs_counter!("dynacomm_codec_encode_ns_total", lbl, inst),
            decodes: crate::obs_counter!("dynacomm_codec_decodes_total", lbl, inst),
            decode_ns: crate::obs_counter!("dynacomm_codec_decode_ns_total", lbl, inst),
            max_err: crate::obs_gauge!("dynacomm_codec_max_quant_error", lbl, inst),
        }
    }

    fn record_max_err(&self, err: f32) {
        if err > 0.0 {
            self.max_err.max(err as f64);
        }
    }

    fn snapshot(&self) -> CodecStats {
        CodecStats {
            raw_bytes: self.raw_bytes.get(),
            wire_bytes: self.wire_bytes.get(),
            encodes: self.encodes.get(),
            encode_ns: self.encode_ns.get(),
            decodes: self.decodes.get(),
            decode_ns: self.decode_ns.get(),
            max_quant_error: self.max_err.get() as f32,
        }
    }
}

/// Thread-safe per-codec counter table (one row per [`CodecId`]); the
/// server shard and each worker own one. Rows live in the unified obs
/// registry (labelled `codec="..."`, one instance set per table); the
/// snapshot getters below are thin adapters over those series.
pub struct CodecStatsTable {
    per: [CodecCounters; 3],
}

impl Default for CodecStatsTable {
    fn default() -> CodecStatsTable {
        CodecStatsTable::new()
    }
}

impl CodecStatsTable {
    pub fn new() -> CodecStatsTable {
        CodecStatsTable {
            per: [
                CodecCounters::for_codec(CodecId::Fp32.name()),
                CodecCounters::for_codec(CodecId::Fp16.name()),
                CodecCounters::for_codec(CodecId::Int8.name()),
            ],
        }
    }

    fn row(&self, id: CodecId) -> &CodecCounters {
        &self.per[id.tag() as usize]
    }

    /// Record one `encode` of `raw_bytes` → `wire_bytes` taking `ns`, with
    /// the call's max quantization error.
    pub fn record_encode(
        &self,
        id: CodecId,
        raw_bytes: usize,
        wire_bytes: usize,
        ns: u64,
        max_err: f32,
    ) {
        let row = self.row(id);
        row.raw_bytes.add(raw_bytes as u64);
        row.wire_bytes.add(wire_bytes as u64);
        row.bytes_saved.add(raw_bytes.saturating_sub(wire_bytes) as u64);
        row.encodes.inc();
        row.encode_ns.add(ns);
        row.record_max_err(max_err);
    }

    /// Record one `decode`/`accumulate` of `wire_bytes` → `raw_bytes`
    /// taking `ns`. Byte volume is attributed exclusively by
    /// [`CodecStatsTable::record_encode`] so a table never double-counts a
    /// slab its endpoint both produced and consumed; decode calls
    /// contribute their count and wall-clock.
    pub fn record_decode(&self, id: CodecId, raw_bytes: usize, wire_bytes: usize, ns: u64) {
        let row = self.row(id);
        row.decodes.inc();
        row.decode_ns.add(ns);
        let _ = (raw_bytes, wire_bytes);
    }

    /// Snapshot of every codec's counters, indexed by [`CodecId::tag`].
    pub fn snapshot(&self) -> [CodecStats; 3] {
        [
            self.per[0].snapshot(),
            self.per[1].snapshot(),
            self.per[2].snapshot(),
        ]
    }

    /// Snapshot of one codec's counters.
    pub fn get(&self, id: CodecId) -> CodecStats {
        self.row(id).snapshot()
    }
}

impl std::fmt::Debug for CodecStatsTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_map()
            .entries(CodecId::ALL.iter().map(|&id| (id.name(), self.get(id))))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slab;
    use crate::util::rng::Rng;

    #[test]
    fn registry_names_align_with_codec_ids() {
        for (name, id) in NAMES.iter().zip(CodecId::ALL) {
            assert_eq!(*name, id.name());
        }
    }

    fn random_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 10.0) as f32).collect()
    }

    #[test]
    fn ids_tags_names_roundtrip() {
        for id in CodecId::ALL {
            assert_eq!(CodecId::from_tag(id.tag()), Some(id));
            assert_eq!(CodecId::parse(id.name()), Some(id));
            assert_eq!(codec(id).id(), id);
        }
        assert_eq!(CodecId::from_tag(3), None);
        assert_eq!(CodecId::parse("zstd"), None);
    }

    #[test]
    fn fp32_is_the_identity() {
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let n = rng.below(300);
            let raw = slab::from_f32s(&random_f32s(&mut rng, n));
            let c = codec(CodecId::Fp32);
            assert_eq!(c.wire_len(raw.len()), raw.len());
            assert_eq!(c.raw_len(raw.len()).unwrap(), raw.len());
            let mut wire = Vec::new();
            assert_eq!(c.encode(&raw, &mut wire), 0.0);
            assert_eq!(wire, raw, "fp32 must be byte-identical");
            let mut back = Vec::new();
            c.decode(&wire, &mut back).unwrap();
            assert_eq!(back, raw);
        }
        assert!(codec(CodecId::Fp32).raw_len(6).is_err(), "misaligned fp32");
    }

    /// Every codec: wire_len/raw_len are exact inverses and encode/decode
    /// produce exactly those sizes.
    #[test]
    fn sizes_are_exact_for_every_codec() {
        let mut rng = Rng::new(8);
        for id in CodecId::ALL {
            let c = codec(id);
            for _ in 0..40 {
                let n = rng.below(5000);
                let vals = random_f32s(&mut rng, n);
                let raw = slab::from_f32s(&vals);
                let mut wire = Vec::new();
                c.encode(&raw, &mut wire);
                assert_eq!(wire.len(), c.wire_len(raw.len()), "{}", id.name());
                assert_eq!(c.raw_len(wire.len()).unwrap(), raw.len(), "{}", id.name());
                let mut back = Vec::new();
                c.decode(&wire, &mut back).unwrap();
                assert_eq!(back.len(), raw.len(), "{}", id.name());
            }
            // The empty slab is valid everywhere.
            assert_eq!(c.wire_len(0), 0);
            assert_eq!(c.raw_len(0).unwrap(), 0);
        }
    }

    /// accumulate == decode-then-add for every codec.
    #[test]
    fn accumulate_matches_decode_then_add() {
        let mut rng = Rng::new(9);
        for id in CodecId::ALL {
            let c = codec(id);
            let vals = random_f32s(&mut rng, 700);
            let raw = slab::from_f32s(&vals);
            let mut wire = Vec::new();
            c.encode(&raw, &mut wire);
            let mut decoded = Vec::new();
            c.decode(&wire, &mut decoded).unwrap();
            let mut via_acc = vec![1.5f32; vals.len()];
            c.accumulate(&mut via_acc, &wire).unwrap();
            let expect: Vec<f32> =
                slab::to_f32s(&decoded).iter().map(|v| 1.5 + v).collect();
            assert_eq!(via_acc, expect, "{}", id.name());
            // Length mismatches are refused, not mis-indexed.
            let mut short = vec![0.0f32; vals.len() - 1];
            assert!(c.accumulate(&mut short, &wire).is_err(), "{}", id.name());
        }
    }

    #[test]
    fn wire_bytes_f64_matches_wire_len() {
        for id in CodecId::ALL {
            for elems in [0usize, 1, 5, 1023, 1024, 1025, 10_000] {
                let raw = 4 * elems;
                assert_eq!(
                    id.wire_bytes_f64(raw as f64),
                    id.wire_len(raw) as f64,
                    "{} at {elems} elems",
                    id.name()
                );
            }
        }
    }

    #[test]
    fn negotiation_converges_and_prefers_the_proposal() {
        // Any (pref, supported-set) pair lands on a codec the answerer
        // supports; sets always contain Fp32 (mandatory in v3).
        let sets: [&[CodecId]; 4] = [
            &[CodecId::Fp32],
            &[CodecId::Fp32, CodecId::Fp16],
            &[CodecId::Fp32, CodecId::Int8],
            &SUPPORTED,
        ];
        for pref in CodecId::ALL {
            for sup in sets {
                let got = negotiate(&[pref], sup);
                assert!(sup.contains(&got), "{} over {sup:?}", pref.name());
                if sup.contains(&pref) {
                    assert_eq!(got, pref, "supported preference must win");
                } else {
                    assert_eq!(got, CodecId::Fp32, "fallback must be fp32");
                }
            }
        }
        // Ordered preference lists pick the first supported entry.
        assert_eq!(
            negotiate(&[CodecId::Int8, CodecId::Fp16], &[CodecId::Fp32, CodecId::Fp16]),
            CodecId::Fp16
        );
        assert_eq!(negotiate(&[], &SUPPORTED), CodecId::Fp32);
    }

    #[test]
    fn stats_table_counts_and_maxes() {
        let t = CodecStatsTable::new();
        t.record_encode(CodecId::Int8, 4000, 1032, 10, 0.5);
        t.record_encode(CodecId::Int8, 4000, 1032, 5, 0.25);
        t.record_decode(CodecId::Int8, 4000, 1032, 7);
        let s = t.get(CodecId::Int8);
        assert_eq!(s.raw_bytes, 8000);
        assert_eq!(s.wire_bytes, 2064);
        assert_eq!(s.bytes_saved(), 8000 - 2064);
        assert_eq!(s.encodes, 2);
        assert_eq!(s.encode_ns, 15);
        assert_eq!(s.decodes, 1);
        assert_eq!(s.decode_ns, 7);
        assert_eq!(s.max_quant_error, 0.5);
        assert_eq!(t.get(CodecId::Fp16), CodecStats::default());
    }
}
