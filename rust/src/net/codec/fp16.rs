//! IEEE 754 binary16 wire codec: 2 bytes per element, half the fp32 wire
//! volume, round-to-nearest-even conversion in safe integer code (no
//! `half` crate — the container is offline).
//!
//! Deviation from a strict IEEE conversion, chosen for training traffic:
//! **finite** f32 values beyond the fp16 range saturate to ±65504 (the
//! largest finite half) instead of rounding to infinity, so one stray
//! large gradient cannot poison the accumulator with `inf`. Infinities and
//! NaNs propagate unchanged. For `|x| ≤ 65504` the conversion is exactly
//! round-to-nearest-even, so the roundtrip error is at most half an ULP of
//! the fp16 result (≤ `|x|·2⁻¹¹` for normals, ≤ `2⁻²⁵` in the subnormal
//! range) — the bound the property tests pin down.

use anyhow::Result;

use super::{CodecId, WireCodec};

/// f32 → fp16 bit pattern, round-to-nearest-even, saturating (see module
/// docs).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Infinity propagates; NaN collapses to a quiet NaN.
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    let e = exp - 127 + 15; // fp16 biased exponent
    if e >= 0x1f {
        return sign | 0x7bff; // finite overflow saturates to ±65504
    }
    if e <= 0 {
        // Subnormal target range. Below half the smallest subnormal
        // (|x| < 2⁻²⁵) everything rounds to zero.
        if e < -10 {
            return sign;
        }
        let m = man | 0x0080_0000; // implicit leading bit
        let shift = (14 - e) as u32; // 14..=24
        let half = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && half & 1 == 1);
        return sign | (half + u32::from(round_up)) as u16;
    }
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && half & 1 == 1);
    let rounded = half + u32::from(round_up);
    if rounded >= 0x7c00 {
        return sign | 0x7bff; // rounding carried into the infinity slot
    }
    sign | rounded as u16
}

/// fp16 bit pattern → f32 (exact: every half is representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        if man == 0 {
            sign | 0x7f80_0000
        } else {
            sign | 0x7fc0_0000 | (man << 13)
        }
    } else if exp != 0 {
        sign | ((exp + 112) << 23) | (man << 13)
    } else if man == 0 {
        sign
    } else {
        // Subnormal: normalize. value = man · 2⁻²⁴.
        let mut e = 0u32;
        let mut m = man;
        while m & 0x400 == 0 {
            m <<= 1;
            e += 1;
        }
        sign | ((113 - e) << 23) | ((m & 0x3ff) << 13)
    };
    f32::from_bits(bits)
}

/// The binary16 wire codec.
pub struct Fp16Codec;

impl WireCodec for Fp16Codec {
    fn id(&self) -> CodecId {
        CodecId::Fp16
    }

    fn wire_len(&self, raw_len: usize) -> usize {
        debug_assert!(raw_len % 4 == 0);
        raw_len / 2
    }

    fn raw_len(&self, wire_len: usize) -> Result<usize> {
        anyhow::ensure!(wire_len % 2 == 0, "fp16 slab length {wire_len} not f16-aligned");
        Ok(wire_len * 2)
    }

    fn encode(&self, raw: &[u8], dst: &mut Vec<u8>) -> f32 {
        debug_assert!(raw.len() % 4 == 0);
        dst.reserve(raw.len() / 2);
        let mut max_err = 0.0f32;
        for c in raw.chunks_exact(4) {
            let x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            let h = f32_to_f16_bits(x);
            dst.extend_from_slice(&h.to_le_bytes());
            let err = (f16_bits_to_f32(h) - x).abs();
            if err.is_finite() && err > max_err {
                max_err = err;
            }
        }
        max_err
    }

    fn decode(&self, wire: &[u8], dst: &mut Vec<u8>) -> Result<()> {
        self.raw_len(wire.len())?;
        dst.reserve(wire.len() * 2);
        for c in wire.chunks_exact(2) {
            let x = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
            dst.extend_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }

    fn accumulate(&self, acc: &mut [f32], wire: &[u8]) -> Result<()> {
        anyhow::ensure!(
            acc.len() * 2 == wire.len(),
            "fp16 slab/accumulator length mismatch: {} vs {}",
            wire.len(),
            acc.len() * 2
        );
        for (a, c) in acc.iter_mut().zip(wire.chunks_exact(2)) {
            *a += f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_bit_patterns() {
        for (x, h) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (0.5, 0x3800),
            (2.0, 0x4000),
            (65504.0, 0x7bff),             // largest finite half
            (6.103_515_6e-5, 0x0400),      // smallest normal (2⁻¹⁴)
            (5.960_464_5e-8, 0x0001),      // smallest subnormal (2⁻²⁴)
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
        ] {
            assert_eq!(f32_to_f16_bits(x), h, "{x}");
            if x.is_finite() {
                assert_eq!(f16_bits_to_f32(h), x, "{x}");
            }
        }
        assert_eq!(f16_bits_to_f32(0x8000), -0.0);
        assert!(f16_bits_to_f32(0x7e00).is_nan());
        assert!(f32_to_f16_bits(f32::NAN) & 0x7c00 == 0x7c00);
    }

    #[test]
    fn ties_round_to_even_and_overflow_saturates() {
        // 1 + 2⁻¹¹ is exactly halfway between 0x3c00 and 0x3c01 → even.
        assert_eq!(f32_to_f16_bits(1.0 + f32::powi(2.0, -11)), 0x3c00);
        // 1 + 3·2⁻¹¹ is halfway between 0x3c01 and 0x3c02 → even (0x3c02).
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * f32::powi(2.0, -11)), 0x3c02);
        // Anything past the midpoint rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 1.1 * f32::powi(2.0, -11)), 0x3c01);
        // Finite overflow saturates instead of producing infinity.
        assert_eq!(f32_to_f16_bits(1e9), 0x7bff);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfbff);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7bff, "midpoint past max saturates");
        // Exactly 2⁻²⁵ ties to even zero; just above rounds to 2⁻²⁴.
        assert_eq!(f32_to_f16_bits(f32::powi(2.0, -25)), 0x0000);
        assert_eq!(f32_to_f16_bits(1.0001 * f32::powi(2.0, -25)), 0x0001);
    }

    /// The satellite property: for every finite `|x| ≤ 65504` the
    /// roundtrip error is at most half an ULP of the fp16 grid —
    /// `max(|x|·2⁻¹¹, 2⁻²⁵)` — and the result is the *nearest* half (no
    /// neighbor is closer).
    #[test]
    fn roundtrip_error_bounded_by_half_ulp() {
        let mut rng = Rng::new(1717);
        for i in 0..20_000 {
            // Log-uniform magnitudes across the whole fp16 range, plus
            // exact powers of two and subnormals.
            let mag = 10f64.powf(rng.range_f64(-8.0, 4.8));
            let x = (mag * if rng.bool() { -1.0 } else { 1.0 }) as f32;
            let x = if i % 7 == 0 { x.floor() } else { x };
            if !x.is_finite() || x.abs() > 65504.0 {
                continue;
            }
            let h = f32_to_f16_bits(x);
            let rt = f16_bits_to_f32(h);
            let err = (rt - x).abs();
            let bound = (x.abs() * f32::powi(2.0, -11)).max(f32::powi(2.0, -25));
            assert!(
                err <= bound * (1.0 + 1e-6),
                "half-ULP bound violated for {x}: rt={rt}, err={err}, bound={bound}"
            );
            // Nearest-grid-point check against both neighbors.
            for nb in [h.wrapping_sub(1), h.wrapping_add(1)] {
                // Skip wraps across the sign/infinity boundaries.
                if nb & 0x7c00 == 0x7c00 || (nb ^ h) & 0x8000 != 0 {
                    continue;
                }
                let nv = f16_bits_to_f32(nb);
                assert!(
                    err <= (nv - x).abs() + 1e-12,
                    "{x}: neighbor {nv} closer than {rt}"
                );
            }
        }
    }

    #[test]
    fn every_half_roundtrips_exactly_through_f32() {
        // f32 represents all 2¹⁶ half patterns exactly, so
        // half → f32 → half must be the identity (NaNs excluded).
        for h in 0..=u16::MAX {
            if h & 0x7c00 == 0x7c00 && h & 0x3ff != 0 {
                continue; // NaN payloads collapse
            }
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "{h:#06x}");
        }
    }
}
