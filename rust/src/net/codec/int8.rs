//! Per-chunk affine int8 wire codec (AccEPT-style, arXiv 2311.05827).
//!
//! The raw f32 slab is split into chunks of [`CHUNK`] elements; each chunk
//! is quantized independently against its own value range and laid out as
//!
//! ```text
//! +--------------+-------------------+------------------------+
//! | scale f32 LE | zero-point f32 LE | one u8 per element     |
//! +--------------+-------------------+------------------------+
//! ```
//!
//! with `x ≈ zero + scale·q`, `scale = (max − min)/255`, `zero = min`.
//! Asymptotic wire size is `elems + 8·⌈elems/CHUNK⌉` bytes — ~26% of fp32.
//! Per-chunk rounding keeps the max absolute error at `scale/2 =
//! range/510`, comfortably inside the `range/254` contract the property
//! tests assert. A constant chunk (`max == min`) encodes with `scale = 0`
//! and reproduces exactly; chunks whose range overflows f32 (or contains
//! no finite value) degrade to the same constant encoding rather than
//! producing non-finite scales.
//!
//! Chunking restarts at every layer slab (codecs apply per layer, see the
//! parent module), so the layout of a multi-layer payload is computable
//! from the per-layer byte tables alone.

use anyhow::Result;

use super::{CodecId, WireCodec};

/// f32 elements per quantization chunk.
pub const CHUNK: usize = 1024;

/// Chunk header bytes: `f32 scale ‖ f32 zero-point`.
pub const HEADER_BYTES: usize = 8;

/// The per-chunk affine int8 wire codec.
pub struct Int8Codec;

fn read_f32(b: &[u8]) -> f32 {
    f32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

impl WireCodec for Int8Codec {
    fn id(&self) -> CodecId {
        CodecId::Int8
    }

    fn wire_len(&self, raw_len: usize) -> usize {
        debug_assert!(raw_len % 4 == 0);
        let elems = raw_len / 4;
        elems + HEADER_BYTES * ((elems + CHUNK - 1) / CHUNK)
    }

    fn raw_len(&self, wire_len: usize) -> Result<usize> {
        if wire_len == 0 {
            return Ok(0);
        }
        // A full chunk occupies HEADER_BYTES + CHUNK; only the last chunk
        // may be short, so the chunk count is uniquely determined.
        let per = HEADER_BYTES + CHUNK;
        let chunks = (wire_len + per - 1) / per;
        let elems = wire_len
            .checked_sub(HEADER_BYTES * chunks)
            .filter(|&e| e > 0 && (e + CHUNK - 1) / CHUNK == chunks)
            .ok_or_else(|| anyhow::anyhow!("invalid int8 slab length {wire_len}"))?;
        Ok(4 * elems)
    }

    fn encode(&self, raw: &[u8], dst: &mut Vec<u8>) -> f32 {
        debug_assert!(raw.len() % 4 == 0);
        dst.reserve(self.wire_len(raw.len()));
        let mut max_err = 0.0f32;
        for chunk in raw.chunks(4 * CHUNK) {
            // Finite range of the chunk.
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for c in chunk.chunks_exact(4) {
                let v = read_f32(c);
                if v.is_finite() {
                    if v < lo {
                        lo = v;
                    }
                    if v > hi {
                        hi = v;
                    }
                }
            }
            let (scale, zero) = if hi > lo && (hi - lo).is_finite() {
                ((hi - lo) / 255.0, lo)
            } else if lo.is_finite() {
                (0.0, lo) // constant chunk: exact
            } else {
                (0.0, 0.0) // no finite value at all
            };
            dst.extend_from_slice(&scale.to_le_bytes());
            dst.extend_from_slice(&zero.to_le_bytes());
            for c in chunk.chunks_exact(4) {
                let v = read_f32(c);
                let q = if scale > 0.0 {
                    ((v - zero) / scale).round().clamp(0.0, 255.0)
                } else {
                    0.0
                };
                let q = q as u8; // NaN casts to 0, never panics
                dst.push(q);
                let err = (zero + scale * q as f32 - v).abs();
                if err.is_finite() && err > max_err {
                    max_err = err;
                }
            }
        }
        max_err
    }

    fn decode(&self, wire: &[u8], dst: &mut Vec<u8>) -> Result<()> {
        let raw = self.raw_len(wire.len())?;
        let mut elems = raw / 4;
        dst.reserve(raw);
        let mut off = 0usize;
        while elems > 0 {
            let scale = read_f32(&wire[off..off + 4]);
            let zero = read_f32(&wire[off + 4..off + 8]);
            off += HEADER_BYTES;
            let n = elems.min(CHUNK);
            for &q in &wire[off..off + n] {
                dst.extend_from_slice(&(zero + scale * q as f32).to_le_bytes());
            }
            off += n;
            elems -= n;
        }
        Ok(())
    }

    fn accumulate(&self, acc: &mut [f32], wire: &[u8]) -> Result<()> {
        let raw = self.raw_len(wire.len())?;
        anyhow::ensure!(
            acc.len() * 4 == raw,
            "int8 slab/accumulator length mismatch: {} decoded bytes vs {} slots",
            raw,
            acc.len()
        );
        let mut off = 0usize;
        let mut i = 0usize;
        while i < acc.len() {
            let scale = read_f32(&wire[off..off + 4]);
            let zero = read_f32(&wire[off + 4..off + 8]);
            off += HEADER_BYTES;
            let n = (acc.len() - i).min(CHUNK);
            for &q in &wire[off..off + n] {
                acc[i] += zero + scale * q as f32;
                i += 1;
            }
            off += n;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slab;
    use crate::util::rng::Rng;

    fn codec() -> Int8Codec {
        Int8Codec
    }

    fn roundtrip(vals: &[f32]) -> (Vec<f32>, f32) {
        let raw = slab::from_f32s(vals);
        let mut wire = Vec::new();
        let max_err = codec().encode(&raw, &mut wire);
        assert_eq!(wire.len(), codec().wire_len(raw.len()));
        let mut back = Vec::new();
        codec().decode(&wire, &mut back).unwrap();
        (slab::to_f32s(&back), max_err)
    }

    /// The satellite property: per-chunk max abs error ≤ range/254, where
    /// range is that chunk's own max−min.
    #[test]
    fn per_chunk_error_bounded_by_range_over_254() {
        let mut rng = Rng::new(4242);
        for _ in 0..60 {
            let n = 1 + rng.below(3 * CHUNK);
            let scale = 10f64.powf(rng.range_f64(-6.0, 6.0));
            let vals: Vec<f32> =
                (0..n).map(|_| (rng.normal() * scale) as f32).collect();
            let (back, reported) = roundtrip(&vals);
            let mut worst = 0.0f32;
            for (ci, chunk) in vals.chunks(CHUNK).enumerate() {
                let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let bound = (hi - lo) / 254.0;
                for (i, (&x, &y)) in
                    chunk.iter().zip(&back[ci * CHUNK..ci * CHUNK + chunk.len()]).enumerate()
                {
                    let err = (y - x).abs();
                    worst = worst.max(err);
                    assert!(
                        err <= bound * (1.0 + 1e-5) + f32::MIN_POSITIVE,
                        "chunk {ci} elem {i}: err {err} > range/254 = {bound}"
                    );
                }
            }
            // The encoder's own error report covers the worst element.
            assert!(reported >= worst * (1.0 - 1e-5), "{reported} < {worst}");
        }
    }

    #[test]
    fn constant_and_empty_slabs_are_exact() {
        let (back, err) = roundtrip(&[3.25; 2000]);
        assert_eq!(back, vec![3.25; 2000]);
        assert_eq!(err, 0.0);
        let (back, err) = roundtrip(&[]);
        assert!(back.is_empty());
        assert_eq!(err, 0.0);
        // Endpoints of each chunk are reproduced exactly (q = 0 and 255).
        let mut vals = vec![0.0f32; CHUNK];
        vals[0] = -7.0;
        vals[CHUNK - 1] = 9.0;
        let (back, _) = roundtrip(&vals);
        assert_eq!(back[0], -7.0);
        assert_eq!(back[CHUNK - 1], 9.0);
    }

    #[test]
    fn non_finite_inputs_never_panic_or_poison_the_frame() {
        let vals = [1.0f32, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.0];
        let raw = slab::from_f32s(&vals);
        let mut wire = Vec::new();
        codec().encode(&raw, &mut wire);
        let mut back = Vec::new();
        codec().decode(&wire, &mut back).unwrap();
        let back = slab::to_f32s(&back);
        // Finite values stay close; non-finite ones land somewhere finite
        // inside the chunk's range instead of emitting inf/NaN bytes.
        assert!(back.iter().all(|v| v.is_finite()), "{back:?}");
        assert!((back[0] - 1.0).abs() <= (2.0 - 1.0) / 254.0);
        assert!((back[4] - 2.0).abs() <= (2.0 - 1.0) / 254.0);
    }

    #[test]
    fn wire_len_and_raw_len_are_inverse_and_strict() {
        let c = codec();
        for elems in [1usize, 2, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 17] {
            let raw = 4 * elems;
            let wire = c.wire_len(raw);
            assert_eq!(c.raw_len(wire).unwrap(), raw, "{elems} elems");
        }
        // Lengths that no raw slab encodes to are refused.
        for bad in [1usize, HEADER_BYTES, HEADER_BYTES + CHUNK + 1, 2 * HEADER_BYTES] {
            assert!(c.raw_len(bad).is_err(), "accepted invalid length {bad}");
        }
        assert_eq!(c.raw_len(0).unwrap(), 0);
    }

    #[test]
    fn chunk_headers_sit_at_computed_offsets() {
        // Two chunks: elems = CHUNK + 3; second header must start at
        // HEADER_BYTES + CHUNK.
        let mut vals = vec![0.5f32; CHUNK + 3];
        vals[CHUNK] = -1.0;
        vals[CHUNK + 2] = 1.0;
        let raw = slab::from_f32s(&vals);
        let mut wire = Vec::new();
        codec().encode(&raw, &mut wire);
        let second = HEADER_BYTES + CHUNK;
        let scale = f32::from_le_bytes(wire[second..second + 4].try_into().unwrap());
        let zero = f32::from_le_bytes(wire[second + 4..second + 8].try_into().unwrap());
        assert_eq!(zero, -1.0);
        assert!((scale - 2.0 / 255.0).abs() < 1e-9);
    }
}
