//! Reusable byte-slab pool for the PS wire path.
//!
//! Every multi-megabyte buffer on the steady-state path — pull-reply
//! assembly on the server, per-layer gradient slabs on the worker, received
//! tensor frames on both — is checked out of a [`SlabPool`] pre-sized from
//! the byte tables that already exist (`Shared::layer_bytes` server-side,
//! the compiled `ExecPlan` worker-side) and recycled across iterations, so
//! after warm-up the wire path performs **zero slab allocations**
//! ([`PoolStats::allocations`] stays flat — the property the pool tests and
//! `benches/ps_throughput.rs` pin down).
//!
//! Ownership shapes:
//!
//! * [`SlabCheckout`] — exclusive, mutable (`DerefMut<Target = Vec<u8>>`);
//!   returned to the pool on drop.
//! * [`Arc<PooledSlab>`] — frozen, shared, immutable; returned to the pool
//!   when the last clone drops. This is what the server's reply cache holds
//!   and what lets one assembled broadcast slab serve every worker.
//! * [`SlabSlice`] — a `(slab, offset, len)` view into a shared slab; the
//!   worker hands each layer a view of the reply frame it arrived in.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex, Weak};

use crate::obs::Counter;
use crate::util::sync::lock_or_die;

/// Buffers retained by a pool beyond this count are dropped instead of
/// recycled (bounds worst-case memory when segment shapes change). Callers
/// with a known working set (e.g. the worker, which holds one gradient
/// slab per layer) should size the pool explicitly via
/// [`SlabPool::with_max_retained`].
const DEFAULT_MAX_RETAINED: usize = 32;

/// A returned buffer whose capacity exceeds this is dropped instead of
/// parked: one pathological checkout (e.g. a frame near the 1 GiB protocol
/// cap) must not pin its memory in the pool — the same hygiene the
/// transport applies to its receive scratch.
const MAX_RETAINED_BUF_BYTES: usize = 64 << 20;

/// Counters exposed for observability, benches, and the zero-allocation
/// steady-state tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Total checkouts served (recycled + freshly allocated).
    pub checkouts: u64,
    /// Checkouts served from the free list without allocating.
    pub recycled: u64,
    /// Checkouts that had to allocate a fresh buffer. Flat after warm-up.
    pub allocations: u64,
    /// Buffers currently parked on the free list.
    pub retained: usize,
}

/// A bounded pool of reusable byte buffers (see module docs).
pub struct SlabPool {
    free: Mutex<Vec<Vec<u8>>>,
    max_retained: usize,
    // Per-pool counters live in the unified obs registry (one
    // `inst="N"`-labelled series per pool); `stats()` reads them back so
    // the historical getter surface is a thin adapter over one source of
    // truth (docs/OBSERVABILITY.md).
    checkouts: Counter,
    recycled: Counter,
    allocations: Counter,
}

impl SlabPool {
    /// A pool retaining up to the default number of warm buffers.
    pub fn new() -> Arc<SlabPool> {
        SlabPool::with_max_retained(DEFAULT_MAX_RETAINED)
    }

    /// A pool retaining at most `max_retained` idle buffers.
    pub fn with_max_retained(max_retained: usize) -> Arc<SlabPool> {
        let inst = crate::obs::next_inst();
        Arc::new(SlabPool {
            free: Mutex::new(Vec::new()),
            max_retained,
            checkouts: crate::obs_counter!("dynacomm_pool_checkouts_total", "", inst),
            recycled: crate::obs_counter!("dynacomm_pool_recycled_total", "", inst),
            allocations: crate::obs_counter!("dynacomm_pool_allocations_total", "", inst),
        })
    }

    /// Best-fit grab: the smallest free buffer whose capacity covers `cap`,
    /// else a fresh allocation (counted).
    // dynalint: hot-path
    fn grab(&self, cap: usize) -> Vec<u8> {
        self.checkouts.inc();
        let mut free = lock_or_die(&self.free, "pool.free");
        let mut best: Option<usize> = None;
        for (i, b) in free.iter().enumerate() {
            if b.capacity() < cap {
                continue;
            }
            let better = match best {
                None => true,
                Some(j) => b.capacity() < free[j].capacity(),
            };
            if better {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                self.recycled.inc();
                free.swap_remove(i)
            }
            None => {
                drop(free);
                self.allocations.inc();
                Vec::with_capacity(cap)
            }
        }
    }

    /// Check out an **empty** buffer with at least `cap` bytes of capacity
    /// — for `extend_from_slice`-style assembly (no zero-fill anywhere).
    // dynalint: hot-path
    pub fn checkout(self: &Arc<Self>, cap: usize) -> SlabCheckout {
        let mut buf = self.grab(cap);
        buf.clear();
        SlabCheckout { buf: Some(buf), pool: Arc::downgrade(self) }
    }

    /// Check out a buffer of exactly `len` **initialized** bytes whose
    /// contents are unspecified (possibly stale from a previous checkout) —
    /// for paths that overwrite every byte, like reading a frame off a
    /// socket. Only growth past the buffer's previous length zero-fills, so
    /// a warm pool never re-memsets.
    // dynalint: hot-path
    pub fn checkout_filled(self: &Arc<Self>, len: usize) -> SlabCheckout {
        let mut buf = self.grab(len);
        if buf.len() < len {
            buf.resize(len, 0);
        } else {
            buf.truncate(len);
        }
        SlabCheckout { buf: Some(buf), pool: Arc::downgrade(self) }
    }

    /// Park a buffer back on the free list (its capacity is the asset; the
    /// length/contents are left as-is so refills skip the memset).
    /// Oversized buffers are dropped, not parked — see
    /// [`MAX_RETAINED_BUF_BYTES`].
    // dynalint: hot-path
    fn put(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > MAX_RETAINED_BUF_BYTES {
            return;
        }
        let mut free = lock_or_die(&self.free, "pool.free");
        if free.len() < self.max_retained {
            free.push(buf);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            checkouts: self.checkouts.get(),
            recycled: self.recycled.get(),
            allocations: self.allocations.get(),
            retained: lock_or_die(&self.free, "pool.free").len(),
        }
    }
}

impl fmt::Debug for SlabPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SlabPool({:?})", self.stats())
    }
}

/// An exclusively-owned pooled buffer (`DerefMut<Target = Vec<u8>>`).
/// Returns to its pool on drop; [`SlabCheckout::freeze`] converts it into a
/// shared [`PooledSlab`] instead.
pub struct SlabCheckout {
    /// `Some` until frozen or dropped.
    buf: Option<Vec<u8>>,
    pool: Weak<SlabPool>,
}

impl SlabCheckout {
    /// Seal the buffer into a shared, immutable slab. The bytes return to
    /// the pool when the last `Arc` clone (and every [`SlabSlice`] over it)
    /// drops.
    // dynalint: hot-path
    pub fn freeze(mut self) -> Arc<PooledSlab> {
        let buf = self.buf.take().expect("checkout already consumed");
        // dynalint: allow(alloc, Weak refcount bump hands the pool pointer over)
        Arc::new(PooledSlab { buf, pool: self.pool.clone() })
    }
}

impl Deref for SlabCheckout {
    type Target = Vec<u8>;

    fn deref(&self) -> &Vec<u8> {
        self.buf.as_ref().expect("checkout already consumed")
    }
}

impl DerefMut for SlabCheckout {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        self.buf.as_mut().expect("checkout already consumed")
    }
}

impl Drop for SlabCheckout {
    fn drop(&mut self) {
        if let (Some(buf), Some(pool)) = (self.buf.take(), self.pool.upgrade()) {
            pool.put(buf);
        }
    }
}

impl fmt::Debug for SlabCheckout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SlabCheckout(len={})", self.buf.as_ref().map_or(0, Vec::len))
    }
}

/// A frozen, shared pooled buffer (`Deref<Target = [u8]>`); see
/// [`SlabCheckout::freeze`]. [`PooledSlab::detached`] wraps a plain vector
/// with no backing pool (tests, cold paths).
pub struct PooledSlab {
    buf: Vec<u8>,
    pool: Weak<SlabPool>,
}

impl PooledSlab {
    /// A shared slab that is not connected to any pool (dropping it simply
    /// frees the vector).
    pub fn detached(buf: Vec<u8>) -> Arc<PooledSlab> {
        Arc::new(PooledSlab { buf, pool: Weak::new() })
    }
}

impl Deref for PooledSlab {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        &self.buf
    }
}

impl Drop for PooledSlab {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.upgrade() {
            pool.put(std::mem::take(&mut self.buf));
        }
    }
}

impl fmt::Debug for PooledSlab {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PooledSlab(len={})", self.buf.len())
    }
}

/// A shared, immutable `(slab, offset, len)` view into a [`PooledSlab`]:
/// the puller hands each layer a slice of the reply frame it arrived in,
/// so the pull path performs no per-layer copies between the socket and
/// tensor materialization — and the frame returns to the pool when the
/// last view drops.
#[derive(Clone)]
pub struct SlabSlice {
    buf: Arc<PooledSlab>,
    off: usize,
    len: usize,
}

impl SlabSlice {
    /// Panics if `[off, off + len)` is out of bounds — callers validate
    /// offsets (e.g. against the `ExecPlan` tables) before slicing.
    pub fn new(buf: Arc<PooledSlab>, off: usize, len: usize) -> SlabSlice {
        assert!(off + len <= buf.len(), "slab slice out of bounds");
        SlabSlice { buf, off, len }
    }

    /// Wrap an owned vector as a full-range view (no backing pool).
    pub fn from_vec(buf: Vec<u8>) -> SlabSlice {
        let len = buf.len();
        SlabSlice { buf: PooledSlab::detached(buf), off: 0, len }
    }

    /// A sub-view relative to this view's range (same backing slab).
    // dynalint: hot-path
    pub fn slice(&self, off: usize, len: usize) -> SlabSlice {
        assert!(off + len <= self.len, "slab sub-slice out of bounds");
        // dynalint: allow(alloc, Arc refcount bump shares the backing slab)
        SlabSlice { buf: self.buf.clone(), off: self.off + off, len }
    }
}

impl Deref for SlabSlice {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }
}

impl fmt::Debug for SlabSlice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SlabSlice(off={}, len={})", self.off, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_is_empty_with_capacity() {
        let pool = SlabPool::new();
        let co = pool.checkout(1024);
        assert!(co.is_empty());
        assert!(co.capacity() >= 1024);
        drop(co);
        assert_eq!(pool.stats().retained, 1);
    }

    #[test]
    fn three_iterations_allocate_only_in_the_first() {
        // The satellite contract: checkout/return across iterations
        // performs zero new allocations after warm-up.
        let pool = SlabPool::new();
        let sizes = [1024usize, 4096, 256];
        for iter in 0..3 {
            // Hold all checkouts live at once, as an iteration does.
            let mut held = Vec::new();
            for &s in &sizes {
                let mut co = pool.checkout(s);
                co.extend_from_slice(&vec![0xABu8; s]);
                held.push(co);
            }
            drop(held);
            let st = pool.stats();
            assert_eq!(
                st.allocations,
                sizes.len() as u64,
                "iteration {iter}: steady state must not allocate"
            );
            assert_eq!(st.checkouts, ((iter + 1) * sizes.len()) as u64);
        }
        let st = pool.stats();
        assert_eq!(st.recycled, 2 * sizes.len() as u64);
        assert_eq!(st.retained, sizes.len());
    }

    #[test]
    fn best_fit_prefers_smallest_adequate_buffer() {
        let pool = SlabPool::new();
        let (a, b) = (pool.checkout(100), pool.checkout(10_000));
        drop(a);
        drop(b);
        // A 50-byte request must take the 100-capacity buffer, leaving the
        // big one parked.
        let co = pool.checkout(50);
        assert!(co.capacity() < 10_000);
        let free_caps: Vec<usize> =
            pool.free.lock().unwrap().iter().map(Vec::capacity).collect();
        assert_eq!(free_caps.len(), 1);
        assert!(free_caps[0] >= 10_000);
    }

    #[test]
    fn checkout_filled_is_sized_and_grow_only() {
        let pool = SlabPool::new();
        let mut co = pool.checkout(64);
        co.extend_from_slice(&[7u8; 64]);
        drop(co);
        // Refill smaller: contents unspecified, but length exact and no
        // fresh allocation.
        let co = pool.checkout_filled(16);
        assert_eq!(co.len(), 16);
        assert_eq!(pool.stats().allocations, 1);
        drop(co);
        // Refill larger than capacity: allocates (or grows) once.
        let co = pool.checkout_filled(256);
        assert_eq!(co.len(), 256);
        drop(co);
    }

    #[test]
    fn freeze_returns_to_pool_on_last_view_drop() {
        let pool = SlabPool::new();
        let mut co = pool.checkout(100);
        co.extend_from_slice(&(0u8..100).collect::<Vec<u8>>());
        let slab = co.freeze();
        let a = SlabSlice::new(slab.clone(), 10, 20);
        let b = a.slice(5, 5);
        assert_eq!(&a[..], &(10u8..30).collect::<Vec<u8>>()[..]);
        assert_eq!(&b[..], &(15u8..20).collect::<Vec<u8>>()[..]);
        drop(slab);
        assert_eq!(pool.stats().retained, 0, "views still hold the slab");
        drop(a);
        drop(b);
        assert_eq!(pool.stats().retained, 1, "slab returned on last drop");
        // And the returned buffer is recycled by the next checkout.
        let _co = pool.checkout(50);
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn detached_slab_and_from_vec_need_no_pool() {
        let s = SlabSlice::from_vec(vec![1, 2, 3, 4]);
        assert_eq!(&s[..], &[1, 2, 3, 4]);
        assert_eq!(s.slice(1, 2).len(), 2);
        let d = PooledSlab::detached(vec![9; 8]);
        assert_eq!(d.len(), 8);
    }

    #[test]
    fn checkout_outlives_its_pool() {
        let pool = SlabPool::new();
        let co = pool.checkout(10);
        let slab = {
            let mut c2 = pool.checkout(10);
            c2.push(1);
            c2.freeze()
        };
        drop(pool);
        // Returning to a dead pool is a no-op, not a panic.
        drop(co);
        drop(slab);
    }

    #[test]
    fn retention_is_bounded() {
        let pool = SlabPool::with_max_retained(2);
        let held: Vec<SlabCheckout> = (0..4).map(|_| pool.checkout(64)).collect();
        drop(held);
        assert_eq!(pool.stats().retained, 2);
    }

    #[test]
    fn oversized_buffers_are_dropped_not_parked() {
        // One near-cap frame must not pin its memory in the pool.
        let pool = SlabPool::new();
        let big = pool.checkout(MAX_RETAINED_BUF_BYTES + 1);
        drop(big);
        assert_eq!(pool.stats().retained, 0, "oversized buffer was parked");
        // Ordinary buffers still recycle.
        drop(pool.checkout(1024));
        assert_eq!(pool.stats().retained, 1);
    }

    #[test]
    #[should_panic]
    fn slab_slice_rejects_out_of_bounds() {
        let buf = PooledSlab::detached(vec![0u8; 8]);
        let _ = SlabSlice::new(buf, 4, 8);
    }
}
