//! Deterministic fault injection for the wire (`docs/FAULTS.md`).
//!
//! A [`FaultProxy`] is a frame-aware TCP proxy: it listens on loopback,
//! forwards every `[u32 len][payload]` frame (`docs/WIRE.md`) between each
//! accepted client and the real target, and — per frame — may delay it,
//! sever the connection cleanly between frames, or kill it **mid-frame**
//! (header plus half the payload, then RST-ish shutdown), exercising every
//! partial-read path in the transport.
//!
//! The schedule is a **pure function** of
//! `(seed, connection index, direction, frame index, opcode)` — no shared
//! RNG stream, no timing dependence — so the same seed replays the same
//! faults no matter how threads interleave, and two runs of the same
//! scenario can be asserted identical event-for-event
//! (`tests/churn_integration.rs`). Every decision that fires is recorded
//! in an event log ordered by `(conn, dir, frame)`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::rng::Rng;
use crate::util::sync::lock_or_die;

/// What the proxy does to one forwarded frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Forward untouched.
    Pass,
    /// Forward after sleeping this many milliseconds.
    DelayMs(u64),
    /// Drop the frame and sever the connection between frames — a clean
    /// peer death at a frame boundary.
    DropConn,
    /// Forward the header and half the payload, then sever — a peer dying
    /// mid-write, the worst case for the receiver's framing.
    KillMidFrame,
}

/// Which way a frame was traveling when the decision was made.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Dir {
    /// Client → target (requests).
    Up,
    /// Target → client (replies).
    Down,
}

/// One fired (non-`Pass`) decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Accept-order index of the proxied connection.
    pub conn: u32,
    pub dir: Dir,
    /// Frame index within `(conn, dir)`, from 0.
    pub frame: u64,
    /// The frame's wire opcode (`docs/WIRE.md`).
    pub opcode: u8,
    pub action: FaultAction,
}

/// The fault schedule's knobs. Probabilities are evaluated in the order
/// `drop_conn`, `kill_mid_frame`, `delay` from a single uniform draw, so
/// they must sum to at most 1.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Everything derives from this: same seed, same faults.
    pub seed: u64,
    /// Probability a frame severs its connection at the frame boundary.
    pub drop_conn_p: f64,
    /// Probability a frame is cut off mid-payload.
    pub kill_mid_frame_p: f64,
    /// Probability a frame is delayed.
    pub delay_p: f64,
    /// Upper bound (inclusive) on an injected delay, ms.
    pub delay_max_ms: u64,
    /// Restrict faults to these opcodes; `None` targets every frame.
    /// Frames outside the set always pass (and log nothing).
    pub only_opcodes: Option<Vec<u8>>,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 0,
            drop_conn_p: 0.0,
            kill_mid_frame_p: 0.0,
            delay_p: 0.0,
            delay_max_ms: 0,
            only_opcodes: None,
        }
    }
}

impl FaultSpec {
    /// The deterministic per-frame decision — a pure function of the
    /// spec and the frame's coordinates, usable without a proxy (unit
    /// tests pin schedules against it).
    pub fn decide(&self, conn: u32, dir: Dir, frame: u64, opcode: u8) -> FaultAction {
        if let Some(ops) = &self.only_opcodes {
            if !ops.contains(&opcode) {
                return FaultAction::Pass;
            }
        }
        // FNV-1a over the coordinates keys an independent PRNG per frame:
        // the decision cannot depend on traffic order or thread timing.
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ self.seed;
        for b in conn
            .to_le_bytes()
            .into_iter()
            .chain([dir as u8, opcode])
            .chain(frame.to_le_bytes())
        {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        let mut rng = Rng::new(h);
        let x = rng.f64();
        if x < self.drop_conn_p {
            FaultAction::DropConn
        } else if x < self.drop_conn_p + self.kill_mid_frame_p {
            FaultAction::KillMidFrame
        } else if x < self.drop_conn_p + self.kill_mid_frame_p + self.delay_p {
            FaultAction::DelayMs(rng.below(self.delay_max_ms as usize + 1) as u64)
        } else {
            FaultAction::Pass
        }
    }
}

/// A running fault proxy in front of one target address.
pub struct FaultProxy {
    addr: SocketAddr,
    shutting_down: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    events: Arc<Mutex<Vec<FaultEvent>>>,
    /// Live proxied sockets (client side, target side) so shutdown can
    /// fail every blocked relay read.
    socks: Arc<Mutex<Vec<TcpStream>>>,
}

impl FaultProxy {
    /// Listen on an ephemeral loopback port and relay every accepted
    /// connection to `target` under `spec`'s schedule.
    pub fn start(target: SocketAddr, spec: FaultSpec) -> Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0").context("bind fault proxy")?;
        let addr = listener.local_addr()?;
        let shutting_down = Arc::new(AtomicBool::new(false));
        let events = Arc::new(Mutex::new(Vec::new()));
        let socks = Arc::new(Mutex::new(Vec::new()));
        let (sd, ev, sk) = (shutting_down.clone(), events.clone(), socks.clone());
        let accept_thread = std::thread::Builder::new()
            .name(format!("fault-proxy-{}", addr.port()))
            .spawn(move || {
                let spec = Arc::new(spec);
                let next_conn = AtomicU32::new(0);
                let mut relays = Vec::new();
                loop {
                    let Ok((client, _)) = listener.accept() else { break };
                    if sd.load(Ordering::SeqCst) {
                        let _ = client.shutdown(Shutdown::Both);
                        break;
                    }
                    let conn = next_conn.fetch_add(1, Ordering::SeqCst);
                    let Ok(server) = TcpStream::connect(target) else {
                        // Target gone (e.g. a killed shard): drop the
                        // client so its dialer sees the death too.
                        let _ = client.shutdown(Shutdown::Both);
                        continue;
                    };
                    let pairs = [
                        (client.try_clone(), server.try_clone(), Dir::Up),
                        (server.try_clone(), client.try_clone(), Dir::Down),
                    ];
                    {
                        let mut s = lock_or_die(&sk, "fault.socks");
                        s.push(client);
                        s.push(server);
                    }
                    for (src, dst, dir) in pairs {
                        let (Ok(src), Ok(dst)) = (src, dst) else { continue };
                        let (spec, ev) = (spec.clone(), ev.clone());
                        relays.push(std::thread::spawn(move || {
                            relay(src, dst, &spec, conn, dir, &ev);
                        }));
                    }
                }
                for r in relays {
                    let _ = r.join();
                }
            })?;
        Ok(FaultProxy { addr, shutting_down, accept_thread: Some(accept_thread), events, socks })
    }

    /// The loopback address clients dial instead of the real target.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Every fired (non-`Pass`) decision so far, ordered by
    /// `(conn, dir, frame)` — thread interleaving cannot reorder it, so
    /// same-seed runs compare equal element-for-element.
    pub fn events(&self) -> Vec<FaultEvent> {
        let mut ev = lock_or_die(&self.events, "fault.events").clone();
        ev.sort_by_key(|e| (e.conn, e.dir, e.frame));
        ev
    }

    /// Sever every proxied connection and stop accepting.
    pub fn shutdown(&mut self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        for s in lock_or_die(&self.socks, "fault.socks").iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Relay frames one way until EOF, an I/O error, or an injected kill.
fn relay(
    mut src: TcpStream,
    mut dst: TcpStream,
    spec: &FaultSpec,
    conn: u32,
    dir: Dir,
    events: &Mutex<Vec<FaultEvent>>,
) {
    let mut frame = 0u64;
    let mut payload = Vec::new();
    loop {
        let mut hdr = [0u8; 4];
        if src.read_exact(&mut hdr).is_err() {
            break;
        }
        let len = u32::from_le_bytes(hdr) as usize;
        payload.resize(len, 0);
        if src.read_exact(&mut payload).is_err() {
            break;
        }
        let opcode = payload.first().copied().unwrap_or(0);
        let action = spec.decide(conn, dir, frame, opcode);
        if action != FaultAction::Pass {
            lock_or_die(events, "fault.events").push(FaultEvent {
                conn,
                dir,
                frame,
                opcode,
                action,
            });
        }
        match action {
            FaultAction::Pass => {}
            FaultAction::DelayMs(ms) => std::thread::sleep(Duration::from_millis(ms)),
            FaultAction::DropConn => {
                let _ = src.shutdown(Shutdown::Both);
                let _ = dst.shutdown(Shutdown::Both);
                return;
            }
            FaultAction::KillMidFrame => {
                let _ = dst.write_all(&hdr);
                let _ = dst.write_all(&payload[..len / 2]);
                let _ = src.shutdown(Shutdown::Both);
                let _ = dst.shutdown(Shutdown::Both);
                return;
            }
        }
        if dst.write_all(&hdr).is_err() || dst.write_all(&payload).is_err() {
            break;
        }
        frame += 1;
    }
    // EOF or error: propagate the close so neither side hangs.
    let _ = src.shutdown(Shutdown::Both);
    let _ = dst.shutdown(Shutdown::Both);
}

/// Group an event log per connection+direction — the stable unit for
/// cross-run determinism assertions.
pub fn events_by_stream(events: &[FaultEvent]) -> HashMap<(u32, Dir), Vec<FaultEvent>> {
    let mut map: HashMap<(u32, Dir), Vec<FaultEvent>> = HashMap::new();
    for e in events {
        map.entry((e.conn, e.dir)).or_default().push(*e);
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Connection, Message, PROTOCOL_VERSION};

    /// The schedule is a pure function: identical coordinates, identical
    /// decision; a different seed decorrelates.
    #[test]
    fn decisions_are_pure_and_seeded()  {
        let spec = FaultSpec {
            seed: 7,
            drop_conn_p: 0.2,
            kill_mid_frame_p: 0.2,
            delay_p: 0.3,
            delay_max_ms: 5,
            only_opcodes: None,
        };
        let mut decisions = Vec::new();
        for conn in 0..4 {
            for frame in 0..64 {
                for op in [1u8, 3, 13] {
                    let a = spec.decide(conn, Dir::Up, frame, op);
                    assert_eq!(a, spec.decide(conn, Dir::Up, frame, op));
                    decisions.push(a);
                }
            }
        }
        assert!(decisions.iter().any(|a| *a != FaultAction::Pass), "schedule never fired");
        assert!(decisions.iter().any(|a| *a == FaultAction::Pass), "schedule always fired");
        let other = FaultSpec { seed: 8, ..spec.clone() };
        let redrawn: Vec<FaultAction> = (0..4)
            .flat_map(|c| (0..64).flat_map(move |f| [1u8, 3, 13].map(|op| (c, f, op))))
            .map(|(c, f, op)| other.decide(c, Dir::Up, f, op))
            .collect();
        assert_ne!(decisions, redrawn, "seeds must decorrelate schedules");
    }

    #[test]
    fn opcode_filter_masks_everything_else() {
        let spec = FaultSpec {
            seed: 1,
            drop_conn_p: 1.0,
            only_opcodes: Some(vec![3]),
            ..FaultSpec::default()
        };
        for frame in 0..32 {
            assert_eq!(spec.decide(0, Dir::Up, frame, 1), FaultAction::Pass);
            assert_eq!(spec.decide(0, Dir::Up, frame, 3), FaultAction::DropConn);
        }
    }

    /// A fault-free proxy is transparent: a framed round-trip through it
    /// is byte-identical to a direct one.
    #[test]
    fn passthrough_proxy_is_transparent() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let target = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            let m = conn.recv().unwrap();
            conn.send(&m).unwrap();
        });
        let mut proxy = FaultProxy::start(target, FaultSpec::default()).unwrap();
        let mut conn =
            Connection::new(TcpStream::connect(proxy.addr()).unwrap(), None);
        let sent = Message::Hello { worker: 9, version: PROTOCOL_VERSION };
        conn.send(&sent).unwrap();
        assert_eq!(conn.recv().unwrap(), sent);
        echo.join().unwrap();
        assert!(proxy.events().is_empty(), "no faults configured, none may fire");
        proxy.shutdown();
    }

    /// A mid-frame kill delivers a truncated frame: the receiver must
    /// error out (never hang, never misparse) and the event log records
    /// exactly what fired.
    #[test]
    fn mid_frame_kill_truncates_and_logs() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let target = listener.local_addr().unwrap();
        let srv = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            conn.recv()
        });
        let spec = FaultSpec { kill_mid_frame_p: 1.0, ..FaultSpec::default() };
        let mut proxy = FaultProxy::start(target, spec).unwrap();
        let mut conn =
            Connection::new(TcpStream::connect(proxy.addr()).unwrap(), None);
        // The send may or may not error (the kill races the local write
        // buffer); the receiving side MUST error.
        let _ = conn.send(&Message::Pull { iter: 0, lo: 0, hi: 4 });
        assert!(srv.join().unwrap().is_err(), "truncated frame must fail the recv");
        let ev = proxy.events();
        assert_eq!(ev.len(), 1, "{ev:?}");
        assert_eq!(ev[0].action, FaultAction::KillMidFrame);
        assert_eq!(ev[0].opcode, 1, "Pull's opcode");
        assert_eq!((ev[0].conn, ev[0].dir, ev[0].frame), (0, Dir::Up, 0));
        proxy.shutdown();
    }
}
