//! Framed message transport over TCP.
//!
//! Wire format (specified in full in `docs/WIRE.md`): `u32 LE length` (of
//! everything after it) + `u8 opcode` + payload. Tensor payloads are
//! opaque little-endian f32 byte slabs ([`crate::net::slab`]) carried in
//! [`Message::PullReply`] / [`Message::Push`].
//!
//! The hot path is **copy-free around the slab**: [`MessageRef`] borrows
//! its tensor payload, [`Connection::send_ref`] writes `[header][slab]`
//! with `write_vectored` (no memcpy of multi-MB slabs into a frame
//! buffer), [`Connection::recv_ref`] decodes with the slab still borrowed
//! from the receive scratch, and [`Connection::recv_pooled`] reads the
//! frame straight into a [`crate::net::pool::SlabPool`] checkout and hands
//! back [`SlabSlice`] views. The wire bytes are identical to the legacy
//! contiguous encoding ([`Message::encode_into`]), which is kept as the
//! reference implementation the property tests compare against.
//!
//! Protocol v3 added negotiated wire codecs ([`crate::net::codec`]):
//! tensor slabs may be fp16- or int8-compressed, with the codec id carried
//! in the top 2 bits of the slab-length field. Protocol v4 adds the
//! synchronization subsystem's wire surface ([`crate::ps::sync`]):
//! `PullReply` carries the `applied` iteration of the snapshot it serves
//! (the staleness signal SSP/ASP workers measure), and the
//! `SyncPropose`/`SyncAgree` registration frames fail mismatched
//! worker/server sync configurations loudly. fp32 `Push` frames remain
//! byte-identical to v2. Protocol v5 adds the hierarchical aggregation
//! tier's registration frame ([`crate::ps::agg`], `docs/TOPOLOGY.md`):
//! `AggHello` carries a [`PeerRole`] plus the number of edge workers the
//! peer aggregates, so a regional aggregator can register upstream as one
//! weighted super-worker. Protocol v7 adds the fleet-tracing surface
//! (`docs/OBSERVABILITY.md`): `Push`/`PullReply` frames may carry a
//! trailing 13-byte [`TraceCtx`] (trace id + sender span id) after the
//! slab, and the `ClockProbe`/`ClockReply` frames implement the NTP-style
//! four-timestamp clock-offset probe ([`crate::obs::clock`]).

use std::io::{IoSlice, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::net::codec::CodecId;
use crate::net::pool::{SlabPool, SlabSlice};
use crate::ps::sync::SyncMode;

/// Hard ceiling on a frame's payload size (corruption guard). Also bounds
/// tensor slabs to 30 bits, which is what frees the top 2 bits of the
/// slab-length field to carry the codec tag (see [`SLAB_LEN_MASK`]).
const MAX_FRAME: usize = 1 << 30;

/// Low 30 bits of a tensor frame's slab-length field hold the byte count;
/// the top 2 bits hold the [`CodecId::tag`] of the codec that encoded the
/// slab. Tag 0 is fp32, so fp32 frames are byte-identical to protocol v2.
const SLAB_LEN_MASK: u32 = (1 << 30) - 1;

/// The slab-length field a tensor frame carries for `len` bytes of
/// `codec`-encoded payload.
fn slab_len_field(codec: CodecId, len: usize) -> u32 {
    debug_assert!(len < 1 << 30, "slab of {len} bytes overflows the length field");
    (len as u32) | ((codec.tag() as u32) << 30)
}

/// Warm receive-buffer capacity retained across frames. One oversized
/// frame (up to the 1 GiB [`MAX_FRAME`] cap) must not pin its capacity for
/// the life of the connection: the buffer is shrunk back to this bound
/// before the next smaller frame is read.
const RECV_RETAIN_MAX: usize = 16 << 20;

/// Version of the wire protocol this build speaks (`docs/WIRE.md`; v1 was
/// the unversioned slab protocol, v2 added versioned registration, v3
/// added negotiated wire codecs). v4 adds the pluggable synchronization
/// subsystem's surface: `PullReply` gains an `applied: u64` field (the
/// server's applied iteration for the served snapshot — how SSP/ASP
/// workers measure staleness) and the `SyncPropose`/`SyncAgree`
/// registration frames carry the sync mode + staleness bound. A v3 peer
/// would misparse the widened `PullReply`, so the version is bumped and
/// mixed deployments fail loudly at registration time: the server rejects
/// a mismatched `Hello`, and the worker rejects a mismatched `HelloAck`.
/// v5 adds the hierarchical-tier registration frame: `AggHello` (opcode
/// 12) identifies an aggregator session and its worker-count weight
/// (`docs/TOPOLOGY.md`). Every v4 frame is byte-identical under v5, but a
/// v4 server would reject the unknown opcode, hence the bump. v6 adds the
/// mid-run join surface (`docs/FAULTS.md`): `SnapshotReq` (opcode 13)
/// asks for the full parameter state of a layer range and `SnapshotReply`
/// (opcode 14) carries it back with the server's clock and configured
/// fleet size, so a late worker adopts state and enters the barrier at
/// the correct weight. Every pre-v6 frame is byte-identical; the bump
/// exists because a v5 server would reject the join request an elastic
/// fleet depends on. v7 adds the fleet-tracing surface: `Push` and
/// `PullReply` frames may carry a trailing [`TraceCtx`] after the slab
/// (distributed-trace propagation — the sender's span id becomes the
/// receiver's remote parent), and `ClockProbe` (opcode 15) /
/// `ClockReply` (opcode 16) implement the four-timestamp clock-offset
/// probe. A context-free v6 tensor frame stays byte-identical and is
/// still accepted for one version per the usual compat rule; the bump
/// exists because a v6 peer would reject a context-carrying frame as
/// trailing garbage and the clock frames as unknown opcodes.
pub const PROTOCOL_VERSION: u16 = 7;

/// The role a peer announces in an [`Message::AggHello`] registration
/// frame (v5): a plain edge worker, or a regional aggregator acting as one
/// super-worker for `workers` edge devices (`docs/TOPOLOGY.md`). The wire
/// tag is one byte; tags past [`PeerRole::Regional`] are rejected by the
/// decoder so a corrupted role can never register with a bogus weight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerRole {
    /// A single edge device (weight 1).
    Edge,
    /// A regional aggregator speaking for its whole worker group.
    Regional,
}

impl PeerRole {
    pub fn tag(&self) -> u8 {
        match self {
            PeerRole::Edge => 0,
            PeerRole::Regional => 1,
        }
    }

    pub fn from_tag(tag: u8) -> Option<PeerRole> {
        match tag {
            0 => Some(PeerRole::Edge),
            1 => Some(PeerRole::Regional),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PeerRole::Edge => "edge",
            PeerRole::Regional => "regional",
        }
    }
}

/// Distributed-tracing context (v7), carried as a trailing 13-byte block
/// after the tensor slab of `Push`/`PullReply` frames so the fixed slab
/// offsets of every pre-v7 consumer stay valid. Layout: `u64 LE trace id`
/// (hash of run seed + iteration — one id per logical iteration fleet
/// wide), `u32 LE sender span id`, `u8 flags`. The receiver records its
/// own span (apply/fan-in/decode) with the sender's span id as remote
/// parent, which is what lets the merged Chrome trace stitch
/// worker→agg→shard causality with flow arrows (`docs/OBSERVABILITY.md`).
///
/// Flags: bit 0 ([`TraceCtx::FLAG_SAMPLED`]) must be set — a context is
/// only attached when tracing is armed; bit 1 ([`TraceCtx::FLAG_REPLY`])
/// marks reply-direction contexts (`PullReply`), whose link is an
/// arrow-only stitch rather than a containment parent (the server's
/// assemble span ends before the worker's decode begins). All other bits
/// are reserved-must-be-zero and rejected by the decoder, as is a context
/// of any length other than exactly 13 bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub parent_span: u32,
    pub flags: u8,
}

impl TraceCtx {
    /// On-wire size of a trace context: trace id + span id + flags.
    pub const WIRE_LEN: usize = 8 + 4 + 1;
    /// The context was recorded by an armed tracer (always set).
    pub const FLAG_SAMPLED: u8 = 1 << 0;
    /// Reply-direction context (`PullReply`): stitch an arrow, not a
    /// containment parent.
    pub const FLAG_REPLY: u8 = 1 << 1;
    const KNOWN_FLAGS: u8 = Self::FLAG_SAMPLED | Self::FLAG_REPLY;

    /// A request-direction (`Push`) context.
    pub fn sampled(trace_id: u64, parent_span: u32) -> TraceCtx {
        TraceCtx { trace_id, parent_span, flags: Self::FLAG_SAMPLED }
    }

    /// A reply-direction (`PullReply`) context.
    pub fn reply(trace_id: u64, parent_span: u32) -> TraceCtx {
        TraceCtx { trace_id, parent_span, flags: Self::FLAG_SAMPLED | Self::FLAG_REPLY }
    }

    pub fn is_reply(&self) -> bool {
        self.flags & Self::FLAG_REPLY != 0
    }

    /// The exact 13 wire bytes of this context.
    pub fn to_bytes(&self) -> [u8; Self::WIRE_LEN] {
        let mut b = [0u8; Self::WIRE_LEN];
        b[0..8].copy_from_slice(&self.trace_id.to_le_bytes());
        b[8..12].copy_from_slice(&self.parent_span.to_le_bytes());
        b[12] = self.flags;
        b
    }

    /// Parse and validate exactly [`TraceCtx::WIRE_LEN`] bytes.
    fn parse(b: &[u8]) -> Result<TraceCtx> {
        debug_assert_eq!(b.len(), Self::WIRE_LEN);
        let trace_id = u64::from_le_bytes(b[0..8].try_into().unwrap());
        let parent_span = u32::from_le_bytes(b[8..12].try_into().unwrap());
        let flags = b[12];
        anyhow::ensure!(
            flags & !Self::KNOWN_FLAGS == 0,
            "trace context with unknown flag bits {flags:#04x}"
        );
        anyhow::ensure!(
            flags & Self::FLAG_SAMPLED != 0,
            "trace context without the sampled flag"
        );
        Ok(TraceCtx { trace_id, parent_span, flags })
    }
}

/// Protocol messages between edge workers and parameter servers (owned
/// form; [`MessageRef`] is the borrowed-payload twin the hot path uses).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → server: pull parameters of layers `[lo, hi]` for `iter`.
    Pull { iter: u64, lo: u32, hi: u32 },
    /// Server → worker: the parameters as one byte slab — each owned
    /// layer's `w‖b` data encoded per layer by `codec`
    /// ([`crate::net::codec`]), concatenated in ascending layer order.
    /// `applied` (v4) is the oldest applied iteration among the served
    /// layers: `== iter` under BSP, and the staleness signal under
    /// SSP/ASP, where the snapshot is whatever the server last applied.
    PullReply { iter: u64, lo: u32, hi: u32, applied: u64, codec: CodecId, data: Vec<u8> },
    /// Worker → server: gradients of layers `[lo, hi]` for `iter`, as a
    /// byte slab with the same layout as [`Message::PullReply`].
    Push { iter: u64, lo: u32, hi: u32, codec: CodecId, data: Vec<u8> },
    /// Server → worker: push accepted.
    PushAck { iter: u64, lo: u32, hi: u32 },
    /// Worker → server (after a successful `Hello` handshake): propose the
    /// session's wire codec. The `Hello`/`HelloAck` layouts are frozen
    /// from v2 on, so negotiation rides in its own frames.
    CodecPropose { pref: CodecId },
    /// Server → worker: the codec this session will use — the proposed one
    /// if the server supports it, [`CodecId::Fp32`] otherwise, so mixed
    /// fleets keep training.
    CodecAgree { codec: CodecId },
    /// Worker → server (v4, after the `Hello` handshake): announce the
    /// synchronization mode + staleness bound the worker was configured
    /// for. Unlike codecs there is no safe fallback between consistency
    /// models, so the server answers with its *own* configuration and the
    /// worker refuses the session on mismatch.
    SyncPropose { mode: SyncMode, bound: u32 },
    /// Server → worker: the shard's authoritative sync configuration.
    SyncAgree { mode: SyncMode, bound: u32 },
    /// Worker → server: register with a worker id, announcing the
    /// worker's [`PROTOCOL_VERSION`].
    Hello { worker: u32, version: u16 },
    /// Peer → server (v5): weighted registration for the hierarchical
    /// tier (`docs/TOPOLOGY.md`). `group` identifies the registering
    /// identity (a worker group id for aggregators), `workers` is the
    /// number of edge devices it speaks for — the weight its pushes carry
    /// at a barrier. The decoder rejects unknown role tags, a zero
    /// worker-count, and an `Edge` role claiming more than one worker.
    /// Answered with the same frozen `HelloAck` as `Hello`.
    AggHello { role: PeerRole, group: u32, workers: u32, version: u16 },
    /// Server → worker: registration answer; reports cluster size and the
    /// server's [`PROTOCOL_VERSION`] (sent even on mismatch, so the worker
    /// can name both versions in its error).
    HelloAck { workers: u32, version: u16 },
    /// Worker → server (v6, after registration): a mid-run joiner asks for
    /// the full current parameter state of layers `[lo, hi]` — ungated by
    /// any sync policy, served from whatever the server last applied
    /// (`docs/FAULTS.md`).
    SnapshotReq { lo: u32, hi: u32 },
    /// Server → worker (v6): the snapshot. `iter` is the server's clock —
    /// the oldest applied iteration among the served layers, i.e. the
    /// iteration the joiner should enter the fleet at — and `workers` the
    /// configured fleet size (the barrier denominator), so the joiner can
    /// size its expectations without a second handshake. The slab carries
    /// the owned layers' parameters exactly like a `PullReply`.
    SnapshotReply { iter: u64, lo: u32, hi: u32, workers: u32, codec: CodecId, data: Vec<u8> },
    /// Either direction (v7): first leg of the NTP-style four-timestamp
    /// clock probe ([`crate::obs::clock`]). `t1` is the prober's local
    /// monotonic clock at send time, echoed back verbatim in the reply so
    /// the prober never has to correlate in-flight probes.
    ClockProbe { t1: u64 },
    /// The probe answer (v7): the echoed `t1`, the responder's clock at
    /// receive (`t2`) and at send (`t3`). The prober timestamps the
    /// arrival (`t4`) and computes offset `((t2−t1)+(t3−t4))/2` and
    /// uncertainty `((t4−t1)−(t3−t2))/2`. Answered immediately and
    /// ungated by registration or sync state.
    ClockReply { t1: u64, t2: u64, t3: u64 },
    /// Either direction: tear the connection down.
    Shutdown,
}

impl Message {
    pub fn opcode(&self) -> u8 {
        self.wire_ref().opcode()
    }

    /// Serialized payload size in bytes (excluding the length prefix).
    pub fn wire_size(&self) -> usize {
        self.wire_ref().wire_size()
    }

    /// The borrowed-payload view of this message (same wire encoding).
    pub fn wire_ref(&self) -> MessageRef<'_> {
        match self {
            Message::Pull { iter, lo, hi } => {
                MessageRef::Pull { iter: *iter, lo: *lo, hi: *hi }
            }
            Message::PullReply { iter, lo, hi, applied, codec, data } => {
                MessageRef::PullReply {
                    iter: *iter,
                    lo: *lo,
                    hi: *hi,
                    applied: *applied,
                    codec: *codec,
                    data: data.as_slice(),
                }
            }
            Message::Push { iter, lo, hi, codec, data } => MessageRef::Push {
                iter: *iter,
                lo: *lo,
                hi: *hi,
                codec: *codec,
                data: data.as_slice(),
            },
            Message::PushAck { iter, lo, hi } => {
                MessageRef::PushAck { iter: *iter, lo: *lo, hi: *hi }
            }
            Message::CodecPropose { pref } => MessageRef::CodecPropose { pref: *pref },
            Message::CodecAgree { codec } => MessageRef::CodecAgree { codec: *codec },
            Message::SyncPropose { mode, bound } => {
                MessageRef::SyncPropose { mode: *mode, bound: *bound }
            }
            Message::SyncAgree { mode, bound } => {
                MessageRef::SyncAgree { mode: *mode, bound: *bound }
            }
            Message::Hello { worker, version } => {
                MessageRef::Hello { worker: *worker, version: *version }
            }
            Message::AggHello { role, group, workers, version } => MessageRef::AggHello {
                role: *role,
                group: *group,
                workers: *workers,
                version: *version,
            },
            Message::HelloAck { workers, version } => {
                MessageRef::HelloAck { workers: *workers, version: *version }
            }
            Message::SnapshotReq { lo, hi } => MessageRef::SnapshotReq { lo: *lo, hi: *hi },
            Message::SnapshotReply { iter, lo, hi, workers, codec, data } => {
                MessageRef::SnapshotReply {
                    iter: *iter,
                    lo: *lo,
                    hi: *hi,
                    workers: *workers,
                    codec: *codec,
                    data: data.as_slice(),
                }
            }
            Message::ClockProbe { t1 } => MessageRef::ClockProbe { t1: *t1 },
            Message::ClockReply { t1, t2, t3 } => {
                MessageRef::ClockReply { t1: *t1, t2: *t2, t3: *t3 }
            }
            Message::Shutdown => MessageRef::Shutdown,
        }
    }

    /// Encode the full frame (length prefix included) into a reusable
    /// buffer. The buffer is cleared first; capacity is retained across
    /// calls, so a warm buffer makes this allocation-free.
    ///
    /// This is the **legacy contiguous encoding**: the slab is memcpy'd
    /// into the frame buffer. The hot path sends with
    /// [`Connection::send_ref`] (vectored, no slab copy) instead; this
    /// implementation is kept independent as the byte-exact reference the
    /// vectored-framing property tests compare against.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        buf.clear();
        buf.reserve(4 + self.wire_size());
        buf.extend_from_slice(&(self.wire_size() as u32).to_le_bytes());
        buf.push(self.opcode());
        match self {
            Message::Pull { iter, lo, hi } | Message::PushAck { iter, lo, hi } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            Message::PullReply { iter, lo, hi, applied, codec, data } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
                buf.extend_from_slice(&applied.to_le_bytes());
                buf.extend_from_slice(&slab_len_field(*codec, data.len()).to_le_bytes());
                buf.extend_from_slice(data);
            }
            Message::Push { iter, lo, hi, codec, data } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
                buf.extend_from_slice(&slab_len_field(*codec, data.len()).to_le_bytes());
                buf.extend_from_slice(data);
            }
            Message::CodecPropose { pref } => buf.push(pref.tag()),
            Message::CodecAgree { codec } => buf.push(codec.tag()),
            Message::SyncPropose { mode, bound } | Message::SyncAgree { mode, bound } => {
                buf.push(mode.tag());
                buf.extend_from_slice(&bound.to_le_bytes());
            }
            Message::Hello { worker, version } => {
                buf.extend_from_slice(&worker.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            Message::AggHello { role, group, workers, version } => {
                buf.push(role.tag());
                buf.extend_from_slice(&group.to_le_bytes());
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            Message::HelloAck { workers, version } => {
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            Message::SnapshotReq { lo, hi } => {
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            Message::SnapshotReply { iter, lo, hi, workers, codec, data } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&slab_len_field(*codec, data.len()).to_le_bytes());
                buf.extend_from_slice(data);
            }
            Message::ClockProbe { t1 } => buf.extend_from_slice(&t1.to_le_bytes()),
            Message::ClockReply { t1, t2, t3 } => {
                buf.extend_from_slice(&t1.to_le_bytes());
                buf.extend_from_slice(&t2.to_le_bytes());
                buf.extend_from_slice(&t3.to_le_bytes());
            }
            Message::Shutdown => {}
        }
    }

    /// Encode into a fresh frame buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(&mut buf);
        buf
    }

    pub fn decode(payload: &[u8]) -> Result<Message> {
        Ok(MessageRef::decode(payload)?.into_owned())
    }
}

/// Borrowed-payload twin of [`Message`]: identical wire encoding, but the
/// tensor slab of `PullReply`/`Push` is a borrowed slice, so sending never
/// copies it and decoding can hand out views into the receive buffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MessageRef<'a> {
    Pull { iter: u64, lo: u32, hi: u32 },
    PullReply { iter: u64, lo: u32, hi: u32, applied: u64, codec: CodecId, data: &'a [u8] },
    Push { iter: u64, lo: u32, hi: u32, codec: CodecId, data: &'a [u8] },
    PushAck { iter: u64, lo: u32, hi: u32 },
    Hello { worker: u32, version: u16 },
    AggHello { role: PeerRole, group: u32, workers: u32, version: u16 },
    HelloAck { workers: u32, version: u16 },
    Shutdown,
    CodecPropose { pref: CodecId },
    CodecAgree { codec: CodecId },
    SyncPropose { mode: SyncMode, bound: u32 },
    SyncAgree { mode: SyncMode, bound: u32 },
    SnapshotReq { lo: u32, hi: u32 },
    SnapshotReply { iter: u64, lo: u32, hi: u32, workers: u32, codec: CodecId, data: &'a [u8] },
    ClockProbe { t1: u64 },
    ClockReply { t1: u64, t2: u64, t3: u64 },
}

impl<'a> MessageRef<'a> {
    pub fn opcode(&self) -> u8 {
        match self {
            MessageRef::Pull { .. } => 1,
            MessageRef::PullReply { .. } => 2,
            MessageRef::Push { .. } => 3,
            MessageRef::PushAck { .. } => 4,
            MessageRef::Hello { .. } => 5,
            MessageRef::HelloAck { .. } => 6,
            MessageRef::Shutdown => 7,
            MessageRef::CodecPropose { .. } => 8,
            MessageRef::CodecAgree { .. } => 9,
            MessageRef::SyncPropose { .. } => 10,
            MessageRef::SyncAgree { .. } => 11,
            MessageRef::AggHello { .. } => 12,
            MessageRef::SnapshotReq { .. } => 13,
            MessageRef::SnapshotReply { .. } => 14,
            MessageRef::ClockProbe { .. } => 15,
            MessageRef::ClockReply { .. } => 16,
        }
    }

    /// Serialized payload size in bytes (excluding the length prefix).
    pub fn wire_size(&self) -> usize {
        1 + match self {
            MessageRef::Pull { .. } => 8 + 4 + 4,
            MessageRef::PullReply { data, .. } => 8 + 4 + 4 + 8 + 4 + data.len(),
            MessageRef::Push { data, .. } => 8 + 4 + 4 + 4 + data.len(),
            MessageRef::PushAck { .. } => 8 + 4 + 4,
            MessageRef::Hello { .. } => 4 + 2,
            MessageRef::AggHello { .. } => 1 + 4 + 4 + 2,
            MessageRef::HelloAck { .. } => 4 + 2,
            MessageRef::Shutdown => 0,
            MessageRef::CodecPropose { .. } => 1,
            MessageRef::CodecAgree { .. } => 1,
            MessageRef::SyncPropose { .. } => 1 + 4,
            MessageRef::SyncAgree { .. } => 1 + 4,
            MessageRef::SnapshotReq { .. } => 4 + 4,
            MessageRef::SnapshotReply { data, .. } => 8 + 4 + 4 + 4 + 4 + data.len(),
            MessageRef::ClockProbe { .. } => 8,
            MessageRef::ClockReply { .. } => 8 + 8 + 8,
        }
    }

    /// Encode the length prefix, opcode, fixed fields, and (for tensor
    /// messages) the slab length field into `buf` — everything **except**
    /// the slab bytes — and return the borrowed slab to be written after
    /// it. `buf ‖ returned` is byte-identical to [`Message::encode`].
    // dynalint: hot-path
    pub fn encode_header_into(&self, buf: &mut Vec<u8>) -> &'a [u8] {
        match *self {
            // Tensor frames share one header encoder with
            // `Connection::send_push_parts` — a single source of truth for
            // the layout.
            MessageRef::PullReply { iter, lo, hi, applied, codec, data } => {
                encode_tensor_header(buf, iter, lo, hi, Some(applied), codec, data.len());
                return data;
            }
            MessageRef::Push { iter, lo, hi, codec, data } => {
                encode_tensor_header(buf, iter, lo, hi, None, codec, data.len());
                return data;
            }
            // The v6 snapshot reply is the third tensor frame; its header
            // differs from the other two (`workers` instead of `applied`,
            // and it precedes the slab field), so it owns its layout here.
            MessageRef::SnapshotReply { iter, lo, hi, workers, codec, data } => {
                let wire_size = SNAPSHOT_REPLY_SLAB_OFF + data.len();
                buf.clear();
                buf.extend_from_slice(&(wire_size as u32).to_le_bytes());
                buf.push(14);
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&slab_len_field(codec, data.len()).to_le_bytes());
                return data;
            }
            _ => {}
        }
        buf.clear();
        buf.extend_from_slice(&(self.wire_size() as u32).to_le_bytes());
        buf.push(self.opcode());
        match *self {
            MessageRef::Pull { iter, lo, hi } | MessageRef::PushAck { iter, lo, hi } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            MessageRef::Hello { worker, version } => {
                buf.extend_from_slice(&worker.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            MessageRef::AggHello { role, group, workers, version } => {
                buf.push(role.tag());
                buf.extend_from_slice(&group.to_le_bytes());
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            MessageRef::HelloAck { workers, version } => {
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            MessageRef::CodecPropose { pref } => buf.push(pref.tag()),
            MessageRef::CodecAgree { codec } => buf.push(codec.tag()),
            MessageRef::SyncPropose { mode, bound } | MessageRef::SyncAgree { mode, bound } => {
                buf.push(mode.tag());
                buf.extend_from_slice(&bound.to_le_bytes());
            }
            MessageRef::SnapshotReq { lo, hi } => {
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            MessageRef::ClockProbe { t1 } => buf.extend_from_slice(&t1.to_le_bytes()),
            MessageRef::ClockReply { t1, t2, t3 } => {
                buf.extend_from_slice(&t1.to_le_bytes());
                buf.extend_from_slice(&t2.to_le_bytes());
                buf.extend_from_slice(&t3.to_le_bytes());
            }
            _ => {}
        }
        &[]
    }

    /// Decode a frame payload, borrowing the tensor slab from it. A v7
    /// trailing trace context on `Push`/`PullReply` is validated and
    /// discarded — v6-era consumers keep working unchanged; trace-aware
    /// receive paths use [`MessageRef::decode_with_ctx`].
    // dynalint: hot-path
    pub fn decode(payload: &'a [u8]) -> Result<MessageRef<'a>> {
        Ok(Self::decode_with_ctx(payload)?.0)
    }

    /// Decode a frame payload, also returning the v7 trace context if the
    /// frame carried one (only `Push`/`PullReply` can; `None` for a
    /// context-free v6 tensor frame, which stays accepted this version).
    // dynalint: hot-path
    pub fn decode_with_ctx(
        payload: &'a [u8],
    ) -> Result<(MessageRef<'a>, Option<TraceCtx>)> {
        anyhow::ensure!(!payload.is_empty(), "empty frame");
        let op = payload[0];
        let mut r = Reader { b: &payload[1..] };
        let mut ctx = None;
        let msg = match op {
            1 => MessageRef::Pull { iter: r.u64()?, lo: r.u32()?, hi: r.u32()? },
            2 => {
                let (iter, lo, hi, applied) = (r.u64()?, r.u32()?, r.u32()?, r.u64()?);
                let (codec, data) = r.slab()?;
                ctx = r.trace_ctx()?;
                MessageRef::PullReply { iter, lo, hi, applied, codec, data }
            }
            3 => {
                let (iter, lo, hi) = (r.u64()?, r.u32()?, r.u32()?);
                let (codec, data) = r.slab()?;
                ctx = r.trace_ctx()?;
                MessageRef::Push { iter, lo, hi, codec, data }
            }
            4 => MessageRef::PushAck { iter: r.u64()?, lo: r.u32()?, hi: r.u32()? },
            5 => MessageRef::Hello { worker: r.u32()?, version: r.u16()? },
            6 => MessageRef::HelloAck { workers: r.u32()?, version: r.u16()? },
            7 => MessageRef::Shutdown,
            8 => MessageRef::CodecPropose { pref: r.codec()? },
            9 => MessageRef::CodecAgree { codec: r.codec()? },
            10 => {
                let (mode, bound) = r.sync()?;
                MessageRef::SyncPropose { mode, bound }
            }
            11 => {
                let (mode, bound) = r.sync()?;
                MessageRef::SyncAgree { mode, bound }
            }
            12 => {
                let (role, group, workers, version) = r.agg_hello()?;
                MessageRef::AggHello { role, group, workers, version }
            }
            13 => MessageRef::SnapshotReq { lo: r.u32()?, hi: r.u32()? },
            14 => {
                let (iter, lo, hi, workers) = (r.u64()?, r.u32()?, r.u32()?, r.u32()?);
                anyhow::ensure!(workers > 0, "snapshot reply with zero fleet size");
                let (codec, data) = r.slab()?;
                MessageRef::SnapshotReply { iter, lo, hi, workers, codec, data }
            }
            15 => MessageRef::ClockProbe { t1: r.u64()? },
            16 => MessageRef::ClockReply { t1: r.u64()?, t2: r.u64()?, t3: r.u64()? },
            _ => bail!("unknown opcode {op}"),
        };
        anyhow::ensure!(r.b.is_empty(), "trailing bytes in frame (op {op})");
        Ok((msg, ctx))
    }

    /// Copy into the owned form (the only place the slab is cloned).
    pub fn into_owned(self) -> Message {
        match self {
            MessageRef::Pull { iter, lo, hi } => Message::Pull { iter, lo, hi },
            MessageRef::PullReply { iter, lo, hi, applied, codec, data } => {
                Message::PullReply { iter, lo, hi, applied, codec, data: data.to_vec() }
            }
            MessageRef::Push { iter, lo, hi, codec, data } => {
                Message::Push { iter, lo, hi, codec, data: data.to_vec() }
            }
            MessageRef::PushAck { iter, lo, hi } => Message::PushAck { iter, lo, hi },
            MessageRef::Hello { worker, version } => Message::Hello { worker, version },
            MessageRef::AggHello { role, group, workers, version } => {
                Message::AggHello { role, group, workers, version }
            }
            MessageRef::HelloAck { workers, version } => {
                Message::HelloAck { workers, version }
            }
            MessageRef::Shutdown => Message::Shutdown,
            MessageRef::CodecPropose { pref } => Message::CodecPropose { pref },
            MessageRef::CodecAgree { codec } => Message::CodecAgree { codec },
            MessageRef::SyncPropose { mode, bound } => Message::SyncPropose { mode, bound },
            MessageRef::SyncAgree { mode, bound } => Message::SyncAgree { mode, bound },
            MessageRef::SnapshotReq { lo, hi } => Message::SnapshotReq { lo, hi },
            MessageRef::SnapshotReply { iter, lo, hi, workers, codec, data } => {
                Message::SnapshotReply { iter, lo, hi, workers, codec, data: data.to_vec() }
            }
            MessageRef::ClockProbe { t1 } => Message::ClockProbe { t1 },
            MessageRef::ClockReply { t1, t2, t3 } => Message::ClockReply { t1, t2, t3 },
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.b.len() >= n, "truncated frame");
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A one-byte codec id (the `CodecPropose`/`CodecAgree` payload).
    fn codec(&mut self) -> Result<CodecId> {
        let tag = self.take(1)?[0];
        CodecId::from_tag(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown codec tag {tag}"))
    }

    /// The `SyncPropose`/`SyncAgree` payload: a one-byte sync mode tag
    /// followed by the `u32` staleness bound. A bound only means anything
    /// under SSP, so a non-zero bound on a bsp/asp frame is malformed and
    /// rejected here rather than silently ignored by the endpoint.
    fn sync(&mut self) -> Result<(SyncMode, u32)> {
        let tag = self.take(1)?[0];
        let mode = SyncMode::from_tag(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown sync mode tag {tag}"))?;
        let bound = self.u32()?;
        anyhow::ensure!(
            bound == 0 || mode == SyncMode::Ssp,
            "malformed staleness bound {bound} for sync mode {}",
            mode.name()
        );
        Ok((mode, bound))
    }

    /// The `AggHello` payload (v5): a one-byte peer-role tag, the `u32`
    /// group id, the `u32` worker-count weight, and the sender's protocol
    /// version. Malformed roles are rejected here — an unknown role tag, a
    /// zero worker-count (a weightless registration could never satisfy a
    /// barrier), or an `Edge` role claiming to speak for more than one
    /// worker — rather than silently registered by the endpoint.
    fn agg_hello(&mut self) -> Result<(PeerRole, u32, u32, u16)> {
        let tag = self.take(1)?[0];
        let role = PeerRole::from_tag(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown peer role tag {tag}"))?;
        let group = self.u32()?;
        let workers = self.u32()?;
        let version = self.u16()?;
        anyhow::ensure!(workers > 0, "agg hello with zero worker count");
        anyhow::ensure!(
            role == PeerRole::Regional || workers == 1,
            "malformed worker count {workers} for peer role {}",
            role.name()
        );
        Ok((role, group, workers, version))
    }

    /// The optional v7 trace context trailing a tensor frame's slab: no
    /// remaining bytes means a context-free (v6-compatible) frame; exactly
    /// [`TraceCtx::WIRE_LEN`] remaining bytes are parsed and validated
    /// (unknown flag bits and a clear sampled bit are rejected). Any other
    /// remaining count is left in place for the decoder's trailing-bytes
    /// rejection — a truncated or padded context never parses.
    fn trace_ctx(&mut self) -> Result<Option<TraceCtx>> {
        if self.b.is_empty() {
            return Ok(None);
        }
        if self.b.len() != TraceCtx::WIRE_LEN {
            return Ok(None);
        }
        Ok(Some(TraceCtx::parse(self.take(TraceCtx::WIRE_LEN)?)?))
    }

    /// Length-prefixed byte slab, borrowed — no copy, no per-element work.
    /// The length field's top 2 bits carry the codec tag; the low 30 bits
    /// the byte count, checked against the codec's frame-level invariants
    /// (fp32 4-aligned, fp16 2-aligned). A tensor payload is a
    /// *concatenation* of per-layer encodings, so per-layer framing — in
    /// particular int8's chunked layout — is validated by the endpoint
    /// that slices the payload with its byte tables, not here.
    fn slab(&mut self) -> Result<(CodecId, &'a [u8])> {
        let field = self.u32()?;
        let tag = (field >> 30) as u8;
        let n = (field & SLAB_LEN_MASK) as usize;
        let codec = CodecId::from_tag(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown slab codec tag {tag}"))?;
        anyhow::ensure!(
            codec.valid_frame_len(n),
            "slab length {n} misaligned for codec {}",
            codec.name()
        );
        Ok((codec, self.take(n)?))
    }
}

/// A received message whose tensor payload (if any) is a [`SlabSlice`]
/// view into a pooled frame buffer — the buffer returns to the pool when
/// the last view drops. Produced by [`Connection::recv_pooled`].
#[derive(Debug)]
pub enum RecvMsg {
    /// Control frames, owned as usual.
    Control(Message),
    /// A `PullReply` whose slab is a pooled view. `ctx` is the v7 trace
    /// context when the sender attached one.
    PullReply {
        iter: u64,
        lo: u32,
        hi: u32,
        applied: u64,
        codec: CodecId,
        data: SlabSlice,
        ctx: Option<TraceCtx>,
    },
    /// A `Push` whose slab is a pooled view.
    Push { iter: u64, lo: u32, hi: u32, codec: CodecId, data: SlabSlice, ctx: Option<TraceCtx> },
}

/// Byte offset of the slab inside a `Push` frame payload: opcode + `iter`
/// + `lo` + `hi` + the slab-length field.
const PUSH_SLAB_OFF: usize = 1 + 8 + 4 + 4 + 4;

/// Byte offset of the slab inside a `PullReply` frame payload: the `Push`
/// layout plus the v4 `applied: u64` field before the slab-length field.
const PULL_REPLY_SLAB_OFF: usize = 1 + 8 + 4 + 4 + 8 + 4;

/// Byte offset of the slab inside a `SnapshotReply` frame payload (v6):
/// opcode + `iter` + `lo` + `hi` + `workers` + the slab-length field.
const SNAPSHOT_REPLY_SLAB_OFF: usize = 1 + 8 + 4 + 4 + 4 + 4;

/// Encode a tensor frame's header (length prefix through the slab-length
/// field) for a slab of `data_len` bytes: the single owner of the
/// `PullReply`/`Push` layout, shared by [`MessageRef::encode_header_into`]
/// and [`Connection::send_push_parts`]. `applied` is present exactly for
/// `PullReply` frames (v4) — which is also what selects the opcode, since
/// they are the only two tensor frames.
// dynalint: hot-path
fn encode_tensor_header(
    buf: &mut Vec<u8>,
    iter: u64,
    lo: u32,
    hi: u32,
    applied: Option<u64>,
    codec: CodecId,
    data_len: usize,
) {
    let (opcode, fixed) = match applied {
        Some(_) => (2u8, PULL_REPLY_SLAB_OFF),
        None => (3u8, PUSH_SLAB_OFF),
    };
    let wire_size = fixed + data_len;
    buf.clear();
    buf.extend_from_slice(&(wire_size as u32).to_le_bytes());
    buf.push(opcode);
    buf.extend_from_slice(&iter.to_le_bytes());
    buf.extend_from_slice(&lo.to_le_bytes());
    buf.extend_from_slice(&hi.to_le_bytes());
    if let Some(applied) = applied {
        buf.extend_from_slice(&applied.to_le_bytes());
    }
    buf.extend_from_slice(&slab_len_field(codec, data_len).to_le_bytes());
}

/// Widen an encoded frame's `u32 LE` length prefix by `extra` bytes: the
/// shared tensor-header encoder emits the context-free (v6) length, and
/// the send paths that append a [`TraceCtx`] trailer patch the prefix to
/// cover it — one place less for the two layouts to drift apart.
// dynalint: hot-path
fn patch_frame_len(buf: &mut [u8], extra: usize) {
    debug_assert!(buf.len() >= 4);
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) + extra as u32;
    buf[0..4].copy_from_slice(&len.to_le_bytes());
}

/// The virtual part list of a scattered frame: index 0 is the header,
/// indices `1..` map onto `parts`.
fn scattered_part<'a>(head: &'a [u8], parts: &'a [&'a [u8]], i: usize) -> &'a [u8] {
    if i == 0 {
        head
    } else {
        parts[i - 1]
    }
}

/// Write `head` then every slice of `parts`, scatter-gather style, with
/// correct resumption after partial writes. One frame, no assembly copy.
// dynalint: hot-path
fn write_scattered(w: &mut TcpStream, head: &[u8], parts: &[&[u8]]) -> std::io::Result<()> {
    /// Max iovec entries per `write_vectored` call.
    const IOV_BATCH: usize = 16;
    let total = 1 + parts.len();
    let mut idx = 0usize; // current part
    let mut off = 0usize; // bytes of the current part already written
    while idx < total {
        if off >= scattered_part(head, parts, idx).len() {
            idx += 1;
            off = 0;
            continue;
        }
        let mut iov: [IoSlice<'_>; IOV_BATCH] = [IoSlice::new(&[]); IOV_BATCH];
        let mut n = 0usize;
        while n < IOV_BATCH && idx + n < total {
            let p = scattered_part(head, parts, idx + n);
            iov[n] = IoSlice::new(if n == 0 { &p[off..] } else { p });
            n += 1;
        }
        // Same retry discipline as `Write::write_all`: EINTR restarts the
        // write instead of tearing the connection down mid-frame.
        let written = match w.write_vectored(&iov[..n]) {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(written) => written,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        let mut rem = written;
        while rem > 0 && idx < total {
            let avail = scattered_part(head, parts, idx).len() - off;
            if rem >= avail {
                rem -= avail;
                idx += 1;
                off = 0;
            } else {
                off += rem;
                rem = 0;
            }
        }
    }
    Ok(())
}

/// Size the receive buffer for an incoming `len`-byte frame: grow-only in
/// steady state (warm capacity is **never** re-zeroed — the socket read
/// overwrites `[..len]`), and shrink back to [`RECV_RETAIN_MAX`] when a
/// prior oversized frame left pathological capacity behind.
// dynalint: hot-path
fn prepare_frame_buf(buf: &mut Vec<u8>, len: usize) {
    if buf.capacity() > RECV_RETAIN_MAX && len <= RECV_RETAIN_MAX {
        buf.clear();
        buf.shrink_to(RECV_RETAIN_MAX);
    }
    if buf.len() < len {
        // resize zero-fills only the newly grown tail, once; after that the
        // buffer's length is its high-water mark and refills are memset-free.
        buf.resize(len, 0);
    }
}

/// Process-global wire counters in the unified obs registry: one relaxed
/// atomic op per frame/byte-count on the hot path (docs/OBSERVABILITY.md).
struct NetCounters {
    tx_frames: crate::obs::Counter,
    tx_bytes: crate::obs::Counter,
    rx_frames: crate::obs::Counter,
    rx_bytes: crate::obs::Counter,
}

fn net_counters() -> &'static NetCounters {
    static CELL: std::sync::OnceLock<NetCounters> = std::sync::OnceLock::new();
    CELL.get_or_init(|| {
        let inst = crate::obs::next_inst();
        NetCounters {
            tx_frames: crate::obs_counter!("dynacomm_net_tx_frames_total", "", inst),
            tx_bytes: crate::obs_counter!("dynacomm_net_tx_bytes_total", "", inst),
            rx_frames: crate::obs_counter!("dynacomm_net_rx_frames_total", "", inst),
            rx_bytes: crate::obs_counter!("dynacomm_net_rx_bytes_total", "", inst),
        }
    })
}

/// A framed, optionally shaped, connection.
///
/// Each direction owns a scratch buffer: `send` encodes the (small) frame
/// header into `send_buf` — tensor slabs are written borrowed, vectored —
/// and `recv` reads frames into `recv_buf` (or a pool checkout for
/// [`Connection::recv_pooled`]), so steady-state traffic reuses warm
/// capacity instead of allocating per message.
pub struct Connection {
    stream: TcpStream,
    shaper: Option<crate::net::LinkShaper>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
}

impl Connection {
    pub fn new(stream: TcpStream, shaper: Option<crate::net::LinkShaper>) -> Connection {
        stream.set_nodelay(true).ok();
        Connection { stream, shaper, send_buf: Vec::new(), recv_buf: Vec::new() }
    }

    /// Send one owned message (delegates to the vectored path).
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        self.send_ref_ctx(msg.wire_ref(), None)
    }

    /// Send one owned message with a v7 trace context appended after the
    /// slab (tensor frames only; `None` sends the context-free v6 layout).
    pub fn send_ctx(&mut self, msg: &Message, ctx: Option<TraceCtx>) -> Result<()> {
        self.send_ref_ctx(msg.wire_ref(), ctx)
    }

    /// Send one message with its tensor slab borrowed: the header is
    /// encoded into the scratch buffer and the frame goes out as
    /// `[header][slab]` via `write_vectored` — the slab is never copied.
    /// When shaped, sleeps for the emulated serialization + latency time
    /// before the bytes hit the socket.
    // dynalint: hot-path
    pub fn send_ref(&mut self, msg: MessageRef<'_>) -> Result<()> {
        self.send_ref_ctx(msg, None)
    }

    /// [`Connection::send_ref`] with an optional v7 trace context: the
    /// frame goes out as `[header][slab][ctx]` — the 13 context bytes ride
    /// as a third scattered part from a stack buffer, and the length
    /// prefix (encoded context-free by the shared header encoder) is
    /// patched to cover them. Attaching a context to a non-tensor frame is
    /// a caller bug (only `Push`/`PullReply` carry one on the wire).
    // dynalint: hot-path
    pub fn send_ref_ctx(&mut self, msg: MessageRef<'_>, ctx: Option<TraceCtx>) -> Result<()> {
        let payload = msg.encode_header_into(&mut self.send_buf);
        let ctx_bytes;
        let trailer: &[u8] = match ctx {
            Some(c) => {
                debug_assert!(
                    matches!(msg, MessageRef::Push { .. } | MessageRef::PullReply { .. }),
                    "trace context on a non-tensor frame (op {})",
                    msg.opcode()
                );
                patch_frame_len(&mut self.send_buf, TraceCtx::WIRE_LEN);
                ctx_bytes = c.to_bytes();
                &ctx_bytes
            }
            None => &[],
        };
        if let Some(shaper) = &self.shaper {
            shaper.delay_for(self.send_buf.len() + payload.len() + trailer.len());
        }
        let wire = self.send_buf.len() + payload.len() + trailer.len();
        write_scattered(&mut self.stream, &self.send_buf, &[payload, trailer])
            .context("send")?;
        let net = net_counters();
        net.tx_frames.inc();
        net.tx_bytes.add(wire as u64);
        Ok(())
    }

    /// Send a `Push` whose slab is scattered across `parts` (e.g. one part
    /// per layer, straight from the pooled per-layer gradient slabs). The
    /// frame on the wire is byte-identical to sending the concatenation —
    /// without ever materializing it. A v7 trace context, when given,
    /// rides as one more scattered part after the slab.
    // dynalint: hot-path
    pub fn send_push_parts(
        &mut self,
        iter: u64,
        lo: u32,
        hi: u32,
        codec: CodecId,
        parts: &[&[u8]],
        ctx: Option<TraceCtx>,
    ) -> Result<()> {
        let data_len: usize = parts.iter().map(|p| p.len()).sum();
        encode_tensor_header(&mut self.send_buf, iter, lo, hi, None, codec, data_len);
        let ctx_bytes;
        let trailer: &[u8] = match ctx {
            Some(c) => {
                patch_frame_len(&mut self.send_buf, TraceCtx::WIRE_LEN);
                ctx_bytes = c.to_bytes();
                &ctx_bytes
            }
            None => &[],
        };
        if let Some(shaper) = &self.shaper {
            shaper.delay_for(self.send_buf.len() + data_len + trailer.len());
        }
        let wire = self.send_buf.len() + data_len + trailer.len();
        write_scattered(&mut self.stream, &self.send_buf, parts).context("send")?;
        if !trailer.is_empty() {
            // The context rides as a tail write of the same frame (the
            // patched length prefix already covers it); appending it to
            // the caller's part list would need a heap copy of the table.
            self.stream.write_all(trailer).context("send")?;
        }
        let net = net_counters();
        net.tx_frames.inc();
        net.tx_bytes.add(wire as u64);
        Ok(())
    }

    /// Receive one message (blocking), owned.
    pub fn recv(&mut self) -> Result<Message> {
        Ok(self.recv_ref()?.into_owned())
    }

    /// Receive one message (blocking) with its tensor slab borrowed from
    /// the connection's receive scratch — zero payload copies for callers
    /// that fully consume the message before the next transport call (the
    /// server's `Push` handling).
    // dynalint: hot-path
    pub fn recv_ref(&mut self) -> Result<MessageRef<'_>> {
        Ok(self.recv_ref_ctx()?.0)
    }

    /// [`Connection::recv_ref`] that also surfaces the v7 trace context
    /// when the sender attached one (trace-aware endpoints: the server's
    /// and aggregator's frame loops).
    // dynalint: hot-path
    pub fn recv_ref_ctx(&mut self) -> Result<(MessageRef<'_>, Option<TraceCtx>)> {
        let len = read_frame_len(&mut self.stream)?;
        prepare_frame_buf(&mut self.recv_buf, len);
        self.stream
            .read_exact(&mut self.recv_buf[..len])
            .context("recv payload")?;
        let net = net_counters();
        net.rx_frames.inc();
        net.rx_bytes.add(4 + len as u64);
        MessageRef::decode_with_ctx(&self.recv_buf[..len])
    }

    /// Receive one message (blocking), reading the frame straight into a
    /// pool checkout: tensor payloads come back as [`SlabSlice`] views of
    /// the pooled frame (no copy between the socket and the consumer), and
    /// the frame buffer recycles through `pool` when the last view drops.
    /// Control frames are returned owned and their checkout is recycled
    /// immediately.
    // dynalint: hot-path
    pub fn recv_pooled(&mut self, pool: &Arc<SlabPool>) -> Result<RecvMsg> {
        /// Decode outcome with the frame borrow already released: tensor
        /// frames carry only their fixed fields (the slab stays in the
        /// frame at its opcode's slab offset), control frames are owned.
        enum Parsed {
            Tensor {
                op: u8,
                iter: u64,
                lo: u32,
                hi: u32,
                applied: u64,
                codec: CodecId,
                len: usize,
            },
            Control(Message),
        }

        let len = read_frame_len(&mut self.stream)?;
        let mut frame = pool.checkout_filled(len);
        self.stream.read_exact(&mut frame[..]).context("recv payload")?;
        let net = net_counters();
        net.rx_frames.inc();
        net.rx_bytes.add(4 + len as u64);
        // One decode, fully validating the frame (the v7 trace context
        // included — the slab still sits at its fixed opcode offset, the
        // context trails it).
        let (msg, ctx) = MessageRef::decode_with_ctx(&frame[..])?;
        let parsed = match msg {
            MessageRef::PullReply { iter, lo, hi, applied, codec, data } => {
                Parsed::Tensor { op: 2, iter, lo, hi, applied, codec, len: data.len() }
            }
            MessageRef::Push { iter, lo, hi, codec, data } => {
                Parsed::Tensor { op: 3, iter, lo, hi, applied: 0, codec, len: data.len() }
            }
            other => Parsed::Control(other.into_owned()),
        };
        match parsed {
            Parsed::Tensor { op, iter, lo, hi, applied, codec, len } => {
                Ok(if op == 2 {
                    let data = SlabSlice::new(frame.freeze(), PULL_REPLY_SLAB_OFF, len);
                    RecvMsg::PullReply { iter, lo, hi, applied, codec, data, ctx }
                } else {
                    let data = SlabSlice::new(frame.freeze(), PUSH_SLAB_OFF, len);
                    RecvMsg::Push { iter, lo, hi, codec, data, ctx }
                })
            }
            Parsed::Control(msg) => Ok(RecvMsg::Control(msg)),
        }
    }

    /// Arm (or clear, with `None`) read/write deadlines on the underlying
    /// socket: any blocking transport call past the deadline fails with a
    /// timeout error instead of hanging forever on a dead peer
    /// (`docs/FAULTS.md`). `Some(Duration::ZERO)` is rejected because the
    /// OS interprets it as "no timeout" — the opposite of what a caller
    /// passing zero means.
    pub fn set_io_timeout(&self, timeout: Option<std::time::Duration>) -> Result<()> {
        anyhow::ensure!(
            timeout != Some(std::time::Duration::ZERO),
            "io timeout of zero would disable the deadline; use None"
        );
        self.stream.set_read_timeout(timeout).context("set read timeout")?;
        self.stream.set_write_timeout(timeout).context("set write timeout")
    }

    pub fn try_clone(&self) -> Result<Connection> {
        Ok(Connection {
            stream: self.stream.try_clone()?,
            shaper: self.shaper.clone(),
            send_buf: Vec::new(),
            recv_buf: Vec::new(),
        })
    }
}

// dynalint: hot-path
fn read_frame_len(stream: &mut TcpStream) -> Result<usize> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).context("recv length")?;
    let len = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
    Ok(len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slab;
    use crate::util::rng::Rng;

    fn roundtrip(m: Message) {
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len, enc.len() - 4);
        assert_eq!(len, m.wire_size());
        assert_eq!(Message::decode(&enc[4..]).unwrap(), m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Pull { iter: 7, lo: 1, hi: 3 });
        roundtrip(Message::PullReply {
            iter: 7,
            lo: 1,
            hi: 3,
            applied: 7,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[1.5, -2.0, 0.0]),
        });
        // A stale SSP/ASP snapshot: applied differs from the request.
        roundtrip(Message::PullReply {
            iter: 9,
            lo: 0,
            hi: 0,
            applied: 6,
            codec: CodecId::Fp32,
            data: Vec::new(),
        });
        roundtrip(Message::Push {
            iter: 0,
            lo: 6,
            hi: 6,
            codec: CodecId::Fp32,
            data: Vec::new(),
        });
        roundtrip(Message::PushAck { iter: 1, lo: 2, hi: 4 });
        roundtrip(Message::Hello { worker: 3, version: PROTOCOL_VERSION });
        roundtrip(Message::HelloAck { workers: 8, version: PROTOCOL_VERSION });
        // Versions other than ours still travel intact — that is what lets
        // endpoints *name* the mismatched version in their error.
        roundtrip(Message::Hello { worker: 0, version: 0 });
        roundtrip(Message::HelloAck { workers: 1, version: u16::MAX });
        roundtrip(Message::Shutdown);
        for id in CodecId::ALL {
            roundtrip(Message::CodecPropose { pref: id });
            roundtrip(Message::CodecAgree { codec: id });
        }
        for mode in SyncMode::ALL {
            let bound = if mode == SyncMode::Ssp { 3 } else { 0 };
            roundtrip(Message::SyncPropose { mode, bound });
            roundtrip(Message::SyncAgree { mode, bound });
        }
        roundtrip(Message::AggHello {
            role: PeerRole::Regional,
            group: 2,
            workers: 4,
            version: PROTOCOL_VERSION,
        });
        roundtrip(Message::AggHello {
            role: PeerRole::Edge,
            group: 9,
            workers: 1,
            version: 0,
        });
        roundtrip(Message::SnapshotReq { lo: 0, hi: 7 });
        roundtrip(Message::SnapshotReply {
            iter: 42,
            lo: 0,
            hi: 7,
            workers: 8,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[1.0, -0.5]),
        });
        roundtrip(Message::SnapshotReply {
            iter: 0,
            lo: 3,
            hi: 3,
            workers: 1,
            codec: CodecId::Fp32,
            data: Vec::new(),
        });
        roundtrip(Message::ClockProbe { t1: 0 });
        roundtrip(Message::ClockProbe { t1: u64::MAX });
        roundtrip(Message::ClockReply { t1: 1, t2: 2, t3: 3 });
        roundtrip(Message::ClockReply { t1: u64::MAX, t2: 0, t3: u64::MAX });
    }

    /// The v7 clock frames: fixed layouts (a probe is opcode + u64 t1, a
    /// reply echoes t1 and adds t2/t3), and truncation fails cleanly.
    #[test]
    fn clock_frames_pin_layout() {
        let enc = Message::ClockProbe { t1: 0x0102030405060708 }.encode();
        let mut expect = vec![15u8];
        expect.extend_from_slice(&0x0102030405060708u64.to_le_bytes());
        assert_eq!(&enc[4..], &expect[..]);
        let enc = Message::ClockReply { t1: 7, t2: 9, t3: 11 }.encode();
        let mut expect = vec![16u8];
        expect.extend_from_slice(&7u64.to_le_bytes());
        expect.extend_from_slice(&9u64.to_le_bytes());
        expect.extend_from_slice(&11u64.to_le_bytes());
        assert_eq!(&enc[4..], &expect[..]);
        assert!(Message::decode(&enc[4..enc.len() - 3]).is_err(), "truncated reply");
        assert!(Message::decode(&[15u8, 1, 2]).is_err(), "truncated probe");
    }

    /// Append a v7 trace context to an encoded frame, refreshing the
    /// length prefix — the reference construction the send paths must
    /// match.
    fn with_ctx(mut enc: Vec<u8>, ctx: TraceCtx) -> Vec<u8> {
        enc.extend_from_slice(&ctx.to_bytes());
        let frame_len = (enc.len() - 4) as u32;
        enc[..4].copy_from_slice(&frame_len.to_le_bytes());
        enc
    }

    /// The v7 trace context: rides after the slab of `Push`/`PullReply`,
    /// roundtrips through the ctx-aware decoder, stays invisible to the
    /// v6-style decoder, and context-free frames still decode (the compat
    /// rule).
    #[test]
    fn trace_context_roundtrips_after_the_slab() {
        let data = slab::from_f32s(&[1.0, -2.0, 4.5]);
        let push =
            Message::Push { iter: 3, lo: 0, hi: 1, codec: CodecId::Fp32, data: data.clone() };
        let ctx = TraceCtx::sampled(0xDEAD_BEEF_CAFE_F00D, 41);
        let enc = with_ctx(push.encode(), ctx);
        let (msg, got) = MessageRef::decode_with_ctx(&enc[4..]).unwrap();
        assert_eq!(msg.into_owned(), push);
        assert_eq!(got, Some(ctx));
        // The v6-style decoder validates and discards the context.
        assert_eq!(Message::decode(&enc[4..]).unwrap(), push);
        // Context-free v6 frames stay accepted: ctx comes back None.
        let enc = push.encode();
        let (msg, got) = MessageRef::decode_with_ctx(&enc[4..]).unwrap();
        assert_eq!(msg.into_owned(), push);
        assert_eq!(got, None);
        // Reply-direction context on a PullReply.
        let reply = Message::PullReply {
            iter: 3,
            lo: 0,
            hi: 1,
            applied: 2,
            codec: CodecId::Fp32,
            data,
        };
        let ctx = TraceCtx::reply(77, 12);
        assert!(ctx.is_reply());
        let enc = with_ctx(reply.encode(), ctx);
        let (msg, got) = MessageRef::decode_with_ctx(&enc[4..]).unwrap();
        assert_eq!(msg.into_owned(), reply);
        assert_eq!(got, Some(ctx));
    }

    /// Malformed trace contexts are rejected: unknown flag bits, a clear
    /// sampled bit, wrong trailing lengths, and contexts on frames that
    /// cannot carry one.
    #[test]
    fn decode_rejects_malformed_trace_context() {
        let push = Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[1.0]),
        };
        // Unknown flag bits (0xAA has bits 3/5/7 set).
        let bad = TraceCtx { trace_id: 1, parent_span: 1, flags: 0xAA };
        assert!(Message::decode(&with_ctx(push.encode(), bad)[4..]).is_err());
        // Sampled bit clear: a context would never be attached unsampled.
        let bad = TraceCtx { trace_id: 1, parent_span: 1, flags: 0 };
        assert!(Message::decode(&with_ctx(push.encode(), bad)[4..]).is_err());
        let bad = TraceCtx { trace_id: 1, parent_span: 1, flags: TraceCtx::FLAG_REPLY };
        assert!(Message::decode(&with_ctx(push.encode(), bad)[4..]).is_err());
        // A truncated (12-byte) and padded (14-byte) context both reject
        // as trailing garbage.
        let ok = TraceCtx::sampled(1, 1);
        let mut enc = with_ctx(push.encode(), ok);
        enc.truncate(enc.len() - 1);
        let frame_len = (enc.len() - 4) as u32;
        enc[..4].copy_from_slice(&frame_len.to_le_bytes());
        assert!(Message::decode(&enc[4..]).is_err(), "12-byte context accepted");
        let mut enc = with_ctx(push.encode(), ok);
        enc.push(0);
        let frame_len = (enc.len() - 4) as u32;
        enc[..4].copy_from_slice(&frame_len.to_le_bytes());
        assert!(Message::decode(&enc[4..]).is_err(), "14-byte context accepted");
        // Non-tensor frames never carry a context: 13 trailing bytes on a
        // Pull are trailing garbage even when they parse as a context.
        let mut enc = Message::Pull { iter: 1, lo: 0, hi: 0 }.encode();
        enc.extend_from_slice(&ok.to_bytes());
        let frame_len = (enc.len() - 4) as u32;
        enc[..4].copy_from_slice(&frame_len.to_le_bytes());
        assert!(Message::decode(&enc[4..]).is_err(), "context on a Pull accepted");
    }

    /// The ctx-aware send paths emit `[header][slab][ctx]` byte-identical
    /// to the reference construction, over a real socket, for both the
    /// borrowed-slab and the scattered-parts writers — and the pooled
    /// receiver surfaces the context.
    #[test]
    fn ctx_send_paths_match_reference_over_tcp() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            let pool = crate::net::pool::SlabPool::new();
            // First frame: pooled receive surfaces the context.
            let first = match conn.recv_pooled(&pool).unwrap() {
                RecvMsg::Push { iter, codec, data, ctx, .. } => {
                    assert_eq!(iter, 5);
                    assert_eq!(codec, CodecId::Fp32);
                    (data[..].to_vec(), ctx)
                }
                m => panic!("{m:?}"),
            };
            // Second frame: scattered parts + context.
            let second = match conn.recv_pooled(&pool).unwrap() {
                RecvMsg::Push { data, ctx, .. } => (data[..].to_vec(), ctx),
                m => panic!("{m:?}"),
            };
            // Third: a ctx-carrying PullReply through recv_ref_ctx.
            let (msg, ctx) = conn.recv_ref_ctx().unwrap();
            let third = (msg.into_owned(), ctx);
            (first, second, third)
        });
        let data = slab::from_f32s(&[2.0; 64]);
        let ctx = TraceCtx::sampled(99, 7);
        let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
        let push =
            Message::Push { iter: 5, lo: 0, hi: 1, codec: CodecId::Fp32, data: data.clone() };
        conn.send_ctx(&push, Some(ctx)).unwrap();
        let (a, b) = data.split_at(128);
        conn.send_push_parts(5, 0, 1, CodecId::Fp32, &[a, b], Some(ctx)).unwrap();
        let reply_ctx = TraceCtx::reply(99, 13);
        let reply = Message::PullReply {
            iter: 5,
            lo: 0,
            hi: 1,
            applied: 5,
            codec: CodecId::Fp32,
            data: data.clone(),
        };
        conn.send_ctx(&reply, Some(reply_ctx)).unwrap();
        let (first, second, third) = t.join().unwrap();
        assert_eq!(first, (data.clone(), Some(ctx)));
        assert_eq!(second, (data.clone(), Some(ctx)));
        assert_eq!(third, (reply, Some(reply_ctx)));
    }

    /// The v6 mid-run-join frames: layouts, and the malformed-fleet-size
    /// rejection rule (a zero `workers` could never weight a barrier).
    #[test]
    fn snapshot_frames_pin_layout_and_validate_fleet_size() {
        // SnapshotReq: opcode + u32 lo + u32 hi.
        let enc = Message::SnapshotReq { lo: 2, hi: 5 }.encode();
        assert_eq!(&enc[4..], &[13u8, 2, 0, 0, 0, 5, 0, 0, 0]);
        // SnapshotReply: opcode + u64 iter + u32 lo + u32 hi + u32 workers
        // + slab field + slab — `workers` rides where PullReply's
        // `applied` tail would sit, before the slab field.
        let data = slab::from_f32s(&[7.0]);
        let enc = Message::SnapshotReply {
            iter: 9,
            lo: 1,
            hi: 1,
            workers: 4,
            codec: CodecId::Fp32,
            data: data.clone(),
        }
        .encode();
        let mut expect = vec![14u8];
        expect.extend_from_slice(&9u64.to_le_bytes());
        expect.extend_from_slice(&1u32.to_le_bytes());
        expect.extend_from_slice(&1u32.to_le_bytes());
        expect.extend_from_slice(&4u32.to_le_bytes());
        expect.extend_from_slice(&(data.len() as u32).to_le_bytes());
        expect.extend_from_slice(&data);
        assert_eq!(&enc[4..], &expect[..]);
        assert_eq!(SNAPSHOT_REPLY_SLAB_OFF, 25);
        // A zero fleet size is malformed.
        let mut bad = expect.clone();
        bad[17..21].copy_from_slice(&0u32.to_le_bytes());
        assert!(Message::decode(&bad).is_err(), "zero workers accepted");
        // Truncated frames fail cleanly.
        assert!(Message::decode(&expect[..12]).is_err());
    }

    /// The v5 aggregator registration frame: layout, and the malformed-
    /// role rejection rules (unknown role tag; zero worker count; an edge
    /// role claiming a group's worth of workers).
    #[test]
    fn agg_hello_validates_role_and_worker_count() {
        // Layout: opcode + role tag + u32 group + u32 workers + u16 version.
        let enc = Message::AggHello {
            role: PeerRole::Regional,
            group: 3,
            workers: 7,
            version: 5,
        }
        .encode();
        assert_eq!(&enc[4..], &[12u8, 1, 3, 0, 0, 0, 7, 0, 0, 0, 5, 0]);
        // Unknown role tag 2 is rejected.
        assert!(Message::decode(&[12, 2, 3, 0, 0, 0, 7, 0, 0, 0, 5, 0]).is_err());
        // A zero worker count can never satisfy a barrier: malformed.
        assert!(Message::decode(&[12, 1, 3, 0, 0, 0, 0, 0, 0, 0, 5, 0]).is_err());
        // An edge role is a single device; workers > 1 is malformed...
        assert!(Message::decode(&[12, 0, 3, 0, 0, 0, 7, 0, 0, 0, 5, 0]).is_err());
        // ...while exactly 1 decodes.
        match Message::decode(&[12, 0, 3, 0, 0, 0, 1, 0, 0, 0, 5, 0]).unwrap() {
            Message::AggHello { role, group, workers, version } => {
                assert_eq!(role, PeerRole::Edge);
                assert_eq!(group, 3);
                assert_eq!(workers, 1);
                assert_eq!(version, 5);
            }
            m => panic!("{m:?}"),
        }
        // Truncated frames fail cleanly.
        assert!(Message::decode(&[12, 1, 3, 0]).is_err());
    }

    /// The v4 sync frames: layout, and the malformed-staleness-bound
    /// rejection rules (unknown mode tag; bound outside SSP).
    #[test]
    fn sync_frames_validate_mode_and_bound() {
        // Layout: opcode + mode tag + u32 bound.
        let enc = Message::SyncPropose { mode: SyncMode::Ssp, bound: 7 }.encode();
        assert_eq!(&enc[4..], &[10u8, 1, 7, 0, 0, 0]);
        let enc = Message::SyncAgree { mode: SyncMode::Asp, bound: 0 }.encode();
        assert_eq!(&enc[4..], &[11u8, 2, 0, 0, 0, 0]);
        // Unknown mode tag 3 is rejected.
        assert!(Message::decode(&[10, 3, 0, 0, 0, 0]).is_err());
        // A non-zero staleness bound is malformed outside SSP.
        assert!(Message::decode(&[10, 0, 1, 0, 0, 0]).is_err(), "bsp with bound");
        assert!(Message::decode(&[11, 2, 1, 0, 0, 0]).is_err(), "asp with bound");
        // ...but fine (any value) under SSP.
        match Message::decode(&[11, 1, 255, 0, 0, 0]).unwrap() {
            Message::SyncAgree { mode, bound } => {
                assert_eq!(mode, SyncMode::Ssp);
                assert_eq!(bound, 255);
            }
            m => panic!("{m:?}"),
        }
    }

    /// Codec-tagged tensor frames roundtrip with the tag intact and the
    /// payload decodable by the tagged codec.
    #[test]
    fn codec_tagged_slabs_roundtrip() {
        let vals: Vec<f32> = (0..300).map(|i| i as f32 * 0.125 - 7.0).collect();
        let raw = slab::from_f32s(&vals);
        for id in CodecId::ALL {
            let mut wire = Vec::new();
            id.codec().encode(&raw, &mut wire);
            let m = Message::Push { iter: 4, lo: 0, hi: 2, codec: id, data: wire };
            roundtrip(m.clone());
            let enc = m.encode();
            match Message::decode(&enc[4..]).unwrap() {
                Message::Push { codec, data, .. } => {
                    assert_eq!(codec, id);
                    let mut back = Vec::new();
                    id.codec().decode(&data, &mut back).unwrap();
                    assert_eq!(back.len(), raw.len());
                }
                m => panic!("{m:?}"),
            }
        }
    }

    /// The fp32 `Push` byte-identity property (unchanged since v2: the v4
    /// `applied` field rides only on `PullReply`), plus the v4 `PullReply`
    /// layout: the v2/v3 fields with `applied: u64` inserted before the
    /// slab-length field.
    #[test]
    fn fp32_push_frames_are_byte_identical_to_v2_and_pull_reply_carries_applied() {
        let vals: Vec<f32> = (0..777).map(|i| (i as f32).cos() * 3.0).collect();
        let data = slab::from_f32s(&vals);
        let v2 = |opcode: u8, iter: u64, lo: u32, hi: u32, data: &[u8]| -> Vec<u8> {
            // The v2 layout, reconstructed independently of the encoder.
            let wire_size = 1 + 8 + 4 + 4 + 4 + data.len();
            let mut buf = Vec::with_capacity(4 + wire_size);
            buf.extend_from_slice(&(wire_size as u32).to_le_bytes());
            buf.push(opcode);
            buf.extend_from_slice(&iter.to_le_bytes());
            buf.extend_from_slice(&lo.to_le_bytes());
            buf.extend_from_slice(&hi.to_le_bytes());
            buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
            buf.extend_from_slice(data);
            buf
        };
        let push =
            Message::Push { iter: 5, lo: 0, hi: 1, codec: CodecId::Fp32, data: data.clone() };
        assert_eq!(push.encode(), v2(3, 5, 0, 1, &data));
        // And a v2-shaped Push frame decodes as an fp32-tagged frame.
        let enc = v2(3, 5, 0, 1, &data);
        assert_eq!(Message::decode(&enc[4..]).unwrap(), push);
        // v4 PullReply: the v2 reply layout widened by `applied` right
        // after `hi` — reconstructed independently of the encoder.
        let reply = Message::PullReply {
            iter: 12,
            lo: 3,
            hi: 9,
            applied: 11,
            codec: CodecId::Fp32,
            data: data.clone(),
        };
        let mut v4 = Vec::new();
        let wire_size = 1 + 8 + 4 + 4 + 8 + 4 + data.len();
        v4.extend_from_slice(&(wire_size as u32).to_le_bytes());
        v4.push(2);
        v4.extend_from_slice(&12u64.to_le_bytes());
        v4.extend_from_slice(&3u32.to_le_bytes());
        v4.extend_from_slice(&9u32.to_le_bytes());
        v4.extend_from_slice(&11u64.to_le_bytes());
        v4.extend_from_slice(&(data.len() as u32).to_le_bytes());
        v4.extend_from_slice(&data);
        assert_eq!(reply.encode(), v4);
        // Non-fp32 codecs tag the slab-length field (and only it).
        let mut wire = Vec::new();
        CodecId::Fp16.codec().encode(&data, &mut wire);
        let tagged = Message::Push {
            iter: 5,
            lo: 0,
            hi: 1,
            codec: CodecId::Fp16,
            data: wire.clone(),
        }
        .encode();
        let untagged = v2(3, 5, 0, 1, &wire);
        assert_eq!(tagged.len(), untagged.len());
        let field = 4 + 1 + 8 + 4 + 4; // prefix + op + iter + lo + hi
        assert_eq!(tagged[..field], untagged[..field]);
        assert_eq!(tagged[field + 4..], untagged[field + 4..]);
        let f = u32::from_le_bytes(tagged[field..field + 4].try_into().unwrap());
        assert_eq!(f >> 30, CodecId::Fp16.tag() as u32);
        assert_eq!((f & SLAB_LEN_MASK) as usize, wire.len());
    }

    #[test]
    fn slab_payload_survives_the_wire_bit_exactly() {
        let vals: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 1e3).collect();
        let m = Message::Push {
            iter: 1,
            lo: 0,
            hi: 9,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&vals),
        };
        let enc = m.encode();
        match Message::decode(&enc[4..]).unwrap() {
            Message::Push { data, .. } => assert_eq!(slab::to_f32s(&data), vals),
            m => panic!("{m:?}"),
        }
    }

    /// Random codec + a wire-valid payload length for it (contents are
    /// opaque to the transport).
    fn random_codec_data(rng: &mut Rng) -> (CodecId, Vec<u8>) {
        let codec = CodecId::ALL[rng.below(3)];
        let elems = rng.below(64);
        let n = codec.wire_len(4 * elems);
        (codec, (0..n).map(|_| rng.below(256) as u8).collect())
    }

    /// Random sync frame payload: any mode, with a bound only under SSP.
    fn random_sync(rng: &mut Rng) -> (SyncMode, u32) {
        let mode = SyncMode::ALL[rng.below(3)];
        let bound = if mode == SyncMode::Ssp { rng.below(16) as u32 } else { 0 };
        (mode, bound)
    }

    fn random_message(rng: &mut Rng) -> Message {
        match rng.below(16) {
            0 => Message::Pull { iter: rng.below(1 << 20) as u64, lo: 0, hi: 7 },
            1 => {
                let (codec, data) = random_codec_data(rng);
                let applied = rng.below(10) as u64;
                Message::PullReply { iter: 3, lo: 1, hi: 5, applied, codec, data }
            }
            2 => {
                let (codec, data) = random_codec_data(rng);
                Message::Push { iter: 9, lo: 0, hi: 2, codec, data }
            }
            3 => Message::PushAck { iter: 1, lo: 0, hi: 0 },
            4 => Message::Hello { worker: rng.below(64) as u32, version: 3 },
            5 => Message::HelloAck { workers: 8, version: 3 },
            6 => Message::CodecPropose { pref: CodecId::ALL[rng.below(3)] },
            7 => Message::CodecAgree { codec: CodecId::ALL[rng.below(3)] },
            8 => {
                let (mode, bound) = random_sync(rng);
                Message::SyncPropose { mode, bound }
            }
            9 => {
                let (mode, bound) = random_sync(rng);
                Message::SyncAgree { mode, bound }
            }
            10 => {
                // v5: a regional registration carries any positive worker
                // count; an edge one exactly 1.
                let regional = rng.bool();
                Message::AggHello {
                    role: if regional { PeerRole::Regional } else { PeerRole::Edge },
                    group: rng.below(16) as u32,
                    workers: if regional { 1 + rng.below(64) as u32 } else { 1 },
                    version: rng.below(1 << 16) as u16,
                }
            }
            11 => Message::SnapshotReq { lo: 0, hi: rng.below(16) as u32 },
            12 => {
                let (codec, data) = random_codec_data(rng);
                Message::SnapshotReply {
                    iter: rng.below(1 << 20) as u64,
                    lo: 0,
                    hi: 7,
                    workers: 1 + rng.below(64) as u32,
                    codec,
                    data,
                }
            }
            13 => Message::ClockProbe { t1: rng.below(1 << 30) as u64 },
            14 => Message::ClockReply {
                t1: rng.below(1 << 30) as u64,
                t2: rng.below(1 << 30) as u64,
                t3: rng.below(1 << 30) as u64,
            },
            _ => Message::Shutdown,
        }
    }

    /// The vectored framing contract: for every message variant, the
    /// header produced by `encode_header_into` followed by the borrowed
    /// payload is byte-identical to the legacy contiguous `encode`.
    #[test]
    fn vectored_framing_matches_legacy_encode_for_every_variant() {
        let mut rng = Rng::new(417);
        let mut hdr = Vec::new();
        for _ in 0..500 {
            let m = random_message(&mut rng);
            let legacy = m.encode();
            let payload = m.wire_ref().encode_header_into(&mut hdr);
            let mut vectored = hdr.clone();
            vectored.extend_from_slice(payload);
            assert_eq!(vectored, legacy, "framing diverged for {m:?}");
            // And the borrowed decoder agrees with the owned one.
            assert_eq!(
                MessageRef::decode(&legacy[4..]).unwrap().into_owned(),
                Message::decode(&legacy[4..]).unwrap()
            );
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[1, 0, 0]).is_err()); // truncated
        // trailing bytes
        let mut enc = Message::Hello { worker: 1, version: 1 }.encode();
        enc.push(0);
        assert!(Message::decode(&enc[4..]).is_err());
        // a pre-versioning (v1) Hello lacks the version field: rejected as
        // truncated rather than misread.
        let legacy = [5u8, 1, 0, 0, 0]; // opcode + worker u32 only
        assert!(Message::decode(&legacy).is_err());
    }

    /// Rewrite a Push frame's slab-length field and payload, refreshing
    /// the frame-length prefix.
    fn forged_push_frame(field: u32, payload: &[u8]) -> Vec<u8> {
        let mut enc = Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: Vec::new(),
        }
        .encode();
        let len_field = 4 + 1 + 8 + 4 + 4; // prefix + op + iter + lo + hi
        enc[len_field..len_field + 4].copy_from_slice(&field.to_le_bytes());
        enc.extend_from_slice(payload);
        let frame_len = (enc.len() - 4) as u32;
        enc[..4].copy_from_slice(&frame_len.to_le_bytes());
        enc
    }

    #[test]
    fn decode_rejects_misaligned_slab() {
        // A Push whose slab-length field claims 3 bytes: not f32-aligned.
        let enc = forged_push_frame(3, &[0, 0, 0]);
        assert!(Message::decode(&enc[4..]).is_err());
    }

    #[test]
    fn decode_rejects_bad_codec_framing() {
        // Tag 3 is not a codec.
        let enc = forged_push_frame(4 | (3 << 30), &[0; 4]);
        assert!(Message::decode(&enc[4..]).is_err(), "tag 3 accepted");
        // fp16 slabs must be 2-aligned.
        let enc = forged_push_frame(3 | (1 << 30), &[0; 3]);
        assert!(Message::decode(&enc[4..]).is_err(), "odd fp16 slab accepted");
        // int8 payloads are concatenations of per-layer chunked encodings,
        // so the transport accepts any length — including ones that are
        // NOT a valid single slab, like 1031 + 9 (layers of 1023 and 1
        // elements), whose per-layer framing only the endpoint's byte
        // tables can check.
        for n in [9usize, 1031 + 9, 7, 8] {
            let enc = forged_push_frame(n as u32 | (2 << 30), &vec![0u8; n]);
            match Message::decode(&enc[4..]).unwrap() {
                Message::Push { codec, data, .. } => {
                    assert_eq!(codec, CodecId::Int8);
                    assert_eq!(data.len(), n);
                }
                m => panic!("{m:?}"),
            }
        }
    }

    #[test]
    fn encode_into_reuses_capacity() {
        let m = Message::PullReply {
            iter: 1,
            lo: 0,
            hi: 0,
            applied: 1,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[0.5; 256]),
        };
        let mut buf = Vec::new();
        m.encode_into(&mut buf);
        let cap = buf.capacity();
        let first = buf.clone();
        m.encode_into(&mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.capacity(), cap, "warm re-encode must not reallocate");
    }

    #[test]
    fn frame_buf_grows_without_rezeroing_and_sheds_oversize() {
        let mut buf = Vec::new();
        prepare_frame_buf(&mut buf, 1024);
        assert_eq!(buf.len(), 1024);
        // Poison, then "receive" a smaller frame: the warm region must be
        // left alone (no memset), only sliced.
        buf.iter_mut().for_each(|b| *b = 0xEE);
        prepare_frame_buf(&mut buf, 16);
        assert_eq!(buf.len(), 1024, "warm length is the high-water mark");
        assert!(buf.iter().all(|&b| b == 0xEE), "warm bytes were re-zeroed");
        // One pathological frame must not pin its capacity forever.
        prepare_frame_buf(&mut buf, RECV_RETAIN_MAX + (4 << 20));
        assert!(buf.capacity() > RECV_RETAIN_MAX);
        prepare_frame_buf(&mut buf, 512);
        assert!(
            buf.capacity() <= RECV_RETAIN_MAX,
            "oversized capacity retained: {}",
            buf.capacity()
        );
        assert!(buf.len() >= 512);
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            let m = conn.recv().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
        let msg = Message::Push {
            iter: 42,
            lo: 2,
            hi: 5,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[3.25; 1000]),
        };
        conn.send(&msg).unwrap();
        assert_eq!(conn.recv().unwrap(), msg);
        t.join().unwrap();
    }

    /// An armed I/O deadline turns a silent peer into a timeout error
    /// instead of a forever-blocked `recv`; clearing it and a zero
    /// duration are both policed.
    #[test]
    fn io_timeout_fails_recv_instead_of_hanging() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
        let (_held, _) = listener.accept().unwrap(); // never writes
        conn.set_io_timeout(Some(std::time::Duration::from_millis(30))).unwrap();
        let start = std::time::Instant::now();
        assert!(conn.recv().is_err(), "recv from a silent peer must time out");
        assert!(start.elapsed() < std::time::Duration::from_secs(5));
        // Zero means "no timeout" to the OS — reject it loudly.
        assert!(conn.set_io_timeout(Some(std::time::Duration::ZERO)).is_err());
        // And None disarms.
        conn.set_io_timeout(None).unwrap();
    }

    /// A scattered push (one part per layer slab, including empty parts)
    /// must arrive byte-identical to the contiguous message.
    #[test]
    fn scattered_push_matches_contiguous_over_tcp() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            conn.recv().unwrap()
        });
        let a = slab::from_f32s(&[1.0; 300]);
        let b: Vec<u8> = Vec::new();
        let c = slab::from_f32s(&[-2.5; 77]);
        let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
        conn.send_push_parts(11, 0, 2, CodecId::Fp32, &[&a, &b, &c], None).unwrap();
        let mut expect = a.clone();
        expect.extend_from_slice(&c);
        assert_eq!(
            t.join().unwrap(),
            Message::Push { iter: 11, lo: 0, hi: 2, codec: CodecId::Fp32, data: expect }
        );
    }

    /// Pooled receive: tensor frames land in pool checkouts, views keep
    /// them alive, and the buffers recycle once the views drop.
    #[test]
    fn recv_pooled_views_and_recycles_frames() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let vals: Vec<f32> = (0..512).map(|i| i as f32 * 0.5).collect();
        let payload = slab::from_f32s(&vals);
        let payload2 = payload.clone();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            for i in 0..2 {
                conn.send(&Message::PullReply {
                    iter: i,
                    lo: 0,
                    hi: 3,
                    applied: i,
                    codec: CodecId::Fp32,
                    data: payload2.clone(),
                })
                .unwrap();
            }
            conn.send(&Message::Shutdown).unwrap();
        });
        let pool = crate::net::pool::SlabPool::new();
        let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
        let first = match conn.recv_pooled(&pool).unwrap() {
            RecvMsg::PullReply { iter, applied, data, .. } => {
                assert_eq!(iter, 0);
                assert_eq!(applied, 0, "v4 applied field survives the pooled path");
                assert_eq!(&data[..], &payload[..]);
                data
            }
            m => panic!("{m:?}"),
        };
        assert_eq!(pool.stats().allocations, 1);
        assert_eq!(pool.stats().retained, 0, "view holds the frame");
        drop(first);
        assert_eq!(pool.stats().retained, 1, "frame recycled after last view");
        match conn.recv_pooled(&pool).unwrap() {
            RecvMsg::PullReply { iter, data, .. } => {
                assert_eq!(iter, 1);
                assert_eq!(&data[..], &payload[..]);
            }
            m => panic!("{m:?}"),
        }
        let st = pool.stats();
        assert_eq!(st.allocations, 1, "second frame reused the first buffer");
        assert_eq!(st.recycled, 1);
        // Control frames come back owned with the checkout recycled.
        match conn.recv_pooled(&pool).unwrap() {
            RecvMsg::Control(Message::Shutdown) => {}
            m => panic!("{m:?}"),
        }
        t.join().unwrap();
    }

    /// The scattered writer must survive many tiny parts (several iovec
    /// batches) and interleaved empties.
    #[test]
    fn scattered_write_handles_many_small_parts() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            conn.recv().unwrap()
        });
        // 50 parts of 4 bytes each → 3+ iovec batches of 16.
        let layers: Vec<Vec<u8>> = (0..50u8).map(|i| vec![i; 4]).collect();
        let mut parts: Vec<&[u8]> = Vec::new();
        let empty: Vec<u8> = Vec::new();
        for l in &layers {
            parts.push(l);
            parts.push(&empty);
        }
        let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
        conn.send_push_parts(0, 0, 49, CodecId::Fp32, &parts, None).unwrap();
        let expect: Vec<u8> = layers.concat();
        match t.join().unwrap() {
            Message::Push { data, .. } => assert_eq!(data, expect),
            m => panic!("{m:?}"),
        }
    }
}
