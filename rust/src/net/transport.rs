//! Framed message transport over TCP.
//!
//! Wire format (specified in full in `docs/WIRE.md`): `u32 LE length` (of
//! everything after it) + `u8 opcode` + payload. Tensor payloads are
//! opaque little-endian f32 byte slabs ([`crate::net::slab`]) carried in
//! [`Message::PullReply`] / [`Message::Push`], so encode/decode are bulk
//! `extend_from_slice`/`copy_from_slice` operations — no per-element f32
//! loops anywhere on the wire path. Connections keep per-direction scratch
//! buffers, so steady-state send/recv performs no frame allocations.

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

/// Hard ceiling on a frame's payload size (corruption guard).
const MAX_FRAME: usize = 1 << 30;

/// Version of the wire protocol this build speaks (`docs/WIRE.md`; v1 was
/// the unversioned slab protocol). Carried in [`Message::Hello`] /
/// [`Message::HelloAck`] so mixed deployments fail loudly at registration
/// time instead of corrupting tensors mid-iteration: the server rejects a
/// mismatched `Hello`, and the worker rejects a mismatched `HelloAck`.
pub const PROTOCOL_VERSION: u16 = 2;

/// Protocol messages between edge workers and parameter servers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → server: pull parameters of layers `[lo, hi]` for `iter`.
    Pull { iter: u64, lo: u32, hi: u32 },
    /// Server → worker: the parameters as one byte slab — each owned
    /// layer's `w‖b` f32 data, little-endian, ascending layer order.
    PullReply { iter: u64, lo: u32, hi: u32, data: Vec<u8> },
    /// Worker → server: gradients of layers `[lo, hi]` for `iter`, as a
    /// byte slab with the same layout as [`Message::PullReply`].
    Push { iter: u64, lo: u32, hi: u32, data: Vec<u8> },
    /// Server → worker: push accepted.
    PushAck { iter: u64, lo: u32, hi: u32 },
    /// Worker → server: register with a worker id, announcing the
    /// worker's [`PROTOCOL_VERSION`].
    Hello { worker: u32, version: u16 },
    /// Server → worker: registration answer; reports cluster size and the
    /// server's [`PROTOCOL_VERSION`] (sent even on mismatch, so the worker
    /// can name both versions in its error).
    HelloAck { workers: u32, version: u16 },
    /// Either direction: tear the connection down.
    Shutdown,
}

impl Message {
    pub fn opcode(&self) -> u8 {
        match self {
            Message::Pull { .. } => 1,
            Message::PullReply { .. } => 2,
            Message::Push { .. } => 3,
            Message::PushAck { .. } => 4,
            Message::Hello { .. } => 5,
            Message::HelloAck { .. } => 6,
            Message::Shutdown => 7,
        }
    }

    /// Serialized payload size in bytes (excluding the length prefix).
    pub fn wire_size(&self) -> usize {
        1 + match self {
            Message::Pull { .. } => 8 + 4 + 4,
            Message::PullReply { data, .. } => 8 + 4 + 4 + 4 + data.len(),
            Message::Push { data, .. } => 8 + 4 + 4 + 4 + data.len(),
            Message::PushAck { .. } => 8 + 4 + 4,
            Message::Hello { .. } => 4 + 2,
            Message::HelloAck { .. } => 4 + 2,
            Message::Shutdown => 0,
        }
    }

    /// Encode the full frame (length prefix included) into a reusable
    /// buffer. The buffer is cleared first; capacity is retained across
    /// calls, so a warm buffer makes this allocation-free.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        buf.clear();
        buf.reserve(4 + self.wire_size());
        buf.extend_from_slice(&(self.wire_size() as u32).to_le_bytes());
        buf.push(self.opcode());
        match self {
            Message::Pull { iter, lo, hi } | Message::PushAck { iter, lo, hi } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            Message::PullReply { iter, lo, hi, data }
            | Message::Push { iter, lo, hi, data } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
                buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
                buf.extend_from_slice(data);
            }
            Message::Hello { worker, version } => {
                buf.extend_from_slice(&worker.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            Message::HelloAck { workers, version } => {
                buf.extend_from_slice(&workers.to_le_bytes());
                buf.extend_from_slice(&version.to_le_bytes());
            }
            Message::Shutdown => {}
        }
    }

    /// Encode into a fresh frame buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(&mut buf);
        buf
    }

    pub fn decode(payload: &[u8]) -> Result<Message> {
        anyhow::ensure!(!payload.is_empty(), "empty frame");
        let op = payload[0];
        let mut r = Reader { b: &payload[1..] };
        let msg = match op {
            1 => Message::Pull { iter: r.u64()?, lo: r.u32()?, hi: r.u32()? },
            2 => {
                let (iter, lo, hi) = (r.u64()?, r.u32()?, r.u32()?);
                Message::PullReply { iter, lo, hi, data: r.slab()? }
            }
            3 => {
                let (iter, lo, hi) = (r.u64()?, r.u32()?, r.u32()?);
                Message::Push { iter, lo, hi, data: r.slab()? }
            }
            4 => Message::PushAck { iter: r.u64()?, lo: r.u32()?, hi: r.u32()? },
            5 => Message::Hello { worker: r.u32()?, version: r.u16()? },
            6 => Message::HelloAck { workers: r.u32()?, version: r.u16()? },
            7 => Message::Shutdown,
            _ => bail!("unknown opcode {op}"),
        };
        anyhow::ensure!(r.b.is_empty(), "trailing bytes in frame (op {op})");
        Ok(msg)
    }
}

struct Reader<'a> {
    b: &'a [u8],
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.b.len() >= n, "truncated frame");
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed byte slab: one bulk copy, no per-element work.
    fn slab(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n % 4 == 0, "slab length {n} not f32-aligned");
        Ok(self.take(n)?.to_vec())
    }
}

/// A framed, optionally shaped, connection.
///
/// Each direction owns a scratch buffer (the per-connection scratch pool):
/// `send` encodes into `send_buf` and `recv` reads the frame into
/// `recv_buf`, so steady-state traffic reuses warm capacity instead of
/// allocating per message.
pub struct Connection {
    stream: TcpStream,
    shaper: Option<crate::net::LinkShaper>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
}

impl Connection {
    pub fn new(stream: TcpStream, shaper: Option<crate::net::LinkShaper>) -> Connection {
        stream.set_nodelay(true).ok();
        Connection { stream, shaper, send_buf: Vec::new(), recv_buf: Vec::new() }
    }

    /// Send one message. When shaped, sleeps for the emulated serialization
    /// + latency time before the bytes hit the socket.
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        msg.encode_into(&mut self.send_buf);
        if let Some(shaper) = &self.shaper {
            shaper.delay_for(self.send_buf.len());
        }
        self.stream.write_all(&self.send_buf).context("send")?;
        Ok(())
    }

    /// Receive one message (blocking).
    pub fn recv(&mut self) -> Result<Message> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).context("recv length")?;
        let len = u32::from_le_bytes(len) as usize;
        anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
        self.recv_buf.resize(len, 0);
        self.stream.read_exact(&mut self.recv_buf).context("recv payload")?;
        Message::decode(&self.recv_buf)
    }

    pub fn try_clone(&self) -> Result<Connection> {
        Ok(Connection {
            stream: self.stream.try_clone()?,
            shaper: self.shaper.clone(),
            send_buf: Vec::new(),
            recv_buf: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::slab;

    fn roundtrip(m: Message) {
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len, enc.len() - 4);
        assert_eq!(len, m.wire_size());
        assert_eq!(Message::decode(&enc[4..]).unwrap(), m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Pull { iter: 7, lo: 1, hi: 3 });
        roundtrip(Message::PullReply {
            iter: 7,
            lo: 1,
            hi: 3,
            data: slab::from_f32s(&[1.5, -2.0, 0.0]),
        });
        roundtrip(Message::Push { iter: 0, lo: 6, hi: 6, data: Vec::new() });
        roundtrip(Message::PushAck { iter: 1, lo: 2, hi: 4 });
        roundtrip(Message::Hello { worker: 3, version: PROTOCOL_VERSION });
        roundtrip(Message::HelloAck { workers: 8, version: PROTOCOL_VERSION });
        // Versions other than ours still travel intact — that is what lets
        // endpoints *name* the mismatched version in their error.
        roundtrip(Message::Hello { worker: 0, version: 0 });
        roundtrip(Message::HelloAck { workers: 1, version: u16::MAX });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn slab_payload_survives_the_wire_bit_exactly() {
        let vals: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 1e3).collect();
        let m = Message::Push { iter: 1, lo: 0, hi: 9, data: slab::from_f32s(&vals) };
        let enc = m.encode();
        match Message::decode(&enc[4..]).unwrap() {
            Message::Push { data, .. } => assert_eq!(slab::to_f32s(&data), vals),
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[1, 0, 0]).is_err()); // truncated
        // trailing bytes
        let mut enc = Message::Hello { worker: 1, version: 1 }.encode();
        enc.push(0);
        assert!(Message::decode(&enc[4..]).is_err());
        // a pre-versioning (v1) Hello lacks the version field: rejected as
        // truncated rather than misread.
        let legacy = [5u8, 1, 0, 0, 0]; // opcode + worker u32 only
        assert!(Message::decode(&legacy).is_err());
    }

    #[test]
    fn decode_rejects_misaligned_slab() {
        // A Push whose slab-length field claims 3 bytes: not f32-aligned.
        let mut enc = Message::Push { iter: 0, lo: 0, hi: 0, data: Vec::new() }.encode();
        let len_field = 4 + 1 + 8 + 4 + 4; // prefix + op + iter + lo + hi
        enc[len_field..len_field + 4].copy_from_slice(&3u32.to_le_bytes());
        enc.extend_from_slice(&[0, 0, 0]);
        let frame_len = (enc.len() - 4) as u32;
        enc[..4].copy_from_slice(&frame_len.to_le_bytes());
        assert!(Message::decode(&enc[4..]).is_err());
    }

    #[test]
    fn encode_into_reuses_capacity() {
        let m = Message::PullReply {
            iter: 1,
            lo: 0,
            hi: 0,
            data: slab::from_f32s(&[0.5; 256]),
        };
        let mut buf = Vec::new();
        m.encode_into(&mut buf);
        let cap = buf.capacity();
        let first = buf.clone();
        m.encode_into(&mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.capacity(), cap, "warm re-encode must not reallocate");
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            let m = conn.recv().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn =
            Connection::new(TcpStream::connect(addr).unwrap(), None);
        let msg = Message::Push {
            iter: 42,
            lo: 2,
            hi: 5,
            data: slab::from_f32s(&[3.25; 1000]),
        };
        conn.send(&msg).unwrap();
        assert_eq!(conn.recv().unwrap(), msg);
        t.join().unwrap();
    }
}
