//! Framed message transport over TCP.
//!
//! Wire format: `u32 LE length` (of everything after it) + `u8 opcode` +
//! payload. Payloads carry layer ranges and f32 tensor data; everything is
//! little-endian and hand-serialized (no serde in the offline build).

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

/// Protocol messages between edge workers and parameter servers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → server: pull parameters of layers `[lo, hi]` for `iter`.
    Pull { iter: u64, lo: u32, hi: u32 },
    /// Server → worker: the parameters, layer tensors concatenated
    /// (weights then bias per layer, ascending).
    PullReply { iter: u64, lo: u32, hi: u32, data: Vec<f32> },
    /// Worker → server: push gradients of layers `[lo, hi]` for `iter`.
    Push { iter: u64, lo: u32, hi: u32, data: Vec<f32> },
    /// Server → worker: push accepted.
    PushAck { iter: u64, lo: u32, hi: u32 },
    /// Worker → server: register with a worker id.
    Hello { worker: u32 },
    /// Server → worker: registration accepted; reports cluster size.
    HelloAck { workers: u32 },
    /// Either direction: tear the connection down.
    Shutdown,
}

impl Message {
    pub fn opcode(&self) -> u8 {
        match self {
            Message::Pull { .. } => 1,
            Message::PullReply { .. } => 2,
            Message::Push { .. } => 3,
            Message::PushAck { .. } => 4,
            Message::Hello { .. } => 5,
            Message::HelloAck { .. } => 6,
            Message::Shutdown => 7,
        }
    }

    /// Serialized payload size in bytes (excluding the length prefix).
    pub fn wire_size(&self) -> usize {
        1 + match self {
            Message::Pull { .. } => 8 + 4 + 4,
            Message::PullReply { data, .. } => 8 + 4 + 4 + 4 + 4 * data.len(),
            Message::Push { data, .. } => 8 + 4 + 4 + 4 + 4 * data.len(),
            Message::PushAck { .. } => 8 + 4 + 4,
            Message::Hello { .. } => 4,
            Message::HelloAck { .. } => 4,
            Message::Shutdown => 0,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(4 + self.wire_size());
        buf.extend_from_slice(&(self.wire_size() as u32).to_le_bytes());
        buf.push(self.opcode());
        match self {
            Message::Pull { iter, lo, hi } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            Message::PullReply { iter, lo, hi, data }
            | Message::Push { iter, lo, hi, data } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
                buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Message::PushAck { iter, lo, hi } => {
                buf.extend_from_slice(&iter.to_le_bytes());
                buf.extend_from_slice(&lo.to_le_bytes());
                buf.extend_from_slice(&hi.to_le_bytes());
            }
            Message::Hello { worker } => buf.extend_from_slice(&worker.to_le_bytes()),
            Message::HelloAck { workers } => buf.extend_from_slice(&workers.to_le_bytes()),
            Message::Shutdown => {}
        }
        buf
    }

    pub fn decode(payload: &[u8]) -> Result<Message> {
        anyhow::ensure!(!payload.is_empty(), "empty frame");
        let op = payload[0];
        let mut r = Reader { b: &payload[1..] };
        let msg = match op {
            1 => Message::Pull { iter: r.u64()?, lo: r.u32()?, hi: r.u32()? },
            2 => {
                let (iter, lo, hi) = (r.u64()?, r.u32()?, r.u32()?);
                let n = r.u32()? as usize;
                Message::PullReply { iter, lo, hi, data: r.f32s(n)? }
            }
            3 => {
                let (iter, lo, hi) = (r.u64()?, r.u32()?, r.u32()?);
                let n = r.u32()? as usize;
                Message::Push { iter, lo, hi, data: r.f32s(n)? }
            }
            4 => Message::PushAck { iter: r.u64()?, lo: r.u32()?, hi: r.u32()? },
            5 => Message::Hello { worker: r.u32()? },
            6 => Message::HelloAck { workers: r.u32()? },
            7 => Message::Shutdown,
            _ => bail!("unknown opcode {op}"),
        };
        anyhow::ensure!(r.b.is_empty(), "trailing bytes in frame (op {op})");
        Ok(msg)
    }
}

struct Reader<'a> {
    b: &'a [u8],
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.b.len() >= n, "truncated frame");
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A framed, optionally shaped, connection.
pub struct Connection {
    stream: TcpStream,
    shaper: Option<crate::net::LinkShaper>,
}

impl Connection {
    pub fn new(stream: TcpStream, shaper: Option<crate::net::LinkShaper>) -> Connection {
        stream.set_nodelay(true).ok();
        Connection { stream, shaper }
    }

    /// Send one message. When shaped, sleeps for the emulated serialization
    /// + latency time before the bytes hit the socket.
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        let buf = msg.encode();
        if let Some(shaper) = &self.shaper {
            shaper.delay_for(buf.len());
        }
        self.stream.write_all(&buf).context("send")?;
        Ok(())
    }

    /// Receive one message (blocking).
    pub fn recv(&mut self) -> Result<Message> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).context("recv length")?;
        let len = u32::from_le_bytes(len) as usize;
        anyhow::ensure!(len <= 1 << 30, "frame too large: {len}");
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload).context("recv payload")?;
        Message::decode(&payload)
    }

    pub fn try_clone(&self) -> Result<Connection> {
        Ok(Connection {
            stream: self.stream.try_clone()?,
            shaper: self.shaper.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) {
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len, enc.len() - 4);
        assert_eq!(Message::decode(&enc[4..]).unwrap(), m);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Pull { iter: 7, lo: 1, hi: 3 });
        roundtrip(Message::PullReply {
            iter: 7,
            lo: 1,
            hi: 3,
            data: vec![1.5, -2.0, 0.0],
        });
        roundtrip(Message::Push { iter: 0, lo: 6, hi: 6, data: vec![] });
        roundtrip(Message::PushAck { iter: 1, lo: 2, hi: 4 });
        roundtrip(Message::Hello { worker: 3 });
        roundtrip(Message::HelloAck { workers: 8 });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[1, 0, 0]).is_err()); // truncated
        // trailing bytes
        let mut enc = Message::Hello { worker: 1 }.encode();
        enc.push(0);
        assert!(Message::decode(&enc[4..]).is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Connection::new(s, None);
            let m = conn.recv().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn =
            Connection::new(TcpStream::connect(addr).unwrap(), None);
        let msg = Message::Push { iter: 42, lo: 2, hi: 5, data: vec![3.25; 1000] };
        conn.send(&msg).unwrap();
        assert_eq!(conn.recv().unwrap(), msg);
        t.join().unwrap();
    }
}
