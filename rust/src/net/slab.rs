//! Little-endian f32 ⇄ byte-slab helpers.
//!
//! The wire protocol (see `docs/WIRE.md`) moves tensors as contiguous
//! little-endian f32 byte slabs, so the hot path copies bytes with
//! `extend_from_slice`/`copy_from_slice` and only materializes `f32`
//! values where arithmetic actually happens (server-side SGD, gradient
//! accumulation, tensor handoff to the runtime). These helpers are the
//! single place that encodes the f32 ⇄ bytes convention; everything is
//! safe code over 4-byte chunks.

/// Bytes per encoded f32 element.
pub const ELEM: usize = 4;

/// Number of f32 elements a slab holds. Panics if the slab is misaligned
/// (decode validates alignment at the protocol boundary).
pub fn len_f32s(bytes: &[u8]) -> usize {
    assert!(bytes.len() % ELEM == 0, "slab length {} not f32-aligned", bytes.len());
    bytes.len() / ELEM
}

/// Append `src` to `dst` as little-endian bytes.
pub fn extend_f32s(dst: &mut Vec<u8>, src: &[f32]) {
    dst.reserve(ELEM * src.len());
    for v in src {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a fresh slab from f32 values.
pub fn from_f32s(src: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(ELEM * src.len());
    extend_f32s(&mut out, src);
    out
}

/// Iterate a slab's f32 values without allocating.
pub fn f32_iter(bytes: &[u8]) -> impl Iterator<Item = f32> + '_ {
    assert!(bytes.len() % ELEM == 0, "slab length {} not f32-aligned", bytes.len());
    bytes
        .chunks_exact(ELEM)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
}

/// Decode a slab into a freshly allocated f32 vector.
pub fn to_f32s(bytes: &[u8]) -> Vec<f32> {
    f32_iter(bytes).collect()
}

/// `acc[i] += slab[i]` — gradient accumulation directly off the wire.
pub fn add_assign_f32s(acc: &mut [f32], bytes: &[u8]) {
    assert_eq!(acc.len() * ELEM, bytes.len(), "slab/accumulator length mismatch");
    for (a, v) in acc.iter_mut().zip(f32_iter(bytes)) {
        *a += v;
    }
}

/// In-place paired transform over a slab: `slab[i] = f(slab[i], other[i])`
/// through safe chunked f32 views (e.g. the server's SGD step).
pub fn zip_map_f32s(bytes: &mut [u8], other: &[f32], mut f: impl FnMut(f32, f32) -> f32) {
    assert_eq!(bytes.len(), ELEM * other.len(), "slab/operand length mismatch");
    for (chunk, &o) in bytes.chunks_exact_mut(ELEM).zip(other) {
        let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        chunk.copy_from_slice(&f(v, o).to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 3.4e38];
        let slab = from_f32s(&vals);
        assert_eq!(slab.len(), ELEM * vals.len());
        assert_eq!(len_f32s(&slab), vals.len());
        assert_eq!(to_f32s(&slab), vals);
    }

    #[test]
    fn explicit_layout_is_little_endian() {
        assert_eq!(from_f32s(&[1.0]), vec![0x00, 0x00, 0x80, 0x3f]);
    }

    #[test]
    fn extend_appends() {
        let mut slab = from_f32s(&[1.0]);
        extend_f32s(&mut slab, &[2.0, 3.0]);
        assert_eq!(to_f32s(&slab), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn accumulate() {
        let mut acc = vec![1.0f32, 2.0];
        add_assign_f32s(&mut acc, &from_f32s(&[0.5, -1.0]));
        assert_eq!(acc, vec![1.5, 1.0]);
    }

    #[test]
    fn zip_map_transforms_in_place() {
        let mut slab = from_f32s(&[1.0, 2.0, 3.0]);
        zip_map_f32s(&mut slab, &[1.0, -1.0, 0.0], |w, g| w - 0.5 * g);
        assert_eq!(to_f32s(&slab), vec![0.5, 2.5, 3.0]);
    }

    #[test]
    #[should_panic]
    fn misaligned_slab_panics() {
        let _ = to_f32s(&[0u8, 1, 2]);
    }
}
