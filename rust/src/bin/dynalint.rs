//! `dynalint` — run the in-repo static-analysis pass and gate CI on it.
//!
//! Exit status: 0 clean, 1 findings, 2 analyzer error (missing manifest,
//! unreadable source, malformed manifest TOML).
//!
//! ```text
//! cargo run --release --bin dynalint -- [--root DIR] [--json PATH] [--quiet]
//! ```
//!
//! `--root` defaults to the current directory and must hold `Cargo.toml`
//! plus the manifest at `rust/src/analysis/dynalint.toml`. `--json` also
//! writes the machine-readable report (schema in `docs/ANALYSIS.md`) for
//! CI artifact upload; parent directories are created as needed.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use dynacomm::analysis;
use dynacomm::util::cli::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let root = PathBuf::from(args.get_or("root", "."));
    let quiet = args.bool("quiet");

    let report = match analysis::run(&root) {
        Ok(report) => report,
        Err(err) => {
            eprintln!("dynalint: error: {err:#}");
            return ExitCode::from(2);
        }
    };

    if let Some(json_path) = args.get("json") {
        if let Err(err) = write_json(Path::new(json_path), &report) {
            eprintln!("dynalint: error writing {json_path}: {err:#}");
            return ExitCode::from(2);
        }
    }

    if !quiet || !report.findings.is_empty() {
        print!("{}", report.render_text());
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn write_json(path: &Path, report: &analysis::report::Report) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, report.to_json().to_string())?;
    Ok(())
}
