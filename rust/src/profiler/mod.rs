//! Real-time profiling (Section IV-A).
//!
//! The worker timestamps every mini-procedure; this module turns the raw
//! samples into the scheduler's inputs:
//!
//! * `fc[l]` / `bc[l]` — EWMA of each layer's measured compute time;
//! * transmission model — segment transfer samples `(bytes, ms)` are fit
//!   with least squares, giving `Δt` (the intercept: per-mini-procedure
//!   setup + latency) and the achieved byte rate (the slope), from which
//!   `pt[l]` / `gt[l]` are reconstructed per layer;
//! * an on/off switch (Table II measures its overhead) and the once-per-
//!   epoch re-scheduling policy (Section IV-C).

use std::collections::VecDeque;

use crate::sched::CostVectors;
use crate::util::stats::linear_fit;

/// Exponentially-weighted moving average.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    value: Option<f64>,
    alpha: f64,
}

impl Ewma {
    pub fn new(alpha: f64) -> Ewma {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { value: None, alpha }
    }

    pub fn update(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Transfer-time samples for one direction (pull or push).
#[derive(Debug, Clone, Default)]
struct TransferSamples {
    /// (bytes, ms) per completed segment; bounded ring buffer — eviction
    /// is O(1) (`pop_front`), keeping `record` constant-time on the
    /// worker's hot path.
    samples: VecDeque<(f64, f64)>,
}

const MAX_SAMPLES: usize = 512;

impl TransferSamples {
    fn record(&mut self, bytes: usize, ms: f64) {
        if self.samples.len() >= MAX_SAMPLES {
            self.samples.pop_front();
        }
        self.samples.push_back((bytes as f64, ms));
    }

    /// (Δt ms, ms-per-byte). Falls back to attributing everything to rate
    /// when there is not enough size diversity to separate the intercept.
    fn fit(&self) -> Option<(f64, f64)> {
        if self.samples.len() < 2 {
            return None;
        }
        let xs: Vec<f64> = self.samples.iter().map(|s| s.0).collect();
        let ys: Vec<f64> = self.samples.iter().map(|s| s.1).collect();
        let spread = xs.iter().cloned().fold(f64::MIN, f64::max)
            - xs.iter().cloned().fold(f64::MAX, f64::min);
        if spread < 1.0 {
            // All samples the same size: rate unidentifiable; put the mean
            // entirely into Δt.
            let mean = ys.iter().sum::<f64>() / ys.len() as f64;
            return Some((mean, 0.0));
        }
        let (slope, intercept) = linear_fit(&xs, &ys);
        // Clamp to physical values; noise can push either negative.
        Some((intercept.max(0.0), slope.max(0.0)))
    }
}

/// The profiler: all cost-vector state for one worker.
#[derive(Debug, Clone)]
pub struct Profiler {
    /// Per-layer **wire** bytes — the sizes the transmission model
    /// converts into per-layer pt/gt. The worker passes the session
    /// codec's encoded sizes (`net::codec`), and records wire byte counts
    /// with each transfer sample, so the fitted rate and the reconstructed
    /// pt/gt are codec-aware: when compression shrinks transfers, the
    /// scheduler re-segments against the compressed costs.
    layer_bytes: Vec<usize>,
    pub enabled: bool,
    fc: Vec<Ewma>,
    bc: Vec<Ewma>,
    pull: TransferSamples,
    push: TransferSamples,
}

impl Profiler {
    pub fn new(layer_bytes: Vec<usize>) -> Profiler {
        let depth = layer_bytes.len();
        Profiler {
            layer_bytes,
            enabled: true,
            fc: vec![Ewma::new(0.3); depth],
            bc: vec![Ewma::new(0.3); depth],
            pull: TransferSamples::default(),
            push: TransferSamples::default(),
        }
    }

    pub fn depth(&self) -> usize {
        self.layer_bytes.len()
    }

    pub fn record_fwd(&mut self, layer: usize, ms: f64) {
        if self.enabled {
            self.fc[layer].update(ms);
        }
    }

    pub fn record_bwd(&mut self, layer: usize, ms: f64) {
        if self.enabled {
            self.bc[layer].update(ms);
        }
    }

    pub fn record_pull(&mut self, bytes: usize, ms: f64) {
        if self.enabled {
            self.pull.record(bytes, ms);
        }
    }

    pub fn record_push(&mut self, bytes: usize, ms: f64) {
        if self.enabled {
            self.push.record(bytes, ms);
        }
    }

    /// Do we have enough signal to schedule from measurements?
    pub fn ready(&self) -> bool {
        self.fc.iter().all(|e| e.get().is_some())
            && self.bc.iter().all(|e| e.get().is_some())
            && self.pull.fit().is_some()
            && self.push.fit().is_some()
    }

    /// Assemble the scheduler's cost vectors from the current estimates.
    /// `Δt` is the mean of the pull/push intercepts.
    pub fn cost_vectors(&self) -> Option<CostVectors> {
        if !self.ready() {
            return None;
        }
        let (dt_pull, rate_pull) = self.pull.fit()?;
        let (dt_push, rate_push) = self.push.fit()?;
        let pt = self
            .layer_bytes
            .iter()
            .map(|&b| b as f64 * rate_pull)
            .collect();
        let gt = self
            .layer_bytes
            .iter()
            .map(|&b| b as f64 * rate_push)
            .collect();
        Some(CostVectors {
            pt,
            fc: self.fc.iter().map(|e| e.get().unwrap()).collect(),
            bc: self.bc.iter().map(|e| e.get().unwrap()).collect(),
            gt,
            delta_t: 0.5 * (dt_pull + dt_push),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_smooths() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        e.update(10.0);
        assert_eq!(e.get(), Some(10.0));
        e.update(20.0);
        assert_eq!(e.get(), Some(15.0));
    }

    #[test]
    fn recovers_delta_t_and_rate_from_clean_samples() {
        // Link: Δt = 14 ms, 1e-4 ms/byte (10 MB/s).
        let mut p = Profiler::new(vec![1000, 2000, 4000]);
        for l in 0..3 {
            p.record_fwd(l, 5.0);
            p.record_bwd(l, 10.0);
        }
        for &bytes in &[1000usize, 2000, 4000, 8000] {
            let ms = 14.0 + bytes as f64 * 1e-4;
            p.record_pull(bytes, ms);
            p.record_push(bytes, ms);
        }
        let cv = p.cost_vectors().unwrap();
        assert!((cv.delta_t - 14.0).abs() < 1e-6, "{}", cv.delta_t);
        assert!((cv.pt[0] - 0.1).abs() < 1e-6, "{}", cv.pt[0]);
        assert!((cv.pt[2] - 0.4).abs() < 1e-6);
        assert_eq!(cv.fc, vec![5.0; 3]);
        assert_eq!(cv.bc, vec![10.0; 3]);
    }

    #[test]
    fn not_ready_without_samples() {
        let mut p = Profiler::new(vec![100, 100]);
        assert!(!p.ready());
        assert!(p.cost_vectors().is_none());
        p.record_fwd(0, 1.0);
        assert!(!p.ready());
    }

    #[test]
    fn uniform_sizes_fall_back_to_intercept() {
        let mut p = Profiler::new(vec![500]);
        p.record_fwd(0, 1.0);
        p.record_bwd(0, 1.0);
        for _ in 0..3 {
            p.record_pull(500, 8.0);
            p.record_push(500, 8.0);
        }
        let cv = p.cost_vectors().unwrap();
        assert!((cv.delta_t - 8.0).abs() < 1e-9);
        assert_eq!(cv.pt, vec![0.0]);
    }

    #[test]
    fn disabled_profiler_records_nothing() {
        let mut p = Profiler::new(vec![100]);
        p.enabled = false;
        p.record_fwd(0, 1.0);
        p.record_bwd(0, 1.0);
        p.record_pull(100, 1.0);
        p.record_push(100, 1.0);
        assert!(!p.ready());
    }

    #[test]
    fn sample_window_is_bounded_and_evicts_oldest() {
        let mut s = TransferSamples::default();
        // Old regime: constant 100 ms; then a new regime at 1 ms. Once the
        // window is saturated the old samples must age out.
        for _ in 0..MAX_SAMPLES {
            s.record(1000, 100.0);
        }
        assert_eq!(s.samples.len(), MAX_SAMPLES);
        for _ in 0..MAX_SAMPLES {
            s.record(1000, 1.0);
        }
        assert_eq!(s.samples.len(), MAX_SAMPLES);
        assert!(s.samples.iter().all(|&(_, ms)| ms == 1.0), "stale samples kept");
        // Uniform sizes ⇒ the fit attributes the (new) mean entirely to Δt.
        let (dt, rate) = s.fit().unwrap();
        assert!((dt - 1.0).abs() < 1e-9);
        assert_eq!(rate, 0.0);
    }

    #[test]
    fn noisy_fit_stays_physical() {
        let mut p = Profiler::new(vec![10, 10_000]);
        p.record_fwd(0, 1.0);
        p.record_fwd(1, 1.0);
        p.record_bwd(0, 1.0);
        p.record_bwd(1, 1.0);
        // Wildly noisy samples with a negative apparent slope.
        p.record_pull(10_000, 5.0);
        p.record_pull(20_000, 3.0);
        p.record_push(10_000, 5.0);
        p.record_push(20_000, 3.0);
        let cv = p.cost_vectors().unwrap();
        assert!(cv.validate().is_ok());
    }
}
