//! Shard checkpoint/restore: the crash-restart half of elastic fault
//! tolerance (`docs/FAULTS.md`).
//!
//! A [`Checkpoint`] is the complete durable state of one parameter-server
//! shard — every owned layer's parameter slab + version clock, plus the
//! sync policy's per-worker iteration clocks — serialized to a
//! length-prefixed, checksummed file. A restarted shard started with
//! `--restore <path>` resumes **byte-identically**: the restored slabs are
//! the exact bytes the old shard held, so surviving workers reconnect and
//! training continues instead of resetting.
//!
//! ## File format (little-endian throughout)
//!
//! ```text
//! magic           b"DYNACKPT"                      8 bytes
//! format version  u32                              (currently 1)
//! sync mode tag   u8                               (SyncMode::tag)
//! staleness bound u32
//! clock count     u32
//!   per clock     worker u32, clock u64
//! layer count     u32
//!   per layer     layer u32, version u64, len u32, slab bytes
//! checksum        u64 FNV-1a over every prior byte
//! ```
//!
//! ## Failure contract
//!
//! [`Checkpoint::decode`] parses the **whole file into memory before any
//! caller state is touched** — a corrupt checkpoint can never partially
//! apply. Truncation, a checksum mismatch, and an unsupported format
//! version each fail with a named error (tested per corruption class);
//! nothing in this module panics on untrusted bytes. Writes go through a
//! temp file + atomic rename so a crash mid-write leaves the previous
//! checkpoint intact.

use std::path::Path;

use anyhow::{Context, Result};

use super::sync::SyncMode;

/// The on-disk format revision. Bump when the layout changes; decode
/// refuses other versions by name rather than misparsing.
pub const CHECKPOINT_FORMAT: u32 = 1;

const MAGIC: &[u8; 8] = b"DYNACKPT";

/// One owned layer's durable state: the parameter slab exactly as the
/// shard stores it (raw fp32 bytes) and its applied-version clock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerRecord {
    pub layer: u32,
    pub version: u64,
    pub params: Vec<u8>,
}

/// A complete shard checkpoint — see the module docs for the format.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Checkpoint {
    pub sync_mode: SyncMode,
    pub staleness_bound: u32,
    /// The sync policy's per-worker iteration clocks (empty under BSP).
    pub clocks: Vec<(u32, u64)>,
    /// Owned layers in ascending layer order.
    pub layers: Vec<LayerRecord>,
}

/// FNV-1a over `bytes` — dependency-free integrity check. Detects the
/// single-byte and truncation corruptions a crashed write or bit-rot
/// produces; this is an integrity checksum, not an authenticity MAC.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl Checkpoint {
    /// Serialize to the checksummed byte format.
    pub fn encode(&self) -> Vec<u8> {
        let slab_bytes: usize = self.layers.iter().map(|l| l.params.len()).sum();
        let mut out = Vec::with_capacity(
            MAGIC.len() + 4 + 1 + 4 + 4 + self.clocks.len() * 12 + 4
                + self.layers.len() * 16
                + slab_bytes
                + 8,
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&CHECKPOINT_FORMAT.to_le_bytes());
        out.push(self.sync_mode.tag());
        out.extend_from_slice(&self.staleness_bound.to_le_bytes());
        out.extend_from_slice(&(self.clocks.len() as u32).to_le_bytes());
        for &(worker, clock) in &self.clocks {
            out.extend_from_slice(&worker.to_le_bytes());
            out.extend_from_slice(&clock.to_le_bytes());
        }
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            out.extend_from_slice(&l.layer.to_le_bytes());
            out.extend_from_slice(&l.version.to_le_bytes());
            out.extend_from_slice(&(l.params.len() as u32).to_le_bytes());
            out.extend_from_slice(&l.params);
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse a checkpoint. The whole buffer is validated (magic, format
    /// version, checksum, every record length) before a `Checkpoint` is
    /// returned, so a failed decode leaves the caller with nothing to
    /// half-apply.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        anyhow::ensure!(
            bytes.len() >= MAGIC.len() + 8,
            "checkpoint truncated: {} bytes is shorter than the fixed header",
            bytes.len()
        );
        anyhow::ensure!(
            &bytes[..MAGIC.len()] == MAGIC,
            "checkpoint magic mismatch: not a DynaComm checkpoint file"
        );
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        let computed = fnv1a(body);
        anyhow::ensure!(
            stored == computed,
            "checkpoint checksum mismatch: stored {stored:#018x}, \
             computed {computed:#018x} — the file is corrupt"
        );
        let mut r = Reader { buf: body, pos: MAGIC.len() };
        let format = r.u32()?;
        anyhow::ensure!(
            format == CHECKPOINT_FORMAT,
            "unsupported checkpoint format version {format} \
             (this build reads version {CHECKPOINT_FORMAT})"
        );
        let mode_tag = r.u8()?;
        let sync_mode = SyncMode::from_tag(mode_tag).with_context(|| {
            format!("checkpoint names unknown sync mode tag {mode_tag}")
        })?;
        let staleness_bound = r.u32()?;
        let clock_count = r.u32()? as usize;
        let mut clocks = Vec::with_capacity(clock_count.min(1 << 20));
        for _ in 0..clock_count {
            clocks.push((r.u32()?, r.u64()?));
        }
        let layer_count = r.u32()? as usize;
        let mut layers = Vec::with_capacity(layer_count.min(1 << 20));
        for _ in 0..layer_count {
            let layer = r.u32()?;
            let version = r.u64()?;
            let len = r.u32()? as usize;
            layers.push(LayerRecord { layer, version, params: r.take(len)?.to_vec() });
        }
        anyhow::ensure!(
            r.pos == body.len(),
            "checkpoint truncated: {} trailing bytes after the last layer record",
            body.len() - r.pos
        );
        Ok(Checkpoint { sync_mode, staleness_bound, clocks, layers })
    }

    /// Write atomically: encode, write to `<path>.tmp`, fsync, rename. A
    /// crash mid-write leaves any previous checkpoint at `path` intact.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        let bytes = self.encode();
        let tmp = path.with_extension("tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&bytes)
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display()))
    }

    /// Read and fully validate a checkpoint file.
    pub fn read_from(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::decode(&bytes)
            .with_context(|| format!("restoring checkpoint {}", path.display()))
    }
}

/// Bounds-checked little-endian cursor (mirrors the transport decoder's
/// shape): every read is validated, so corrupt counts fail instead of
/// panicking.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.buf.len() - self.pos >= n,
            "checkpoint truncated: wanted {n} bytes at offset {}, {} remain",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            sync_mode: SyncMode::Ssp,
            staleness_bound: 3,
            clocks: vec![(0, 7), (2, 9)],
            layers: vec![
                LayerRecord { layer: 0, version: 8, params: vec![1, 2, 3, 4] },
                LayerRecord { layer: 2, version: 7, params: vec![9; 4096] },
                LayerRecord { layer: 5, version: 8, params: Vec::new() },
            ],
        }
    }

    #[test]
    fn roundtrips_byte_identically() {
        let ck = sample();
        let enc = ck.encode();
        let back = Checkpoint::decode(&enc).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.encode(), enc, "re-encode is byte-identical");
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let ck = Checkpoint {
            sync_mode: SyncMode::Bsp,
            staleness_bound: 0,
            clocks: Vec::new(),
            layers: Vec::new(),
        };
        assert_eq!(Checkpoint::decode(&ck.encode()).unwrap(), ck);
    }

    #[test]
    fn every_truncation_is_a_named_truncation_or_checksum_error() {
        let enc = sample().encode();
        for cut in 0..enc.len() {
            let err = Checkpoint::decode(&enc[..cut])
                .expect_err("strict prefix must not decode");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("checksum mismatch"),
                "cut at {cut}: unnamed error {msg}"
            );
        }
    }

    #[test]
    fn every_flipped_byte_is_a_named_error() {
        let enc = sample().encode();
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0x40;
            let err =
                Checkpoint::decode(&bad).expect_err("corrupt byte must not decode");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("checksum mismatch") || msg.contains("magic mismatch"),
                "flip at {i}: unnamed error {msg}"
            );
        }
    }

    #[test]
    fn wrong_format_version_is_a_named_error() {
        let ck = sample();
        let mut enc = ck.encode();
        // Forge version 99 at offset 8, then re-stamp the checksum so the
        // version check (not the checksum) is what trips.
        enc[8..12].copy_from_slice(&99u32.to_le_bytes());
        let body_len = enc.len() - 8;
        let sum = fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&sum.to_le_bytes());
        let msg = format!("{:#}", Checkpoint::decode(&enc).unwrap_err());
        assert!(msg.contains("unsupported checkpoint format version 99"), "{msg}");
    }

    #[test]
    fn forged_record_counts_fail_without_panicking() {
        let ck = sample();
        let mut enc = ck.encode();
        // Clock count lives at offset 8 + 4 + 1 + 4 = 17. Forge it huge
        // and re-stamp the checksum: the cursor must run out cleanly.
        enc[17..21].copy_from_slice(&u32::MAX.to_le_bytes());
        let body_len = enc.len() - 8;
        let sum = fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&sum.to_le_bytes());
        let msg = format!("{:#}", Checkpoint::decode(&enc).unwrap_err());
        assert!(msg.contains("truncated"), "{msg}");
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut enc = sample().encode();
        let tail = [0u8; 12];
        enc.extend_from_slice(&tail);
        assert!(Checkpoint::decode(&enc).is_err());
    }

    #[test]
    fn write_read_file_roundtrip_and_atomic_tmp_cleanup() {
        let dir = std::env::temp_dir().join(format!(
            "dynacomm-ckpt-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard0.ckpt");
        let ck = sample();
        ck.write_to(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "tmp file renamed away");
        assert_eq!(Checkpoint::read_from(&path).unwrap(), ck);
        // Overwrite in place with different content.
        let mut ck2 = ck.clone();
        ck2.layers[0].params = vec![7, 7, 7, 7];
        ck2.write_to(&path).unwrap();
        assert_eq!(Checkpoint::read_from(&path).unwrap(), ck2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checksum_catches_a_flipped_bit_in_a_big_slab() {
        let ck = Checkpoint {
            sync_mode: SyncMode::Asp,
            staleness_bound: 0,
            clocks: vec![(1, 1)],
            layers: vec![LayerRecord {
                layer: 0,
                version: 1,
                params: (0..100_000u32).map(|i| (i % 251) as u8).collect(),
            }],
        };
        let enc = ck.encode();
        let mut bad = enc.clone();
        let mid = enc.len() / 2;
        bad[mid] ^= 1;
        let msg = format!("{:#}", Checkpoint::decode(&bad).unwrap_err());
        assert!(msg.contains("checksum mismatch"), "{msg}");
    }
}
