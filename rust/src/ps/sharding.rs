//! Layer → parameter-server shard mapping.
//!
//! Layers are striped round-robin across shards (the paper's testbed runs
//! 4 PS instances). A transmission segment `[lo, hi]` therefore fans out
//! into at most `min(servers, hi-lo+1)` per-server sub-requests; under
//! round-robin striping each server's share of a contiguous range is an
//! arithmetic progression, which [`ShardMap::sub_requests`] exploits to
//! describe the fan-out without allocating per-layer vectors on the
//! worker's hot path.

/// Round-robin striping of `depth` layers over `servers` shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMap {
    pub servers: usize,
    pub depth: usize,
}

/// One server's share of an inclusive layer range: the layers
/// `start, start + step, …` (`count` of them), all owned by `server`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubRange {
    pub server: usize,
    pub start: usize,
    pub step: usize,
    pub count: usize,
}

impl SubRange {
    /// The layers of this sub-request, ascending.
    pub fn layers(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.count).map(move |k| self.start + k * self.step)
    }
}

impl ShardMap {
    pub fn new(servers: usize, depth: usize) -> ShardMap {
        assert!(servers > 0 && depth > 0);
        ShardMap { servers, depth }
    }

    /// Which server owns 0-based layer `l`.
    pub fn owner(&self, l: usize) -> usize {
        debug_assert!(l < self.depth);
        l % self.servers
    }

    /// The 0-based layers owned by `server`, ascending.
    pub fn owned_by(&self, server: usize) -> Vec<usize> {
        (0..self.depth).filter(|l| self.owner(*l) == server).collect()
    }

    /// The per-server sub-requests of an inclusive 0-based layer range,
    /// ordered by first layer (the order sub-requests are issued in).
    /// Allocation-free: each share is an arithmetic progression.
    pub fn sub_requests(self, lo: usize, hi: usize) -> impl Iterator<Item = SubRange> {
        debug_assert!(lo <= hi && hi < self.depth);
        let fan_out = (hi - lo + 1).min(self.servers);
        (lo..lo + fan_out).map(move |start| SubRange {
            server: self.owner(start),
            start,
            step: self.servers,
            count: (hi - start) / self.servers + 1,
        })
    }

    /// Split an inclusive 0-based layer range into per-server layer lists,
    /// ordered by first layer. Allocating variant of
    /// [`ShardMap::sub_requests`], kept for callers that want materialized
    /// lists.
    pub fn split_range(&self, lo: usize, hi: usize) -> Vec<(usize, Vec<usize>)> {
        self.sub_requests(lo, hi)
            .map(|sub| (sub.server, sub.layers().collect()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin() {
        let m = ShardMap::new(4, 10);
        assert_eq!(m.owner(0), 0);
        assert_eq!(m.owner(5), 1);
        assert_eq!(m.owned_by(2), vec![2, 6]);
    }

    #[test]
    fn split_covers_range_exactly() {
        let m = ShardMap::new(3, 12);
        let parts = m.split_range(2, 9);
        let mut all: Vec<usize> = parts.iter().flat_map(|(_, v)| v.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (2..=9).collect::<Vec<_>>());
        for (s, layers) in &parts {
            for l in layers {
                assert_eq!(m.owner(*l), *s);
            }
        }
    }

    #[test]
    fn split_is_ordered_by_first_layer() {
        let m = ShardMap::new(4, 16);
        let parts = m.split_range(3, 11);
        let firsts: Vec<usize> = parts.iter().map(|(_, v)| v[0]).collect();
        let mut sorted = firsts.clone();
        sorted.sort_unstable();
        assert_eq!(firsts, sorted);
    }

    /// First-principles oracle for the arithmetic-progression fan-out
    /// (`split_range` is built on `sub_requests`, so it cannot serve as the
    /// oracle itself): every sub-request's layers must belong to its
    /// server per `owner()`, the union must cover `[lo, hi]` exactly once,
    /// and sub-requests must be ordered by first layer.
    #[test]
    fn sub_requests_cover_ranges_exactly() {
        for servers in 1..=6 {
            for depth in 1..=13 {
                let m = ShardMap::new(servers, depth);
                for lo in 0..depth {
                    for hi in lo..depth {
                        let ctx = format!("servers={servers} depth={depth} [{lo},{hi}]");
                        let mut covered = Vec::new();
                        let mut prev_first = None;
                        for sub in m.sub_requests(lo, hi) {
                            let layers: Vec<usize> = sub.layers().collect();
                            assert!(!layers.is_empty(), "{ctx}: empty sub-request");
                            assert!(
                                prev_first < Some(layers[0]),
                                "{ctx}: sub-requests out of order"
                            );
                            prev_first = Some(layers[0]);
                            for &l in &layers {
                                assert_eq!(m.owner(l), sub.server, "{ctx}: layer {l}");
                            }
                            covered.extend(layers);
                        }
                        covered.sort_unstable();
                        assert_eq!(
                            covered,
                            (lo..=hi).collect::<Vec<_>>(),
                            "{ctx}: coverage"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn single_server_owns_everything() {
        let m = ShardMap::new(1, 6);
        assert_eq!(m.owned_by(0).len(), 6);
        assert_eq!(m.split_range(0, 5), vec![(0, (0..6).collect())]);
        let subs: Vec<SubRange> = m.sub_requests(0, 5).collect();
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].count, 6);
        assert_eq!(subs[0].step, 1);
    }

    #[test]
    fn more_servers_than_layers() {
        let m = ShardMap::new(8, 3);
        assert!(m.owned_by(5).is_empty());
        assert_eq!(m.split_range(0, 2).len(), 3);
        assert_eq!(m.sub_requests(0, 2).count(), 3);
    }
}
