//! Layer → parameter-server shard mapping.
//!
//! Layers are striped round-robin across shards (the paper's testbed runs
//! 4 PS instances). A transmission segment `[lo, hi]` therefore fans out
//! into at most `min(servers, hi-lo+1)` per-server sub-requests.

/// Round-robin striping of `depth` layers over `servers` shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMap {
    pub servers: usize,
    pub depth: usize,
}

impl ShardMap {
    pub fn new(servers: usize, depth: usize) -> ShardMap {
        assert!(servers > 0 && depth > 0);
        ShardMap { servers, depth }
    }

    /// Which server owns 0-based layer `l`.
    pub fn owner(&self, l: usize) -> usize {
        debug_assert!(l < self.depth);
        l % self.servers
    }

    /// The 0-based layers owned by `server`, ascending.
    pub fn owned_by(&self, server: usize) -> Vec<usize> {
        (0..self.depth).filter(|l| self.owner(*l) == server).collect()
    }

    /// Split an inclusive 0-based layer range into per-server layer lists,
    /// ordered by first layer (the order sub-requests are issued in).
    pub fn split_range(&self, lo: usize, hi: usize) -> Vec<(usize, Vec<usize>)> {
        debug_assert!(lo <= hi && hi < self.depth);
        let mut per: Vec<Vec<usize>> = vec![Vec::new(); self.servers];
        for l in lo..=hi {
            per[self.owner(l)].push(l);
        }
        let mut out: Vec<(usize, Vec<usize>)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .collect();
        out.sort_by_key(|(_, v)| v[0]);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin() {
        let m = ShardMap::new(4, 10);
        assert_eq!(m.owner(0), 0);
        assert_eq!(m.owner(5), 1);
        assert_eq!(m.owned_by(2), vec![2, 6]);
    }

    #[test]
    fn split_covers_range_exactly() {
        let m = ShardMap::new(3, 12);
        let parts = m.split_range(2, 9);
        let mut all: Vec<usize> = parts.iter().flat_map(|(_, v)| v.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (2..=9).collect::<Vec<_>>());
        for (s, layers) in &parts {
            for l in layers {
                assert_eq!(m.owner(*l), *s);
            }
        }
    }

    #[test]
    fn single_server_owns_everything() {
        let m = ShardMap::new(1, 6);
        assert_eq!(m.owned_by(0).len(), 6);
        assert_eq!(m.split_range(0, 5), vec![(0, (0..6).collect())]);
    }

    #[test]
    fn more_servers_than_layers() {
        let m = ShardMap::new(8, 3);
        assert!(m.owned_by(5).is_empty());
        assert_eq!(m.split_range(0, 2).len(), 3);
    }
}
