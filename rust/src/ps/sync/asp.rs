//! Asynchronous parallel: no gating anywhere.
//!
//! Every push is applied to the parameters the moment it arrives (scaled
//! `lr / workers`, so one full round of pushes moves the parameters by the
//! same total step as a BSP average), and every pull is served the
//! freshest applied snapshot immediately. Per-worker iteration tags are
//! still tracked — the `applied` iteration a `PullReply` carries lets the
//! worker (and the straggler bench) measure the staleness it actually
//! trained on, and [`SyncPolicy::slowest`] reports the laggard's clock —
//! but nothing ever blocks on them.

use std::sync::atomic::AtomicBool;
use std::sync::Mutex;

use super::{ClockTable, PullGate, PushApply, SyncMode, SyncPolicy};
use crate::util::sync::lock_or_die;

pub struct AspPolicy {
    /// Observability only: per-worker iteration tags.
    clocks: Mutex<ClockTable>,
}

impl AspPolicy {
    pub fn new() -> AspPolicy {
        AspPolicy { clocks: Mutex::new(ClockTable::default()) }
    }
}

impl Default for AspPolicy {
    fn default() -> Self {
        AspPolicy::new()
    }
}

impl SyncPolicy for AspPolicy {
    fn mode(&self) -> SyncMode {
        SyncMode::Asp
    }

    fn register_worker(&self, worker: u32) {
        lock_or_die(&self.clocks, "sync.clocks").register(worker);
    }

    fn deregister_worker(&self, worker: u32) {
        lock_or_die(&self.clocks, "sync.clocks").deregister(worker);
    }

    fn admit_pull(
        &self,
        worker: Option<u32>,
        iter: u64,
        _shutdown: &AtomicBool,
    ) -> Option<PullGate> {
        if let Some(w) = worker {
            lock_or_die(&self.clocks, "sync.clocks").record(w, iter);
        }
        Some(PullGate::Fresh)
    }

    fn on_push(&self, worker: Option<u32>, iter: u64) -> PushApply {
        if let Some(w) = worker {
            // A push for `iter` means the worker finished computing it —
            // keep the tag moving even if its next pull is far away.
            lock_or_die(&self.clocks, "sync.clocks").record(w, iter);
        }
        PushApply::Immediate
    }

    fn slowest(&self) -> u64 {
        lock_or_die(&self.clocks, "sync.clocks").slowest().unwrap_or(0)
    }

    fn export_clocks(&self) -> Vec<(u32, u64)> {
        lock_or_die(&self.clocks, "sync.clocks").export()
    }

    fn import_clocks(&self, clocks: &[(u32, u64)]) {
        lock_or_die(&self.clocks, "sync.clocks").import(clocks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asp_never_blocks_and_tags_iterations() {
        let p = AspPolicy::new();
        let shutdown = AtomicBool::new(false);
        p.register_worker(0);
        p.register_worker(1);
        assert_eq!(p.admit_pull(Some(0), 40, &shutdown), Some(PullGate::Fresh));
        assert_eq!(p.admit_pull(None, 99, &shutdown), Some(PullGate::Fresh));
        assert_eq!(p.on_push(Some(1), 3), PushApply::Immediate);
        assert_eq!(p.slowest(), 3, "laggard's clock reported");
        assert_eq!(p.waiters(), 0);
        assert_eq!(p.name(), "asp");
    }
}
