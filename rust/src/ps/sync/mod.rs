//! Pluggable parameter-server synchronization (ACE-Sync-style adaptive
//! cloud-edge synchronization — arXiv 2512.18127).
//!
//! DynaComm's overlap scheduling was built on a BSP parameter server: every
//! pull parks at a barrier until the slowest worker's gradients of the
//! previous iteration are applied. On a heterogeneous edge fleet one
//! 4×-slowed device therefore stalls *every* worker, and no amount of
//! transmission re-segmentation can win that time back. This module makes
//! the consistency model an explicit, pluggable subsystem — a
//! [`SyncPolicy`] decides, per pull, whether a worker may proceed, must
//! wait, or is served the freshest applied snapshot, and, per push, when
//! gradients are applied — with three implementations behind a registry
//! mirroring `sched::registry`:
//!
//! * [`bsp::BspPolicy`] — the extracted barrier semantics, behavior-
//!   identical to the pre-subsystem server (conformance-tested unchanged);
//! * [`ssp::SspPolicy`] — stale-synchronous parallel with a bounded
//!   staleness window (`--staleness-bound N`): a worker within `N`
//!   iterations of the slowest proceeds immediately against the freshest
//!   applied snapshot, one beyond it parks until the slowest catches up;
//!   the slowest worker trivially satisfies its own bound, so it is never
//!   starved;
//! * [`asp::AspPolicy`] — fully asynchronous: every push is applied
//!   immediately (scaled `lr / workers`), every pull is served fresh, and
//!   per-worker iteration tags are tracked for observability only.
//!
//! The policy's choices surface on the wire (protocol v4, `docs/SYNC.md` /
//! `docs/WIRE.md`): `PullReply` carries the `applied` iteration of the
//! snapshot it serves, so the worker measures the staleness it actually
//! observed — and its profiler's transfer samples embed the *actual* wait
//! window of the active policy, not an assumed full barrier — and a
//! `SyncPropose`/`SyncAgree` registration exchange fails mismatched
//! worker/server sync configurations loudly instead of training under two
//! different consistency models.

pub mod asp;
pub mod bsp;
pub mod ssp;

use std::sync::atomic::AtomicBool;

use anyhow::Result;

/// Synchronization model selector; also the 1-byte wire tag carried by the
/// `SyncPropose`/`SyncAgree` registration frames (`docs/WIRE.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SyncMode {
    /// Bulk-synchronous parallel: full barrier per iteration (the paper's
    /// evaluation mode, and the default).
    Bsp,
    /// Stale-synchronous parallel: bounded staleness window.
    Ssp,
    /// Asynchronous parallel: apply-on-push, serve-fresh, no gating.
    Asp,
}

impl SyncMode {
    /// All modes, BSP (the default) first.
    pub const ALL: [SyncMode; 3] = [SyncMode::Bsp, SyncMode::Ssp, SyncMode::Asp];

    /// The 1-byte wire tag.
    pub fn tag(self) -> u8 {
        match self {
            SyncMode::Bsp => 0,
            SyncMode::Ssp => 1,
            SyncMode::Asp => 2,
        }
    }

    /// Parse a wire tag.
    pub fn from_tag(tag: u8) -> Option<SyncMode> {
        match tag {
            0 => Some(SyncMode::Bsp),
            1 => Some(SyncMode::Ssp),
            2 => Some(SyncMode::Asp),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SyncMode::Bsp => "bsp",
            SyncMode::Ssp => "ssp",
            SyncMode::Asp => "asp",
        }
    }

    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<SyncMode> {
        match s.to_ascii_lowercase().as_str() {
            "bsp" | "sync" | "barrier" => Some(SyncMode::Bsp),
            "ssp" | "stale" | "bounded" => Some(SyncMode::Ssp),
            "asp" | "async" => Some(SyncMode::Asp),
            _ => None,
        }
    }
}

/// Canonical names of every registry entry, in creation-tested order
/// (mirrors `sched::registry::NAMES`).
pub const NAMES: [&str; 3] = ["bsp", "ssp", "asp"];

/// A validated (mode, staleness bound) pair — the server shard's sync
/// configuration and the worker's expectation of it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SyncConfig {
    pub mode: SyncMode,
    /// SSP: iterations a worker may run ahead of the slowest registered
    /// worker. Must be 0 for BSP/ASP ([`SyncConfig::validate`], also
    /// enforced on the wire).
    pub staleness_bound: u32,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig { mode: SyncMode::Bsp, staleness_bound: 0 }
    }
}

impl SyncConfig {
    pub fn new(mode: SyncMode, staleness_bound: u32) -> Result<SyncConfig> {
        let cfg = SyncConfig { mode, staleness_bound };
        cfg.validate()?;
        Ok(cfg)
    }

    /// A staleness bound only means something under SSP; refusing it
    /// elsewhere keeps `--sync asp --staleness-bound 3` from silently
    /// training unbounded while the operator believes otherwise.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.staleness_bound == 0 || self.mode == SyncMode::Ssp,
            "staleness bound {} is invalid for sync mode {} (only ssp is bounded)",
            self.staleness_bound,
            self.mode.name()
        );
        Ok(())
    }
}

/// How a pull must gate on the per-layer applied versions once admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PullGate {
    /// Park on the version condvars until every requested layer has
    /// `version >= min` — the BSP barrier.
    WaitFor { min: u64 },
    /// Serve the freshest applied snapshot immediately (SSP once inside
    /// the staleness window, ASP always).
    Fresh,
}

/// When a pushed gradient is applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushApply {
    /// Accumulate; apply averaged SGD once every registered worker has
    /// contributed, then advance the clock (BSP).
    Barrier,
    /// Apply this gradient now, scaled `lr / workers` (SSP/ASP).
    Immediate,
}

/// One shard's synchronization policy. The server consults it on every
/// pull and push; the policy owns whatever clock state its model needs
/// (per-worker iteration tags, the staleness gate) and may block inside
/// [`SyncPolicy::admit_pull`] — which is why shutdown must call
/// [`SyncPolicy::interrupt`].
pub trait SyncPolicy: Send + Sync {
    fn mode(&self) -> SyncMode;

    fn name(&self) -> &'static str {
        self.mode().name()
    }

    /// SSP's window; 0 elsewhere.
    fn staleness_bound(&self) -> u32 {
        0
    }

    /// A worker (identified when the session said `Hello`) registered.
    /// SSP starts its clock at 0 so late boots gate eager peers.
    fn register_worker(&self, _worker: u32) {}

    /// The worker's session closed; its clock must stop gating others.
    fn deregister_worker(&self, _worker: u32) {}

    /// Admit a pull for iteration `iter`, advancing the worker's clock.
    /// May block (SSP parks past-the-window pulls); returns `None` when
    /// `shutdown` interrupted the wait.
    fn admit_pull(
        &self,
        worker: Option<u32>,
        iter: u64,
        shutdown: &AtomicBool,
    ) -> Option<PullGate>;

    /// Decide what happens to a push for iteration `iter`.
    fn on_push(&self, worker: Option<u32>, iter: u64) -> PushApply;

    /// The slowest registered worker's iteration clock (0 when none).
    fn slowest(&self) -> u64;

    /// Pulls currently parked inside [`SyncPolicy::admit_pull`]
    /// (observability: condition-based tests instead of sleeps).
    fn waiters(&self) -> u32 {
        0
    }

    /// Wake every parked [`SyncPolicy::admit_pull`] so it can observe the
    /// shutdown flag — called by `ParamServer::shutdown`.
    fn interrupt(&self) {}

    /// Snapshot the per-worker iteration clocks for checkpointing
    /// (`ps/checkpoint.rs`), sorted by worker id. Policies without clock
    /// state (BSP gates on layer versions alone) export nothing.
    fn export_clocks(&self) -> Vec<(u32, u64)> {
        Vec::new()
    }

    /// Restore clocks exported by [`SyncPolicy::export_clocks`] — called
    /// once at restore time, before any session registers.
    fn import_clocks(&self, _clocks: &[(u32, u64)]) {}
}

/// Instantiate the policy behind a validated [`SyncConfig`] — the single
/// place policies are constructed, mirroring `sched::registry`.
pub fn create(cfg: SyncConfig) -> Box<dyn SyncPolicy> {
    match cfg.mode {
        SyncMode::Bsp => Box::new(bsp::BspPolicy),
        SyncMode::Ssp => Box::new(ssp::SspPolicy::new(cfg.staleness_bound)),
        SyncMode::Asp => Box::new(asp::AspPolicy::new()),
    }
}

/// Instantiate by name (accepts every [`SyncMode::parse`] spelling);
/// unknown names list what is available.
pub fn create_by_name(name: &str, staleness_bound: u32) -> Result<Box<dyn SyncPolicy>> {
    let mode = SyncMode::parse(name).ok_or_else(|| {
        anyhow::anyhow!("unknown sync mode '{name}' (known: {})", NAMES.join(", "))
    })?;
    Ok(create(SyncConfig::new(mode, staleness_bound)?))
}

/// Per-worker iteration clocks shared by the SSP gate and ASP's
/// observability: `record` advances a worker's clock to the iteration it
/// is pulling for, `slowest` is the min over registered workers.
#[derive(Debug, Default)]
pub(crate) struct ClockTable {
    clocks: std::collections::HashMap<u32, u64>,
}

impl ClockTable {
    /// Advance `worker`'s clock to at least `iter`; true if it moved.
    pub fn record(&mut self, worker: u32, iter: u64) -> bool {
        let c = self.clocks.entry(worker).or_insert(0);
        if iter > *c {
            *c = iter;
            true
        } else {
            false
        }
    }

    pub fn register(&mut self, worker: u32) {
        self.clocks.entry(worker).or_insert(0);
    }

    /// True if the worker was present (its removal can unblock waiters).
    pub fn deregister(&mut self, worker: u32) -> bool {
        self.clocks.remove(&worker).is_some()
    }

    /// Min clock over registered workers; `None` when none registered.
    pub fn slowest(&self) -> Option<u64> {
        self.clocks.values().copied().min()
    }

    /// Sorted `(worker, clock)` pairs — the checkpointable view.
    pub fn export(&self) -> Vec<(u32, u64)> {
        let mut v: Vec<(u32, u64)> =
            self.clocks.iter().map(|(&w, &c)| (w, c)).collect();
        v.sort_unstable();
        v
    }

    /// Restore exported pairs (clocks only ever advance, so a restored
    /// clock behind a live one is left alone).
    pub fn import(&mut self, pairs: &[(u32, u64)]) {
        for &(w, c) in pairs {
            self.clocks.entry(w).or_insert(0);
            self.record(w, c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_tags_names_roundtrip() {
        for m in SyncMode::ALL {
            assert_eq!(SyncMode::from_tag(m.tag()), Some(m));
            assert_eq!(SyncMode::parse(m.name()), Some(m));
        }
        assert_eq!(SyncMode::from_tag(3), None);
        assert_eq!(SyncMode::parse("gossip"), None);
        // Alias spellings.
        assert_eq!(SyncMode::parse("ASYNC"), Some(SyncMode::Asp));
        assert_eq!(SyncMode::parse("stale"), Some(SyncMode::Ssp));
        assert_eq!(SyncMode::parse("barrier"), Some(SyncMode::Bsp));
    }

    #[test]
    fn every_name_creates_and_reports_itself() {
        for name in NAMES {
            let bound = if name == "ssp" { 2 } else { 0 };
            let p = create_by_name(name, bound).unwrap();
            assert_eq!(p.name(), name, "canonical name round-trip");
            assert_eq!(p.staleness_bound(), bound);
        }
        let err = format!("{:#}", create_by_name("nope", 0).unwrap_err());
        assert!(err.contains("ssp"), "error lists known names: {err}");
    }

    #[test]
    fn bound_is_rejected_outside_ssp() {
        assert!(SyncConfig::new(SyncMode::Ssp, 5).is_ok());
        assert!(SyncConfig::new(SyncMode::Bsp, 0).is_ok());
        assert!(SyncConfig::new(SyncMode::Bsp, 1).is_err());
        assert!(SyncConfig::new(SyncMode::Asp, 1).is_err());
        assert!(create_by_name("asp", 3).is_err());
    }

    #[test]
    fn clock_table_tracks_minimum() {
        let mut t = ClockTable::default();
        assert_eq!(t.slowest(), None);
        t.register(3);
        assert_eq!(t.slowest(), Some(0));
        assert!(t.record(3, 5));
        assert!(!t.record(3, 4), "clocks never move backwards");
        t.register(7);
        assert_eq!(t.slowest(), Some(0), "late registrant gates at 0");
        t.record(7, 9);
        assert_eq!(t.slowest(), Some(5));
        assert!(t.deregister(3));
        assert_eq!(t.slowest(), Some(9));
        assert!(!t.deregister(3));
    }

    #[test]
    fn clock_table_export_import_roundtrips() {
        let mut t = ClockTable::default();
        t.register(4);
        t.record(4, 6);
        t.register(1);
        t.record(1, 2);
        let exported = t.export();
        assert_eq!(exported, vec![(1, 2), (4, 6)], "sorted by worker id");
        let mut back = ClockTable::default();
        back.import(&exported);
        assert_eq!(back.export(), exported);
        // Import never rewinds a live clock.
        back.record(1, 9);
        back.import(&exported);
        assert_eq!(back.export(), vec![(1, 9), (4, 6)]);
    }

    #[test]
    fn policies_export_and_import_their_clocks() {
        for name in NAMES {
            let bound = if name == "ssp" { 2 } else { 0 };
            let p = create_by_name(name, bound).unwrap();
            p.register_worker(0);
            let shutdown = AtomicBool::new(false);
            p.admit_pull(Some(0), 5, &shutdown);
            let exported = p.export_clocks();
            if name == "bsp" {
                assert!(exported.is_empty(), "bsp carries no clock state");
                continue;
            }
            assert_eq!(exported, vec![(0, 5)], "{name}");
            let fresh = create_by_name(name, bound).unwrap();
            fresh.import_clocks(&exported);
            assert_eq!(fresh.export_clocks(), exported, "{name}");
            assert_eq!(fresh.slowest(), 5, "{name}");
        }
    }
}
