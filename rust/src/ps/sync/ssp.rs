//! Stale-synchronous parallel: a bounded staleness window over per-worker
//! iteration clocks.
//!
//! Every registered worker carries a clock — the iteration of its latest
//! pull. A pull for iteration `t` is admitted as soon as
//! `t <= slowest + bound` (slowest = min clock over registered workers)
//! and is then served the **freshest applied snapshot** without touching
//! the per-layer version condvars; a pull past the window parks here, in
//! the policy, until the slowest worker's clock catches up (or its session
//! closes). The slowest worker always satisfies `t == slowest`, so it is
//! admitted unconditionally — **never starved** — and, because pushes are
//! applied immediately, its gradients land without waiting for anyone.
//!
//! The consistency guarantee (property-tested in
//! `tests/sync_integration.rs`): an admitted pull observes a snapshot
//! whose applied iteration is at least `slowest`, hence never older than
//! `t - bound` — no worker ever trains on parameters more than `bound`
//! iterations behind its own clock.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};

use super::{ClockTable, PullGate, PushApply, SyncMode, SyncPolicy};
use crate::obs::Gauge;
use crate::util::sync::{lock_or_die, wait_or_die};

pub struct SspPolicy {
    bound: u32,
    clocks: Mutex<ClockTable>,
    /// Signals clock advances (and interrupts) to parked pulls.
    advanced: Condvar,
    /// Pulls currently parked past the window — an obs-registry gauge
    /// (`waiters()` is a thin adapter over it; docs/OBSERVABILITY.md).
    waiters: Gauge,
    /// Mirror of `ClockTable::slowest`, refreshed under `sync.clocks` at
    /// every clock mutation so scrapes never take the clock lock.
    slowest_iter: Gauge,
}

impl SspPolicy {
    pub fn new(bound: u32) -> SspPolicy {
        let inst = crate::obs::next_inst();
        SspPolicy {
            bound,
            clocks: Mutex::new(ClockTable::default()),
            advanced: Condvar::new(),
            waiters: crate::obs_gauge!("dynacomm_sync_waiters", "", inst),
            slowest_iter: crate::obs_gauge!("dynacomm_sync_slowest_iter", "", inst),
        }
    }
}

impl SyncPolicy for SspPolicy {
    fn mode(&self) -> SyncMode {
        SyncMode::Ssp
    }

    fn staleness_bound(&self) -> u32 {
        self.bound
    }

    fn register_worker(&self, worker: u32) {
        let mut clocks = lock_or_die(&self.clocks, "sync.clocks");
        clocks.register(worker);
        self.slowest_iter.set(clocks.slowest().unwrap_or(0) as f64);
    }

    fn deregister_worker(&self, worker: u32) {
        let mut clocks = lock_or_die(&self.clocks, "sync.clocks");
        let released = clocks.deregister(worker);
        self.slowest_iter.set(clocks.slowest().unwrap_or(0) as f64);
        drop(clocks);
        if released {
            // A departed straggler must not gate the survivors forever.
            self.advanced.notify_all();
        }
    }

    fn admit_pull(
        &self,
        worker: Option<u32>,
        iter: u64,
        shutdown: &AtomicBool,
    ) -> Option<PullGate> {
        let mut clocks = lock_or_die(&self.clocks, "sync.clocks");
        if let Some(w) = worker {
            // The pull itself is this worker's progress signal; its
            // advance may be exactly what a parked peer is waiting on.
            if clocks.record(w, iter) {
                self.advanced.notify_all();
            }
            self.slowest_iter.set(clocks.slowest().unwrap_or(0) as f64);
        }
        // Anonymous sessions (no Hello) carry no clock and gate nothing;
        // serve them fresh — they cannot participate in the window.
        if worker.is_some() {
            // `slowest` includes this worker's just-recorded clock, which
            // is `>= iter`, so the slowest worker admits itself trivially.
            while clocks.slowest().is_some_and(|s| iter > s + self.bound as u64) {
                if shutdown.load(Ordering::SeqCst) {
                    return None;
                }
                self.waiters.add(1.0);
                let woken = wait_or_die(&self.advanced, clocks, "sync.clocks");
                self.waiters.add(-1.0);
                clocks = woken;
            }
        }
        Some(PullGate::Fresh)
    }

    fn on_push(&self, _worker: Option<u32>, _iter: u64) -> PushApply {
        PushApply::Immediate
    }

    // Served from the gauge mirror, lock-free: *not* linearizable with
    // pull gating, which re-derives the minimum under `sync.clocks`, so a
    // reader racing a clock mutation can see a momentarily stale value —
    // and the u64→f64 storage rounds above 2^53 iterations. Fine for
    // scrapes and reports; control decisions must read the table under
    // the lock (as `admit_pull` does).
    fn slowest(&self) -> u64 {
        self.slowest_iter.get() as u64
    }

    fn waiters(&self) -> u32 {
        self.waiters.get() as u32
    }

    fn interrupt(&self) {
        // Hold the lock so a racing waiter cannot re-park between its
        // shutdown check and the wait.
        let _clocks = lock_or_die(&self.clocks, "sync.clocks");
        self.advanced.notify_all();
    }

    fn export_clocks(&self) -> Vec<(u32, u64)> {
        lock_or_die(&self.clocks, "sync.clocks").export()
    }

    fn import_clocks(&self, clocks: &[(u32, u64)]) {
        let mut table = lock_or_die(&self.clocks, "sync.clocks");
        table.import(clocks);
        self.slowest_iter.set(table.slowest().unwrap_or(0) as f64);
        drop(table);
        // Restored clocks can only widen the window — wake any waiter.
        self.advanced.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn within_window_pulls_are_admitted_fresh() {
        let p = SspPolicy::new(2);
        let shutdown = AtomicBool::new(false);
        p.register_worker(0);
        p.register_worker(1);
        // Worker 1 at clock 0; worker 0 may pull up to iteration 2.
        for iter in [0, 1, 2] {
            assert_eq!(p.admit_pull(Some(0), iter, &shutdown), Some(PullGate::Fresh));
        }
        assert_eq!(p.slowest(), 0);
        assert_eq!(p.waiters(), 0);
    }

    #[test]
    fn past_window_pulls_park_until_the_slowest_advances() {
        let p = Arc::new(SspPolicy::new(1));
        p.register_worker(0);
        p.register_worker(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (p2, s2) = (p.clone(), shutdown.clone());
        let t = std::thread::spawn(move || p2.admit_pull(Some(0), 2, &s2));
        wait_until("pull to park", || p.waiters() > 0);
        // Worker 1 advancing to iteration 1 puts 2 within 1 + bound(1).
        assert_eq!(p.admit_pull(Some(1), 1, &shutdown), Some(PullGate::Fresh));
        assert_eq!(t.join().unwrap(), Some(PullGate::Fresh));
        assert_eq!(p.waiters(), 0);
    }

    #[test]
    fn the_slowest_worker_is_never_starved() {
        let p = SspPolicy::new(0);
        let shutdown = AtomicBool::new(false);
        p.register_worker(0);
        p.register_worker(1);
        // Even at bound 0, the slowest worker's own pulls always pass.
        for iter in 0..5 {
            assert_eq!(p.admit_pull(Some(0), iter, &shutdown), Some(PullGate::Fresh));
            assert_eq!(p.admit_pull(Some(1), iter, &shutdown), Some(PullGate::Fresh));
        }
        assert_eq!(p.slowest(), 4);
    }

    #[test]
    fn departed_stragglers_release_the_window() {
        let p = Arc::new(SspPolicy::new(0));
        p.register_worker(0);
        p.register_worker(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (p2, s2) = (p.clone(), shutdown.clone());
        let t = std::thread::spawn(move || p2.admit_pull(Some(0), 3, &s2));
        wait_until("pull to park", || p.waiters() > 0);
        p.deregister_worker(1);
        assert_eq!(t.join().unwrap(), Some(PullGate::Fresh));
    }

    #[test]
    fn interrupt_releases_parked_pulls_on_shutdown() {
        let p = Arc::new(SspPolicy::new(0));
        p.register_worker(0);
        p.register_worker(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (p2, s2) = (p.clone(), shutdown.clone());
        let t = std::thread::spawn(move || p2.admit_pull(Some(0), 9, &s2));
        wait_until("pull to park", || p.waiters() > 0);
        shutdown.store(true, Ordering::SeqCst);
        p.interrupt();
        assert_eq!(t.join().unwrap(), None, "shutdown must interrupt the wait");
    }

    #[test]
    fn anonymous_sessions_are_served_fresh_and_never_gate() {
        let p = SspPolicy::new(0);
        let shutdown = AtomicBool::new(false);
        p.register_worker(0);
        // No worker id: no clock, no parking, whatever the iteration.
        assert_eq!(p.admit_pull(None, 50, &shutdown), Some(PullGate::Fresh));
        assert_eq!(p.slowest(), 0, "anonymous pulls leave the clocks alone");
    }
}
