//! Bulk-synchronous parallel — the barrier semantics extracted, unchanged,
//! from the pre-subsystem `ps/server.rs`.
//!
//! A pull for iteration `t` parks on the per-layer version condvars until
//! every requested layer has `version >= t` (the condvar wait itself lives
//! in the server's assembly path — this policy only *names* the gate, so
//! the extraction is behavior-identical and the existing server, worker,
//! and codec-train suites pass unmodified). A push is accumulated and the
//! averaged SGD update is applied once every registered worker has
//! contributed, which is what advances the version clock.

use std::sync::atomic::AtomicBool;

use super::{PullGate, PushApply, SyncMode, SyncPolicy};

/// Stateless: the barrier state (gradient counts, per-layer versions) is
/// the server's own, exactly as before the extraction.
pub struct BspPolicy;

impl SyncPolicy for BspPolicy {
    fn mode(&self) -> SyncMode {
        SyncMode::Bsp
    }

    fn admit_pull(
        &self,
        _worker: Option<u32>,
        iter: u64,
        _shutdown: &AtomicBool,
    ) -> Option<PullGate> {
        Some(PullGate::WaitFor { min: iter })
    }

    fn on_push(&self, _worker: Option<u32>, _iter: u64) -> PushApply {
        PushApply::Barrier
    }

    fn slowest(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bsp_always_gates_on_the_requested_iteration() {
        let p = BspPolicy;
        let shutdown = AtomicBool::new(false);
        for iter in [0u64, 1, 99] {
            assert_eq!(
                p.admit_pull(Some(0), iter, &shutdown),
                Some(PullGate::WaitFor { min: iter })
            );
            assert_eq!(p.admit_pull(None, iter, &shutdown), Some(PullGate::WaitFor { min: iter }));
            assert_eq!(p.on_push(Some(0), iter), PushApply::Barrier);
        }
        assert_eq!(p.staleness_bound(), 0);
        assert_eq!(p.name(), "bsp");
    }
}
