//! The regional aggregation tier (`docs/TOPOLOGY.md`).
//!
//! A [`RegionalAggregator`] sits between a group of edge workers and the
//! cloud shards (protocol v5). **Downstream** it speaks the full server
//! surface — `Hello`/`AggHello` registration, `SyncPropose`/`CodecPropose`
//! negotiation, pulls, pushes — so an edge worker connects to it exactly
//! as it would to a shard (with `server_addrs = [aggregator]` the worker's
//! shard map sees one server owning every layer). **Upstream** it is a
//! single super-worker per shard: it registers with `AggHello { role:
//! Regional, workers: G }` so its combined pushes carry the group's
//! barrier weight, sums its group's gradients per layer and forwards
//! **one** push per layer per iteration, and fans one shared upstream
//! pull reply out to every group member through the same single-flight
//! [`ReplyCache`]/pooled-slab seam the server uses. Cloud ingress and
//! egress therefore shrink by ~group size.
//!
//! Each hop negotiates its own sync policy and wire codec independently:
//! the downstream hop runs the aggregator's own [`SyncPolicy`] and serves
//! whatever codec each edge session negotiates; the upstream hop proposes
//! its own mode/codec to the shards (e.g. ASP+int8 edge→regional,
//! SSP+fp16 regional→cloud). When the two hops agree on a codec, reply
//! bytes pass through untouched; otherwise each layer is decoded and
//! re-encoded (a lossy recompression under quantizing codecs — see
//! `docs/TOPOLOGY.md` for the accuracy note).
//!
//! The forwarded push is the **raw sum** of the group's gradients, not an
//! average: the cloud scales every update by `lr / workers` with
//! `workers` the *total* edge fleet, so `G` summed gradients carrying
//! barrier weight `G` reproduce the flat fleet's update bit-for-bit
//! (`docs/TOPOLOGY.md` has the algebra).

use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::net::codec::{self, CodecId};
use crate::net::pool::{PooledSlab, SlabPool};
use crate::net::{slab, Connection, Message, MessageRef, PeerRole, TraceCtx, PROTOCOL_VERSION};
use crate::ps::reply_cache::{ReplyCache, ReplyState};
use crate::ps::sharding::ShardMap;
use crate::ps::sync::{self, PullGate, SyncConfig, SyncPolicy};
use crate::ps::worker::{connect_with_retry, propose_codec, propose_sync};
use crate::util::sync::{lock_or_die, wait_or_die};

/// Configuration of one regional aggregator.
#[derive(Debug, Clone)]
pub struct AggConfig {
    /// The group identity this aggregator registers upstream (`AggHello`).
    /// Must not collide with any identity registering directly at the
    /// shards — the trainer allocates group ids past the worker ids.
    pub group: u32,
    /// Edge workers in the group: the barrier weight of every combined
    /// push, and the fan-in target per layer per iteration.
    pub workers: u32,
    /// The cloud shards, in shard order (the round-robin layer striping
    /// upstream must match the shards' own).
    pub upstream_addrs: Vec<std::net::SocketAddr>,
    /// f32 elements per layer (`w‖b` flat), indexed by layer id — sizes
    /// every accumulator and wire length without touching the runtime.
    pub layer_elems: Vec<usize>,
    /// The edge→regional hop's sync policy (served authoritatively to
    /// downstream `SyncPropose`s).
    pub downstream_sync: SyncConfig,
    /// The regional→cloud hop's expected sync configuration (proposed to
    /// every shard; a mismatch fails the boot loudly).
    pub upstream_sync: SyncConfig,
    /// Preferred regional→cloud wire codec; falls back to fp32 unless
    /// every upstream session agrees.
    pub upstream_codec: CodecId,
    /// Cap on concurrently live downstream handler threads (clamped to
    /// never sit below `workers`, as on the server).
    pub handler_threads: usize,
    /// Upstream I/O deadline, ms (`--io-timeout-ms`); 0 disables. Armed,
    /// a cloud shard that dies mid-reply fails the aggregator's upstream
    /// recv within the window instead of hanging the whole group
    /// (`docs/FAULTS.md`). Same BSP caveat as on the worker: forwarded
    /// pulls park at the cloud barrier through these sockets, so the
    /// deadline must exceed the slowest straggler's round.
    pub io_timeout_ms: u64,
}

/// Aggregator-side observability counters.
#[derive(Debug, Clone, Copy)]
pub struct AggStats {
    /// Downstream pulls answered from an already-assembled shared reply.
    pub reply_cache_hits: u64,
    /// Shared replies actually assembled (== upstream pull rounds).
    pub reply_cache_builds: u64,
    /// Combined per-layer pushes forwarded upstream.
    pub forwarded_pushes: u64,
    /// Downstream sessions that completed registration.
    pub connected: u32,
}

/// Per-layer fan-in accumulator: the group's gradient sum for the
/// iteration currently in flight.
struct AccSlot {
    sum: Vec<f32>,
    /// Accumulated barrier weight (a stacked sub-aggregator's push
    /// contributes its own group size).
    count: usize,
    /// Iteration of the contributions currently accumulating — stamped on
    /// the forwarded push.
    pending_iter: u64,
}

/// Downstream membership and barrier weights, mirroring the server's
/// elastic registry: a departed group member shrinks the fan-in target so
/// the survivors' combined push still goes out.
struct Registry {
    peers: HashMap<u32, (u32, u32)>,
    departed: u32,
}

/// A completed layer, extracted from its accumulator under the lock and
/// forwarded upstream outside it.
struct Completed {
    layer: usize,
    iter: u64,
    sum: Vec<f32>,
}

struct Shared {
    workers: u32,
    /// This aggregator's node name in the merged fleet trace
    /// (`agg-{group}`): the process lane its handler spans land on.
    node: String,
    /// The downstream hop's synchronization policy.
    sync: Box<dyn SyncPolicy>,
    handler_threads: usize,
    live_handlers: AtomicU32,
    /// Layer → upstream shard striping (must match the shards' own map).
    shard: ShardMap,
    layer_elems: Vec<usize>,
    /// Per-layer fan-in accumulators, indexed by layer id.
    acc: Vec<Mutex<AccSlot>>,
    /// Upstream pull connections, one per shard. Separate from the push
    /// connections by design: a forwarded pull may park at the cloud
    /// barrier for as long as the rest of the fleet takes, and a combined
    /// push must still be able to go out — one shared socket (or one
    /// mutex over it) would deadlock the group against itself.
    up_pull: Vec<Mutex<Connection>>,
    /// Upstream push connections, one per shard.
    up_push: Vec<Mutex<Connection>>,
    /// Shard addresses, for connections outside the two registered
    /// sessions: a forwarded `SnapshotReq` dials its own short-lived
    /// anonymous connection per shard — the shared pull socket may be
    /// parked at the cloud barrier waiting on the very joiner asking
    /// for the snapshot (`docs/FAULTS.md`).
    up_addrs: Vec<std::net::SocketAddr>,
    /// Pull/push I/O deadline for upstream sockets (0 disables), also
    /// applied to the on-demand snapshot connections.
    io_timeout_ms: u64,
    /// The codec every upstream session agreed to.
    up_codec: CodecId,
    pool: Arc<SlabPool>,
    /// Single-flight shared-reply cache for downstream pulls, keyed
    /// `(key_iter, lo, hi, downstream codec)`.
    reply_cache: ReplyCache,
    registry: Mutex<Registry>,
    /// Key clock for `Fresh` downstream gates: 1 + the highest iteration
    /// forwarded upstream, so a fresh pull asks the cloud for a snapshot
    /// that includes the group's own latest contribution and the shared
    /// reply invalidates once per forwarded round.
    fwd_iter: AtomicU64,
    /// Combined per-layer pushes forwarded upstream
    /// (`dynacomm_agg_forwarded_pushes_total` in the obs registry).
    forwarded: crate::obs::Counter,
    shutting_down: AtomicBool,
    connected: AtomicU32,
    /// Live downstream sockets (kill registry, as on the server).
    conns: Mutex<Vec<Option<TcpStream>>>,
}

/// A running regional aggregator: downstream accept loop + handlers, with
/// registered upstream sessions to every shard.
pub struct RegionalAggregator {
    shared: Arc<Shared>,
    listener_thread: Option<JoinHandle<()>>,
    addr: std::net::SocketAddr,
    /// Duplicate fds of the upstream sockets so shutdown can fail any
    /// in-flight upstream recv deterministically.
    up_kill: Vec<TcpStream>,
}

impl RegionalAggregator {
    /// Bind the downstream listener, connect and register both upstream
    /// sessions (pull + push) with every shard — `AggHello` carrying the
    /// group's worker count, the upstream sync mode verified, the
    /// upstream codec unified (fp32 fallback) — then start serving.
    pub fn start(cfg: AggConfig) -> Result<RegionalAggregator> {
        anyhow::ensure!(cfg.workers > 0, "aggregator group must have workers");
        anyhow::ensure!(!cfg.upstream_addrs.is_empty(), "aggregator needs upstream shards");
        anyhow::ensure!(!cfg.layer_elems.is_empty(), "aggregator needs layer sizes");
        cfg.downstream_sync.validate()?;
        cfg.upstream_sync.validate()?;
        let listener = TcpListener::bind("127.0.0.1:0").context("bind aggregator")?;
        let addr = listener.local_addr()?;

        // Both upstream sessions per shard register under the same group
        // identity, so the shard counts the weight — and the departure —
        // exactly once (`ps::server`'s registry).
        let mut up_pull = Vec::with_capacity(cfg.upstream_addrs.len());
        let mut up_push = Vec::with_capacity(cfg.upstream_addrs.len());
        let mut up_kill = Vec::new();
        for shard_addr in &cfg.upstream_addrs {
            for conns in [&mut up_pull, &mut up_push] {
                // Jitter seed: the group identity — concurrent aggregators
                // dialing a restarted shard decorrelate deterministically.
                let stream = connect_with_retry(shard_addr, cfg.group as u64)?;
                up_kill.push(stream.try_clone()?);
                let mut conn = Connection::new(stream, None);
                conn.set_io_timeout(crate::ps::worker::io_timeout_of(cfg.io_timeout_ms))?;
                conn.send(&Message::AggHello {
                    role: PeerRole::Regional,
                    group: cfg.group,
                    workers: cfg.workers,
                    version: PROTOCOL_VERSION,
                })?;
                match conn.recv()? {
                    Message::HelloAck { version, .. } if version == PROTOCOL_VERSION => {}
                    Message::HelloAck { version, .. } => anyhow::bail!(
                        "protocol version mismatch with shard {shard_addr}: \
                         aggregator speaks v{PROTOCOL_VERSION}, server v{version}"
                    ),
                    m => anyhow::bail!("bad agg hello ack: {m:?}"),
                }
                propose_sync(
                    &mut conn,
                    cfg.upstream_sync.mode,
                    cfg.upstream_sync.staleness_bound,
                )?;
                conns.push(conn);
            }
        }
        // Unify the upstream codec across every session (both directions,
        // all shards): split-codec stitching would need per-shard byte
        // tables for no benefit, so any disagreement unifies on fp32.
        let mut up_codec = cfg.upstream_codec;
        if up_codec != CodecId::Fp32 {
            for conn in up_pull.iter_mut().chain(up_push.iter_mut()) {
                if propose_codec(conn, up_codec)? != up_codec {
                    up_codec = CodecId::Fp32;
                    break;
                }
            }
            if up_codec == CodecId::Fp32 {
                for conn in up_pull.iter_mut().chain(up_push.iter_mut()) {
                    anyhow::ensure!(
                        propose_codec(conn, CodecId::Fp32)? == CodecId::Fp32,
                        "shard refused the mandatory fp32 fallback"
                    );
                }
            }
        }
        // Align clocks with every upstream shard at establish
        // (docs/OBSERVABILITY.md): the merged fleet trace corrects each
        // shard lane onto this process's timeline with these offsets.
        for (conn, shard_addr) in up_pull.iter_mut().zip(&cfg.upstream_addrs) {
            let shard_node = format!("shard-{}", shard_addr.port());
            crate::obs::clock::probe_and_note(conn, &shard_node, 3)
                .with_context(|| format!("clock probe against shard {shard_addr}"))?;
        }

        let acc = cfg
            .layer_elems
            .iter()
            .map(|&n| Mutex::new(AccSlot { sum: vec![0.0; n], count: 0, pending_iter: 0 }))
            .collect();
        let shared = Arc::new(Shared {
            workers: cfg.workers,
            node: format!("agg-{}", cfg.group),
            sync: sync::create(cfg.downstream_sync),
            handler_threads: cfg.handler_threads.max(cfg.workers as usize).max(1),
            live_handlers: AtomicU32::new(0),
            shard: ShardMap::new(cfg.upstream_addrs.len(), cfg.layer_elems.len()),
            layer_elems: cfg.layer_elems,
            acc,
            up_pull: up_pull.into_iter().map(Mutex::new).collect(),
            up_push: up_push.into_iter().map(Mutex::new).collect(),
            up_addrs: cfg.upstream_addrs,
            io_timeout_ms: cfg.io_timeout_ms,
            up_codec,
            pool: SlabPool::new(),
            reply_cache: ReplyCache::new("agg"),
            registry: Mutex::new(Registry { peers: HashMap::new(), departed: 0 }),
            fwd_iter: AtomicU64::new(0),
            forwarded: crate::obs_counter!("dynacomm_agg_forwarded_pushes_total"),
            shutting_down: AtomicBool::new(false),
            connected: AtomicU32::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let shared2 = shared.clone();
        let listener_thread = std::thread::Builder::new()
            .name(format!("agg-accept-{}", addr.port()))
            .spawn(move || accept_loop(listener, shared2))?;
        Ok(RegionalAggregator { shared, listener_thread: Some(listener_thread), addr, up_kill })
    }

    /// The downstream address edge workers connect to.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The codec every upstream session agreed to.
    pub fn upstream_codec(&self) -> CodecId {
        self.shared.up_codec
    }

    pub fn stats(&self) -> AggStats {
        AggStats {
            reply_cache_hits: self.shared.reply_cache.hits.get(),
            reply_cache_builds: self.shared.reply_cache.builds.get(),
            forwarded_pushes: self.shared.forwarded.get(),
            connected: self.shared.connected.load(Ordering::SeqCst),
        }
    }

    /// Downstream pulls currently parked inside the sync policy's gate.
    pub fn sync_waiters(&self) -> u32 {
        self.shared.sync.waiters()
    }

    /// Drain and stop: wake parked downstream pulls and cache waiters,
    /// kill downstream and upstream sockets so blocked reads return, then
    /// join the accept loop (which joins every handler).
    pub fn shutdown(&mut self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        self.shared.sync.interrupt();
        {
            let _entries = lock_or_die(&self.shared.reply_cache.entries, "reply_cache.entries");
            self.shared.reply_cache.ready.notify_all();
        }
        for slot in lock_or_die(&self.shared.conns, "agg.conns").iter_mut() {
            if let Some(stream) = slot.take() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        // A handler may be blocked mid-assembly on an upstream reply (a
        // forwarded pull parked at the cloud barrier): fail those reads
        // too, or the handler join below would wait on the cloud.
        for stream in &self.up_kill {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RegionalAggregator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut handlers = Vec::new();
    loop {
        // Bounded handler pool with kernel-backlog backpressure, exactly
        // as on the server (`ps::server::accept_loop`).
        loop {
            handlers.retain(|h: &std::thread::JoinHandle<()>| !h.is_finished());
            if handlers.len() < shared.handler_threads
                || shared.shutting_down.load(Ordering::SeqCst)
            {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let Ok((stream, _)) = listener.accept() else { break };
        let Ok(dup) = stream.try_clone() else {
            drop(stream);
            continue;
        };
        // Register BEFORE the flag check so shutdown either drains this
        // entry or the check below kills it — no unkillable window.
        let conn_id = {
            let mut conns = lock_or_die(&shared.conns, "agg.conns");
            match conns.iter_mut().position(|slot| slot.is_none()) {
                Some(i) => {
                    conns[i] = Some(dup);
                    i
                }
                None => {
                    conns.push(Some(dup));
                    conns.len() - 1
                }
            }
        };
        if shared.shutting_down.load(Ordering::SeqCst) {
            let _ = stream.shutdown(Shutdown::Both);
            break;
        }
        let shared2 = shared.clone();
        let node2 = shared.node.clone();
        shared.live_handlers.fetch_add(1, Ordering::SeqCst);
        let spawned = std::thread::Builder::new()
            .name(format!("{}-h{}", shared.node, conn_id))
            .spawn(move || {
                crate::obs::trace::adopt_node(&node2);
                let conn = Connection::new(stream, None);
                if let Err(e) = handle_conn(conn, &shared2) {
                    crate::debug!("agg", "handler exit: {e:#}");
                }
                lock_or_die(&shared2.conns, "agg.conns")[conn_id] = None;
                shared2.live_handlers.fetch_sub(1, Ordering::SeqCst);
            });
        match spawned {
            Ok(h) => handlers.push(h),
            Err(_) => {
                // Spawn failed: the closure never ran, so undo its
                // bookkeeping here.
                lock_or_die(&shared.conns, "agg.conns")[conn_id] = None;
                shared.live_handlers.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// The group fan-in target right now: the configured group size minus
/// every fully departed member's weight, floored at 1.
fn group_target(shared: &Shared) -> usize {
    let departed = lock_or_die(&shared.registry, "agg.registry").departed as usize;
    (shared.workers as usize).saturating_sub(departed).max(1)
}

/// Record a downstream identity; `true` on its first live session (only
/// then does the downstream sync policy see a registration).
fn register_identity(shared: &Shared, id: u32, weight: u32) -> bool {
    let mut reg = lock_or_die(&shared.registry, "agg.registry");
    match reg.peers.get_mut(&id) {
        Some(entry) => {
            entry.1 += 1;
            false
        }
        None => {
            reg.departed = reg.departed.saturating_sub(weight);
            reg.peers.insert(id, (weight, 1));
            true
        }
    }
}

/// A downstream session ended. On the identity's last session its weight
/// departs (shrinking the fan-in target) and any layer whose accumulated
/// weight already meets the new target forwards immediately — a group
/// member that hung up mid-iteration must not strand the survivors'
/// gradients at the aggregator.
fn deregister_identity(shared: &Shared, id: u32) -> Result<()> {
    let fully_departed = {
        let mut reg = lock_or_die(&shared.registry, "agg.registry");
        match reg.peers.get_mut(&id) {
            Some(entry) if entry.1 > 1 => {
                entry.1 -= 1;
                false
            }
            Some(_) => {
                let (weight, _) = reg.peers.remove(&id).expect("entry just matched");
                reg.departed += weight;
                true
            }
            None => false,
        }
    };
    if fully_departed {
        shared.sync.deregister_worker(id);
        if shared.shutting_down.load(Ordering::SeqCst) {
            return Ok(());
        }
        let target = group_target(shared);
        let mut done = Vec::new();
        for (l, m) in shared.acc.iter().enumerate() {
            let mut slot = lock_or_die(m, "agg.acc");
            if slot.count > 0 && slot.count >= target {
                done.push(take_completed(l, &mut slot, shared.layer_elems[l]));
            }
        }
        for c in done {
            // No originating push frame here — the trigger was a
            // departure, not a traced message — so no remote parent.
            forward_push(shared, c, None)?;
        }
    }
    Ok(())
}

/// Extract a completed layer's sum and reset the accumulator (caller
/// holds the slot lock; the upstream send happens outside it).
fn take_completed(layer: usize, slot: &mut AccSlot, elems: usize) -> Completed {
    let sum = std::mem::replace(&mut slot.sum, vec![0.0; elems]);
    let iter = slot.pending_iter;
    slot.count = 0;
    Completed { layer, iter, sum }
}

/// Accumulate one downstream push into the per-layer fan-in slots;
/// returns the layers the push completed (fan-in target reached), to be
/// forwarded outside the accumulator locks.
fn accumulate_push(
    shared: &Shared,
    iter: u64,
    lo: u32,
    hi: u32,
    codec_id: CodecId,
    data: &[u8],
    weight: u32,
    ctx: Option<TraceCtx>,
) -> Result<Vec<Completed>> {
    let mut sp = crate::obs::trace::span(crate::obs::trace::SPAN_AGG_FAN_IN);
    if let Some(c) = ctx {
        if !c.is_reply() {
            // The downstream push is ack-synchronous, so this fan-in nests
            // inside the sender's push window: a containment parent.
            sp.set_remote_parent(c.parent_span);
        }
    }
    let wc = codec_id.codec();
    let target = group_target(shared);
    let mut off = 0usize;
    let mut done = Vec::new();
    for l in lo as usize..=hi as usize {
        let Some(&elems) = shared.layer_elems.get(l) else { continue };
        let n = wc.wire_len(slab::ELEM * elems);
        anyhow::ensure!(
            off + n <= data.len(),
            "push payload too small for layers {lo}..={hi}"
        );
        let mut slot = lock_or_die(&shared.acc[l], "agg.acc");
        wc.accumulate(&mut slot.sum, &data[off..off + n])?;
        slot.count += weight as usize;
        slot.pending_iter = iter;
        if slot.count >= target {
            done.push(take_completed(l, &mut slot, elems));
        }
        drop(slot);
        off += n;
    }
    anyhow::ensure!(off == data.len(), "push payload size mismatch");
    Ok(done)
}

/// Forward one completed layer upstream: encode the group's raw gradient
/// sum with the upstream codec and push it to the owning shard (send +
/// ack under that shard's push-connection lock). The push is a *sum*, not
/// an average — the shard's `lr / total-workers` scaling averages it.
fn forward_push(shared: &Shared, c: Completed, ctx: Option<TraceCtx>) -> Result<()> {
    let mut sp = crate::obs::trace::span(crate::obs::trace::SPAN_AGG_FORWARD);
    if let Some(x) = ctx {
        if !x.is_reply() {
            // Parented to the downstream push that completed the fan-in:
            // that worker still holds its push window open waiting for the
            // ack this forward precedes.
            sp.set_remote_parent(x.parent_span);
        }
    }
    let raw = slab::from_f32s(&c.sum);
    let wc = shared.up_codec.codec();
    let mut wire = Vec::with_capacity(shared.up_codec.wire_len(raw.len()));
    wc.encode(&raw, &mut wire);
    let srv = shared.shard.owner(c.layer);
    {
        // The shard's apply span parents to THIS forward span, not the
        // edge worker's push — the trace mirrors the two-hop topology.
        let up_ctx = if sp.id() != 0 {
            Some(TraceCtx::sampled(crate::obs::trace::trace_id_for(c.iter), sp.id()))
        } else {
            None
        };
        let mut conn = lock_or_die(&shared.up_push[srv], "agg.upstream");
        conn.send_ctx(
            &Message::Push {
                iter: c.iter,
                lo: c.layer as u32,
                hi: c.layer as u32,
                codec: shared.up_codec,
                data: wire,
            },
            up_ctx,
        )?;
        match conn.recv()? {
            Message::PushAck { .. } => {}
            m => anyhow::bail!("bad upstream push ack: {m:?}"),
        }
    }
    shared.forwarded.inc();
    shared.fwd_iter.fetch_max(c.iter + 1, Ordering::SeqCst);
    Ok(())
}

/// Assemble the shared downstream reply for `[lo, hi]`: one upstream pull
/// per owning shard (requesting iteration `up_iter`), stitched back into
/// ascending layer order, each layer's bytes re-encoded for the
/// downstream codec — or passed through untouched when the hops agree.
/// Returns the slab plus the oldest `applied` among the shard replies and
/// the fan-out span's id (0 untraced) — the reply-direction trace context
/// every downstream reply sharing this assembly points back at.
fn assemble_reply(
    shared: &Shared,
    up_iter: u64,
    lo: u32,
    hi: u32,
    down_codec: CodecId,
) -> Result<(Arc<PooledSlab>, u64, u32)> {
    let mut sp = crate::obs::trace::span(crate::obs::trace::SPAN_AGG_FAN_OUT);
    let depth = shared.layer_elems.len();
    let lo_u = (lo as usize).min(depth - 1);
    let hi_u = (hi as usize).min(depth - 1);
    // One pull per shard covering the whole range: a shard replies with
    // only its owned layers, ascending — exactly one cursor per shard in
    // the stitch below.
    let servers = shared.shard.servers;
    let mut shard_replies: Vec<Option<Vec<u8>>> = (0..servers).map(|_| None).collect();
    let mut applied_min = u64::MAX;
    let mut flow_from: Option<u32> = None;
    for sub in shared.shard.sub_requests(lo_u, hi_u) {
        let mut conn = lock_or_die(&shared.up_pull[sub.server], "agg.upstream");
        conn.send(&Message::Pull { iter: up_iter, lo, hi })?;
        let (msg, up_ctx) = conn.recv_ref_ctx()?;
        let (rcodec, applied, data) = match msg {
            MessageRef::PullReply { codec, applied, data, .. } => {
                (codec, applied, data.to_vec())
            }
            m => anyhow::bail!("bad upstream pull reply: {:?}", m.into_owned()),
        };
        if flow_from.is_none() {
            // First shard reply stitches the upstream assemble → this
            // fan-out arrow (one arrow per assembly is enough to walk the
            // chain; reply windows do not nest, hence flow not parent).
            flow_from = up_ctx.filter(|c| c.is_reply()).map(|c| c.parent_span);
        }
        drop(conn);
        anyhow::ensure!(
            rcodec == shared.up_codec,
            "upstream reply codec mismatch: got {}, session speaks {}",
            rcodec.name(),
            shared.up_codec.name()
        );
        applied_min = applied_min.min(applied);
        shard_replies[sub.server] = Some(data);
    }
    let cap: usize = (lo_u..=hi_u)
        .map(|l| down_codec.wire_len(slab::ELEM * shared.layer_elems[l]))
        .sum();
    let mut data = shared.pool.checkout(cap);
    let wc_up = shared.up_codec.codec();
    let wc_down = down_codec.codec();
    let mut offs = vec![0usize; servers];
    let mut scratch = Vec::new();
    for l in lo_u..=hi_u {
        let srv = shared.shard.owner(l);
        let reply = shard_replies[srv].as_ref().context("missing shard reply")?;
        let n_up = shared.up_codec.wire_len(slab::ELEM * shared.layer_elems[l]);
        anyhow::ensure!(
            offs[srv] + n_up <= reply.len(),
            "upstream reply too small for layer {l}"
        );
        let chunk = &reply[offs[srv]..offs[srv] + n_up];
        offs[srv] += n_up;
        if down_codec == shared.up_codec {
            // Same codec on both hops: byte passthrough, no precision
            // loss beyond the upstream hop's own.
            data.extend_from_slice(chunk);
        } else {
            // Codec cascade: decode the upstream encoding, re-encode for
            // the downstream hop (lossy under quantizing codecs).
            scratch.clear();
            wc_up.decode(chunk, &mut scratch)?;
            wc_down.encode(&scratch, &mut data);
        }
    }
    let applied = if applied_min == u64::MAX { up_iter } else { applied_min };
    if let Some(f) = flow_from {
        sp.set_flow_from(f);
    }
    Ok((data.freeze(), applied, sp.id()))
}

/// Assemble a mid-run joiner's snapshot (`docs/FAULTS.md`): one
/// `SnapshotReq` per owning shard, stitched and re-encoded exactly like
/// [`assemble_reply`], tagged with the *oldest* shard clock so the joiner
/// enters no further ahead than the slowest shard. Each request rides a
/// fresh **anonymous** upstream connection — the registered pull socket
/// may be parked at the cloud barrier waiting on the very joiner asking
/// for the snapshot, and an anonymous session (no `Hello`) never gates —
/// and rare (once per join), so it bypasses the shared-reply cache.
fn assemble_snapshot(
    shared: &Shared,
    lo: u32,
    hi: u32,
    down_codec: CodecId,
) -> Result<(Arc<PooledSlab>, u64)> {
    let depth = shared.layer_elems.len();
    let lo_u = (lo as usize).min(depth - 1);
    let hi_u = (hi as usize).min(depth - 1);
    let servers = shared.shard.servers;
    let mut shard_replies: Vec<Option<Vec<u8>>> = (0..servers).map(|_| None).collect();
    let mut iter_min = u64::MAX;
    for sub in shared.shard.sub_requests(lo_u, hi_u) {
        let addr = shared.up_addrs[sub.server];
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("snapshot connection to shard {addr}"))?;
        let mut conn = Connection::new(stream, None);
        conn.set_io_timeout(crate::ps::worker::io_timeout_of(shared.io_timeout_ms))?;
        if shared.up_codec != CodecId::Fp32 {
            // The fresh session starts at the fp32 default; align it with
            // the upstream hop's codec so the stitched bytes match
            // `assemble_reply`'s precision.
            conn.send(&Message::CodecPropose { pref: shared.up_codec })?;
            match conn.recv()? {
                Message::CodecAgree { codec } if codec == shared.up_codec => {}
                m => anyhow::bail!("shard {addr} refused snapshot codec: {m:?}"),
            }
        }
        conn.send(&Message::SnapshotReq { lo, hi })?;
        let (rcodec, iter, data) = match conn.recv()? {
            Message::SnapshotReply { codec, iter, data, .. } => (codec, iter, data),
            m => anyhow::bail!("bad upstream snapshot reply: {m:?}"),
        };
        drop(conn);
        anyhow::ensure!(
            rcodec == shared.up_codec,
            "upstream snapshot codec mismatch: got {}, session speaks {}",
            rcodec.name(),
            shared.up_codec.name()
        );
        iter_min = iter_min.min(iter);
        shard_replies[sub.server] = Some(data);
    }
    let cap: usize = (lo_u..=hi_u)
        .map(|l| down_codec.wire_len(slab::ELEM * shared.layer_elems[l]))
        .sum();
    let mut data = shared.pool.checkout(cap);
    let wc_up = shared.up_codec.codec();
    let wc_down = down_codec.codec();
    let mut offs = vec![0usize; servers];
    let mut scratch = Vec::new();
    for l in lo_u..=hi_u {
        let srv = shared.shard.owner(l);
        let reply = shard_replies[srv].as_ref().context("missing shard snapshot")?;
        let n_up = shared.up_codec.wire_len(slab::ELEM * shared.layer_elems[l]);
        anyhow::ensure!(
            offs[srv] + n_up <= reply.len(),
            "upstream snapshot too small for layer {l}"
        );
        let chunk = &reply[offs[srv]..offs[srv] + n_up];
        offs[srv] += n_up;
        if down_codec == shared.up_codec {
            data.extend_from_slice(chunk);
        } else {
            scratch.clear();
            wc_up.decode(chunk, &mut scratch)?;
            wc_down.encode(&scratch, &mut data);
        }
    }
    let iter = if iter_min == u64::MAX { 0 } else { iter_min };
    Ok((data.freeze(), iter))
}

/// Serve a downstream pull: admit via the downstream sync policy, derive
/// the shared-reply key its gate implies, and serve from the single-flight
/// cache. `Ok(None)` only on shutdown.
fn serve_pull(
    shared: &Shared,
    worker: Option<u32>,
    iter: u64,
    lo: u32,
    hi: u32,
    codec_id: CodecId,
) -> Result<Option<(Arc<PooledSlab>, u64, u32)>> {
    let Some(gate) = shared.sync.admit_pull(worker, iter, &shared.shutting_down) else {
        return Ok(None);
    };
    // Under a barrier gate the key is the iteration (the forwarded pull
    // parks at the *cloud's* version clock, so the barrier holds
    // transitively without aggregator-local versions); under a fresh gate
    // the key — and the requested upstream iteration — is the forwarded-
    // push clock, so the group's own latest contribution is included and
    // the shared reply invalidates once per forwarded round.
    let key_iter = match gate {
        PullGate::WaitFor { min } => min,
        PullGate::Fresh => shared.fwd_iter.load(Ordering::SeqCst),
    };
    let key = (key_iter, lo, hi, codec_id);
    let cache = &shared.reply_cache;
    let mut entries = lock_or_die(&cache.entries, "reply_cache.entries");
    loop {
        if shared.shutting_down.load(Ordering::SeqCst) {
            return Ok(None);
        }
        enum Peek {
            Hit(Arc<PooledSlab>, u64, u32),
            Wait,
            Vacant,
        }
        let peek = match entries.get(&key) {
            Some(ReplyState::Ready(slab, applied, aspan)) => {
                Peek::Hit(slab.clone(), *applied, *aspan)
            }
            Some(ReplyState::Building) => Peek::Wait,
            None => Peek::Vacant,
        };
        match peek {
            Peek::Hit(slab, applied, aspan) => {
                cache.hits.inc();
                return Ok(Some((slab, applied, aspan)));
            }
            Peek::Wait => {
                entries = wait_or_die(&cache.ready, entries, "reply_cache.entries");
            }
            Peek::Vacant => {
                entries.insert(key, ReplyState::Building);
                drop(entries);
                let built = assemble_reply(shared, key_iter, lo, hi, codec_id);
                let mut relocked = lock_or_die(&cache.entries, "reply_cache.entries");
                let out = match built {
                    Ok((slab, applied, aspan)) => {
                        cache.builds.inc();
                        relocked.insert(key, ReplyState::Ready(slab.clone(), applied, aspan));
                        // Same bounded-cache discipline as the server:
                        // keep in-flight keys, evict finished rounds.
                        relocked.retain(|k, v| {
                            matches!(v, ReplyState::Building) || k.0 + 1 >= key_iter
                        });
                        Ok(Some((slab, applied, aspan)))
                    }
                    Err(e) => {
                        // Clear the Building marker so waiters don't park
                        // forever, then fail this session.
                        relocked.remove(&key);
                        Err(e)
                    }
                };
                drop(relocked);
                cache.ready.notify_all();
                return out;
            }
        }
    }
}

/// What a received downstream message asks the handler to do once the
/// receive borrow is released.
enum Action {
    Register { id: u32, weight: u32, version: u16, role: &'static str },
    Reply(Message),
    ReplyShared {
        iter: u64,
        lo: u32,
        hi: u32,
        applied: u64,
        slab: Arc<PooledSlab>,
        /// Span id of the fan-out assembly serving this reply (0 =
        /// untraced): sent as the reply-direction trace context.
        aspan: u32,
    },
    ReplySnapshot { iter: u64, lo: u32, hi: u32, slab: Arc<PooledSlab> },
    Forward { acks: (u64, u32, u32), done: Vec<Completed>, ctx: Option<TraceCtx> },
    /// Answer a clock probe: `t1` echoed, `t2` stamped at decode; `t3` is
    /// stamped at the send itself so it excludes handler queueing.
    ReplyClock { t1: u64, t2: u64 },
    Close,
}

fn handle_conn(mut conn: Connection, shared: &Shared) -> Result<()> {
    let mut session_codec = CodecId::Fp32;
    let mut session_worker: Option<u32> = None;
    let mut session_weight: u32 = 1;
    let result = handle_conn_inner(
        &mut conn,
        shared,
        &mut session_codec,
        &mut session_worker,
        &mut session_weight,
    );
    if let Some(w) = session_worker {
        // Departure may complete pending layers; a forwarding failure
        // here is secondary to however the session itself ended.
        let _ = deregister_identity(shared, w);
    }
    result
}

fn handle_conn_inner(
    conn: &mut Connection,
    shared: &Shared,
    session_codec: &mut CodecId,
    session_worker: &mut Option<u32>,
    session_weight: &mut u32,
) -> Result<()> {
    loop {
        let action = {
            let (msg, ctx) = match conn.recv_ref_ctx() {
                Ok(m) => m,
                Err(_) => return Ok(()),
            };
            match msg {
                MessageRef::Hello { worker, version } => {
                    Action::Register { id: worker, weight: 1, version, role: "worker" }
                }
                MessageRef::AggHello { role, group, workers, version } => {
                    // Tiers stack: a sub-aggregator registers downstream
                    // exactly as it would at a shard.
                    Action::Register { id: group, weight: workers, version, role: role.name() }
                }
                MessageRef::CodecPropose { pref } => {
                    *session_codec = codec::negotiate(&[pref], &codec::SUPPORTED);
                    Action::Reply(Message::CodecAgree { codec: *session_codec })
                }
                MessageRef::SyncPropose { .. } => Action::Reply(Message::SyncAgree {
                    mode: shared.sync.mode(),
                    bound: shared.sync.staleness_bound(),
                }),
                MessageRef::Pull { iter, lo, hi } => {
                    match serve_pull(shared, *session_worker, iter, lo, hi, *session_codec)? {
                        Some((slab, applied, aspan)) => {
                            Action::ReplyShared { iter, lo, hi, applied, slab, aspan }
                        }
                        None => Action::Close,
                    }
                }
                MessageRef::Push { iter, lo, hi, codec, data } => {
                    // Advance the downstream clocks, then fan the gradient
                    // into the per-layer accumulators.
                    let _ = shared.sync.on_push(*session_worker, iter);
                    let done = accumulate_push(
                        shared,
                        iter,
                        lo,
                        hi,
                        codec,
                        data,
                        *session_weight,
                        ctx,
                    )?;
                    Action::Forward { acks: (iter, lo, hi), done, ctx }
                }
                MessageRef::ClockProbe { t1 } => {
                    // Answered ungated — a probe must never park at a
                    // barrier, or it would measure the sync policy instead
                    // of the clock.
                    Action::ReplyClock { t1, t2: crate::obs::trace::now_ns() }
                }
                MessageRef::SnapshotReq { lo, hi } => {
                    let (slab, iter) = assemble_snapshot(shared, lo, hi, *session_codec)?;
                    Action::ReplySnapshot { iter, lo, hi, slab }
                }
                MessageRef::Shutdown => Action::Close,
                other => {
                    anyhow::bail!("unexpected message at aggregator: {:?}", other.into_owned())
                }
            }
        };
        match action {
            Action::Register { id, weight, version, role } => {
                conn.send(&Message::HelloAck {
                    workers: shared.workers,
                    version: PROTOCOL_VERSION,
                })?;
                anyhow::ensure!(
                    version == PROTOCOL_VERSION,
                    "protocol version mismatch: {role} {id} speaks \
                     v{version}, aggregator v{PROTOCOL_VERSION}"
                );
                *session_worker = Some(id);
                *session_weight = weight;
                if register_identity(shared, id, weight) {
                    shared.sync.register_worker(id);
                }
                shared.connected.fetch_add(1, Ordering::SeqCst);
            }
            Action::Reply(m) => conn.send(&m)?,
            Action::ReplyShared { iter, lo, hi, applied, slab, aspan } => {
                // When traced, the reply carries an arrow-only context
                // pointing at the fan-out assembly it shares (reply
                // windows do not nest inside the puller's).
                let ctx = if aspan != 0 {
                    Some(TraceCtx::reply(crate::obs::trace::trace_id_for(iter), aspan))
                } else {
                    None
                };
                conn.send_ref_ctx(
                    MessageRef::PullReply {
                        iter,
                        lo,
                        hi,
                        applied,
                        codec: *session_codec,
                        data: &slab[..],
                    },
                    ctx,
                )?;
            }
            Action::ReplySnapshot { iter, lo, hi, slab } => {
                // Same malformed-at-0 floor as the shard's reply: the
                // frame advertises the *group* size — the fleet the
                // joiner is entering at this hop.
                conn.send_ref(MessageRef::SnapshotReply {
                    iter,
                    lo,
                    hi,
                    workers: shared.workers.max(1),
                    codec: *session_codec,
                    data: &slab[..],
                })?;
            }
            Action::Forward { acks: (iter, lo, hi), done, ctx } => {
                // Forward completed layers upstream (outside the
                // accumulator locks), then ack the downstream push — the
                // ack means the gradient is durably on its way, matching
                // the blocking-ack contract workers already rely on.
                for c in done {
                    forward_push(shared, c, ctx)?;
                }
                conn.send(&Message::PushAck { iter, lo, hi })?;
            }
            Action::ReplyClock { t1, t2 } => {
                conn.send(&Message::ClockReply {
                    t1,
                    t2,
                    t3: crate::obs::trace::now_ns(),
                })?;
            }
            Action::Close => return Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::server::{ParamServer, ServerConfig};
    use crate::ps::sync::SyncMode;
    use std::time::{Duration, Instant};

    fn connect(addr: std::net::SocketAddr) -> Connection {
        Connection::new(TcpStream::connect(addr).unwrap(), None)
    }

    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Two layers ({0: [1, 2], 1: [10]}), one cloud shard expecting the
    /// whole fleet, one aggregator fronting a group of `group_workers`.
    fn start_tier(
        fleet: usize,
        group_workers: u32,
    ) -> (ParamServer, RegionalAggregator) {
        let mut layers = HashMap::new();
        layers.insert(0, vec![1.0f32, 2.0]);
        layers.insert(1, vec![10.0f32]);
        let srv =
            ParamServer::start(ServerConfig { workers: fleet, lr: 0.5 }, layers, None)
                .unwrap();
        let agg = RegionalAggregator::start(AggConfig {
            group: 100,
            workers: group_workers,
            upstream_addrs: vec![srv.handle().addr],
            layer_elems: vec![2, 1],
            downstream_sync: SyncConfig::default(),
            upstream_sync: SyncConfig::default(),
            upstream_codec: CodecId::Fp32,
            handler_threads: 8,
            io_timeout_ms: 0,
        })
        .unwrap();
        (srv, agg)
    }

    fn hello(c: &mut Connection, worker: u32) {
        c.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
        assert!(matches!(c.recv().unwrap(), Message::HelloAck { .. }));
    }

    fn push(c: &mut Connection, iter: u64, lo: u32, hi: u32, grads: &[f32]) {
        c.send(&Message::Push {
            iter,
            lo,
            hi,
            codec: CodecId::Fp32,
            data: slab::from_f32s(grads),
        })
        .unwrap();
        assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
    }

    /// The fan-in/fan-out contract: the group's pushes reach the cloud as
    /// ONE combined push per layer carrying the group's weight, group
    /// pulls share ONE upstream assembly, and the resulting update is
    /// bit-identical to the flat fleet's.
    #[test]
    fn group_pushes_combine_and_pulls_share_one_upstream_round() {
        let (srv, agg) = start_tier(2, 2);
        let mut a = connect(agg.addr());
        let mut b = connect(agg.addr());
        // Both group members pull iteration 0: one upstream round.
        for c in [&mut a, &mut b] {
            c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
            match c.recv().unwrap() {
                Message::PullReply { data, .. } => {
                    assert_eq!(slab::to_f32s(&data), vec![1.0, 2.0, 10.0]);
                }
                m => panic!("{m:?}"),
            }
        }
        let st = agg.stats();
        assert_eq!(st.reply_cache_builds, 1, "group pulls must share one assembly");
        assert_eq!(st.reply_cache_hits, 1);
        // A pushes [2, 0 | 3], B pushes [0, 4 | 1]: nothing reaches the
        // cloud until the group is complete.
        push(&mut a, 0, 0, 1, &[2.0, 0.0, 3.0]);
        assert_eq!(srv.snapshot(0).unwrap(), vec![1.0, 2.0], "half a group must not apply");
        assert_eq!(srv.wire_stats().ingress_bytes, 0, "nothing forwarded yet");
        push(&mut b, 0, 0, 1, &[0.0, 4.0, 1.0]);
        // Combined sum [2, 4 | 4] with weight 2 fires the fleet barrier:
        // w -= (0.5 / 2) * sum — exactly the flat two-worker update.
        assert_eq!(srv.snapshot(0).unwrap(), vec![0.5, 1.0]);
        assert_eq!(srv.snapshot(1).unwrap(), vec![9.0]);
        // One combined push per layer went upstream.
        assert_eq!(agg.stats().forwarded_pushes, 2);
        // Cloud ingress: one fp32 slab per layer (12 bytes total), not
        // one per worker (24).
        assert_eq!(srv.wire_stats().ingress_bytes, 12);
    }

    /// Mixed per-hop codecs: int8 downstream sessions are served re-encoded
    /// replies and their pushes decode-accumulate; the upstream hop stays
    /// fp32. Values survive within the quantization error.
    #[test]
    fn downstream_codec_is_independent_of_the_upstream_hop() {
        let (srv, agg) = start_tier(1, 1);
        assert_eq!(agg.upstream_codec(), CodecId::Fp32);
        let mut c = connect(agg.addr());
        c.send(&Message::CodecPropose { pref: CodecId::Int8 }).unwrap();
        match c.recv().unwrap() {
            Message::CodecAgree { codec } => assert_eq!(codec, CodecId::Int8),
            m => panic!("{m:?}"),
        }
        let wc = CodecId::Int8.codec();
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { codec, data, .. } => {
                assert_eq!(codec, CodecId::Int8);
                assert_eq!(data.len(), wc.wire_len(8) + wc.wire_len(4));
                let mut raw = Vec::new();
                wc.decode(&data[..wc.wire_len(8)], &mut raw).unwrap();
                wc.decode(&data[wc.wire_len(8)..], &mut raw).unwrap();
                let vals = slab::to_f32s(&raw);
                assert!((vals[0] - 1.0).abs() < 1e-2, "{vals:?}");
                assert!((vals[1] - 2.0).abs() < 1e-2, "{vals:?}");
                assert!((vals[2] - 10.0).abs() < 1e-1, "{vals:?}");
            }
            m => panic!("{m:?}"),
        }
        // Push an int8 gradient for layer 0; the forwarded combined push
        // is fp32 and the cloud applies w -= 0.5 * [2, 2].
        let mut wire = Vec::new();
        wc.encode(&slab::from_f32s(&[2.0, 2.0]), &mut wire);
        c.send(&Message::Push { iter: 0, lo: 0, hi: 0, codec: CodecId::Int8, data: wire })
            .unwrap();
        assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
        let got = srv.snapshot(0).unwrap();
        assert!((got[0] - 0.0).abs() < 1e-2, "{got:?}");
        assert!((got[1] - 1.0).abs() < 1e-2, "{got:?}");
    }

    /// A group member that disconnects mid-iteration shrinks the fan-in
    /// target: the survivors' accumulated gradients forward instead of
    /// stranding at the aggregator.
    #[test]
    fn departed_group_member_releases_the_fan_in() {
        let (srv, agg) = start_tier(1, 2);
        let mut a = connect(agg.addr());
        let mut b = connect(agg.addr());
        hello(&mut a, 0);
        hello(&mut b, 1);
        // A contributes; the layer waits for B.
        push(&mut a, 0, 0, 0, &[2.0, 0.0]);
        assert_eq!(agg.stats().forwarded_pushes, 0);
        // B departs → target shrinks to 1 → A's gradient forwards, and
        // the single-worker cloud barrier applies it (lr/1).
        drop(b);
        wait_until("survivor's gradient to forward", || agg.stats().forwarded_pushes == 1);
        wait_until("cloud to apply the released push", || {
            srv.snapshot(0).unwrap() == vec![0.0, 2.0]
        });
    }

    /// BSP group members pulling the next iteration park transitively at
    /// the cloud barrier — the aggregator forwards the wait instead of
    /// inventing its own clock.
    #[test]
    fn bsp_pulls_park_transitively_at_the_cloud_barrier() {
        let (_srv, agg) = start_tier(2, 2);
        let addr = agg.addr();
        let t = std::thread::spawn(move || {
            let mut c = connect(addr);
            c.send(&Message::Pull { iter: 1, lo: 0, hi: 1 }).unwrap();
            c.recv().unwrap()
        });
        // The forwarded pull parks at the cloud (version 0 < 1) while the
        // group's iteration-0 pushes complete the barrier.
        let mut a = connect(addr);
        let mut b = connect(addr);
        push(&mut a, 0, 0, 1, &[2.0, 2.0, 2.0]);
        push(&mut b, 0, 0, 1, &[2.0, 2.0, 2.0]);
        match t.join().unwrap() {
            Message::PullReply { applied, data, .. } => {
                assert_eq!(applied, 1);
                // w -= (0.5/2) * [4, 4, 4].
                assert_eq!(slab::to_f32s(&data), vec![0.0, 1.0, 9.0]);
            }
            m => panic!("{m:?}"),
        }
    }

    /// The aggregator refuses to boot against a shard running a different
    /// upstream sync mode — consistency models have no safe fallback.
    #[test]
    fn upstream_sync_mismatch_fails_the_boot() {
        let mut layers = HashMap::new();
        layers.insert(0, vec![1.0f32]);
        let srv =
            ParamServer::start(ServerConfig { workers: 1, lr: 0.5 }, layers, None).unwrap();
        let err = RegionalAggregator::start(AggConfig {
            group: 100,
            workers: 1,
            upstream_addrs: vec![srv.handle().addr],
            layer_elems: vec![1],
            downstream_sync: SyncConfig::default(),
            upstream_sync: SyncConfig::new(SyncMode::Asp, 0).unwrap(),
            upstream_codec: CodecId::Fp32,
            handler_threads: 4,
            io_timeout_ms: 0,
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("sync mode mismatch"), "{err:#}");
    }

    /// A mid-run joiner's `SnapshotReq` at the *aggregator* is forwarded
    /// to the shards on its own anonymous connection, stitched, and
    /// served with the shard clock and the group size — even while the
    /// group's registered pull socket could be parked at the cloud
    /// barrier.
    #[test]
    fn snapshot_req_forwards_through_the_tier() {
        let (srv, agg) = start_tier(1, 1);
        let mut w = connect(agg.addr());
        hello(&mut w, 0);
        w.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        assert!(matches!(w.recv().unwrap(), Message::PullReply { .. }));
        push(&mut w, 0, 0, 1, &[2.0, 2.0, 2.0]);
        wait_until("the combined push to apply upstream", || {
            srv.snapshot(0).unwrap() == vec![0.0, 1.0]
        });
        // The joiner is anonymous: no Hello, no barrier membership.
        let mut joiner = connect(agg.addr());
        joiner.send(&Message::SnapshotReq { lo: 0, hi: 1 }).unwrap();
        match joiner.recv().unwrap() {
            Message::SnapshotReply { iter, lo, hi, workers, codec, data } => {
                assert_eq!((iter, lo, hi, workers), (1, 0, 1, 1));
                assert_eq!(codec, CodecId::Fp32);
                assert_eq!(slab::to_f32s(&data), vec![0.0, 1.0, 9.0]);
            }
            m => panic!("{m:?}"),
        }
        drop(srv);
    }
}
