//! The Parameter-Server framework substrate (Li et al., OSDI'14 — the
//! system Fig. 1 abstracts): sharded parameter storage on "cloud" servers,
//! edge workers pulling parameters / pushing gradients layer-wise over the
//! shaped network, BSP synchronization, and server-side SGD.
//!
//! The DynaComm scheduler plugs in at the worker: pulls and pushes are
//! issued **per decomposition segment**, overlapping with per-layer PJRT
//! compute exactly as the paper's execution model prescribes.
//!
//! The hierarchical tier ([`agg`], `docs/TOPOLOGY.md`) slots a regional
//! aggregator between a group of edge workers and the cloud shards: one
//! combined push and one shared pull per group per shard, with each hop
//! negotiating its own sync policy and wire codec.

pub mod agg;
pub mod checkpoint;
pub mod exec;
pub(crate) mod reply_cache;
pub mod server;
pub mod sharding;
pub mod sync;
pub mod worker;

pub use agg::{AggConfig, AggStats, RegionalAggregator};
pub use checkpoint::{Checkpoint, LayerRecord};
pub use exec::{ExecPlan, ExecSegment, ExecSlice, ExecSub, SlabSlice};
pub use server::{ParamServer, ServerConfig, ServerHandle, ServerOptions, WireStats};
pub use sharding::ShardMap;
pub use sync::{SyncConfig, SyncMode, SyncPolicy};
pub use worker::{EdgeWorker, PlanChange, WorkerConfig, WorkerReport};
