//! The Parameter-Server framework substrate (Li et al., OSDI'14 — the
//! system Fig. 1 abstracts): sharded parameter storage on "cloud" servers,
//! edge workers pulling parameters / pushing gradients layer-wise over the
//! shaped network, BSP synchronization, and server-side SGD.
//!
//! The DynaComm scheduler plugs in at the worker: pulls and pushes are
//! issued **per decomposition segment**, overlapping with per-layer PJRT
//! compute exactly as the paper's execution model prescribes.

pub mod exec;
pub mod server;
pub mod sharding;
pub mod sync;
pub mod worker;

pub use exec::{ExecPlan, ExecSegment, ExecSlice, ExecSub, SlabSlice};
pub use server::{ParamServer, ServerConfig, ServerHandle, ServerOptions, WireStats};
pub use sharding::ShardMap;
pub use sync::{SyncConfig, SyncMode, SyncPolicy};
pub use worker::{EdgeWorker, PlanChange, WorkerConfig, WorkerReport};
