//! The edge worker: executes the paper's iteration
//! `[pt, fc, bc, gt]` with **segmented, overlapped** communication.
//!
//! A puller thread streams parameter segments (per the forward
//! decomposition `D_f`) while the main thread runs per-layer PJRT forward
//! compute; a pusher thread flushes gradient segments (per `D_b`) while the
//! main thread continues backward compute. That is exactly the execution
//! model of Fig. 2(c) / Fig. 3, with the scheduler deciding the segment
//! boundaries at run time from profiled cost vectors (Section IV).
//!
//! Tensor traffic stays in wire form (little-endian byte slabs, see
//! `docs/WIRE.md`) end to end: the puller slices reply slabs into pre-sized
//! per-layer byte buffers, the backward path encodes each layer's gradient
//! slab exactly once, and the pusher extracts per-shard payloads by byte
//! offset — no intermediate `Vec<f32>` allocations anywhere between the
//! socket and the runtime tensors.

use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::Strategy;
use crate::net::{Connection, LinkShaper, Message};
use crate::profiler::Profiler;
use crate::ps::sharding::ShardMap;
use crate::runtime::{RuntimeClient, Tensor};
use crate::sched::{self, Decomposition, SchedulePlan};

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub id: usize,
    pub strategy: Strategy,
    pub artifacts_dir: String,
    pub server_addrs: Vec<std::net::SocketAddr>,
    /// Uplink shaper (worker → cloud); cloned per connection so all of this
    /// worker's traffic serializes on one emulated link.
    pub shaper: Option<LinkShaper>,
    /// Profiling switch (Table II measures its cost).
    pub profiling: bool,
    /// Re-run the scheduler every this many iterations ("once per epoch",
    /// Section IV-C).
    pub reschedule_every: usize,
}

/// Per-run observability, returned to the trainer.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    pub iter_ms: Vec<f64>,
    pub losses: Vec<f32>,
    pub batch_top1: Vec<f64>,
    /// Scheduler wall-clock per re-plan, ms (Table I).
    pub sched_ms: Vec<f64>,
    /// (iteration, fwd segments, bwd segments) whenever the plan changed.
    pub plans: Vec<(u64, usize, usize)>,
}

/// One edge device, connected to every shard.
pub struct EdgeWorker {
    cfg: WorkerConfig,
    pub runtime: RuntimeClient,
    conns: Vec<Connection>,
    shard: ShardMap,
    pub profiler: Profiler,
    plan: SchedulePlan,
}

/// Bounded retry-with-backoff for the worker→shard TCP connect: workers
/// and servers boot concurrently, so a worker may dial a shard whose
/// accept loop is not listening yet. Exponential backoff from 1 ms,
/// capped at 100 ms per attempt and ~5 s overall.
fn connect_with_retry(addr: &std::net::SocketAddr) -> Result<TcpStream> {
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut backoff = Duration::from_millis(1);
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    return Err(e).with_context(|| {
                        format!("connecting to shard {addr} (retries exhausted)")
                    });
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(100));
            }
        }
    }
}

impl EdgeWorker {
    /// Load the runtime, connect to all shards (with bounded retry — the
    /// server accept loop may still be coming up), register.
    pub fn connect(cfg: WorkerConfig) -> Result<EdgeWorker> {
        let runtime = RuntimeClient::load(&cfg.artifacts_dir)?;
        let depth = runtime.manifest.depth();
        let shard = ShardMap::new(cfg.server_addrs.len(), depth);
        let mut conns = Vec::with_capacity(cfg.server_addrs.len());
        for addr in &cfg.server_addrs {
            let stream = connect_with_retry(addr)?;
            let mut conn = Connection::new(stream, cfg.shaper.clone());
            conn.send(&Message::Hello { worker: cfg.id as u32 })?;
            match conn.recv()? {
                Message::HelloAck { .. } => {}
                m => anyhow::bail!("bad hello ack: {m:?}"),
            }
            conns.push(conn);
        }
        let layer_bytes: Vec<usize> =
            runtime.manifest.layers.iter().map(|l| l.param_bytes()).collect();
        let mut profiler = Profiler::new(layer_bytes);
        profiler.enabled = cfg.profiling;
        // Bootstrap plan: LBL gives size-diverse per-layer transfer samples
        // for the profiler's Δt/rate fit; fixed strategies start as
        // themselves.
        let boot = match cfg.strategy {
            Strategy::Sequential => Decomposition::sequential(depth),
            _ => Decomposition::layer_by_layer(depth),
        };
        let plan = SchedulePlan { fwd: boot.clone(), bwd: boot };
        Ok(EdgeWorker { cfg, runtime, conns, shard, profiler, plan })
    }

    pub fn depth(&self) -> usize {
        self.runtime.manifest.depth()
    }

    pub fn plan(&self) -> &SchedulePlan {
        &self.plan
    }

    /// Flat `w‖b` slab size of a layer, in bytes.
    fn layer_bytes(&self, l: usize) -> usize {
        let a = &self.runtime.manifest.layers[l];
        4 * (a.w_count() + a.b_count())
    }

    /// Re-run the scheduler from the latest profile; returns scheduling
    /// wall-clock in ms, or None if the profiler has no signal yet.
    pub fn reschedule(&mut self) -> Option<f64> {
        let cv = self.profiler.cost_vectors()?;
        let t0 = Instant::now();
        let plan = sched::plan_for(self.cfg.strategy, &cv);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        self.plan = plan;
        Some(ms)
    }

    /// Run `iters` iterations, fetching batches from `next_batch`.
    pub fn run(
        &mut self,
        iters: u64,
        mut next_batch: impl FnMut(u64) -> (Tensor, Tensor),
    ) -> Result<WorkerReport> {
        let mut report = WorkerReport::default();
        for i in 0..iters {
            if i > 0 && (i as usize) % self.cfg.reschedule_every == 0 {
                if let Some(ms) = self.reschedule() {
                    report.sched_ms.push(ms);
                    report.plans.push((
                        i,
                        self.plan.fwd.num_transmissions(),
                        self.plan.bwd.num_transmissions(),
                    ));
                }
            }
            let (x, onehot) = next_batch(i);
            let t0 = Instant::now();
            let (loss, top1) = self.iteration(i, &x, &onehot)?;
            report.iter_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            report.losses.push(loss);
            report.batch_top1.push(top1);
        }
        Ok(report)
    }

    /// One BSP iteration: segmented pulls + layer-wise fwd, loss,
    /// layer-wise bwd + segmented pushes.
    pub fn iteration(&mut self, iter: u64, x: &Tensor, onehot: &Tensor) -> Result<(f32, f64)> {
        let depth = self.depth();
        let fwd_segs: Vec<(usize, usize)> = self
            .plan
            .fwd
            .fwd_segments()
            .iter()
            .map(|&(a, b)| (a - 1, b - 1)) // to 0-based
            .collect();
        let bwd_segs: Vec<(usize, usize)> = self
            .plan
            .bwd
            .bwd_segments()
            .iter()
            .map(|&(hi, lo)| (hi - 1, lo - 1))
            .collect();

        // Byte sizes and prefix offsets of the per-layer slabs: slicing a
        // segment blob is pure offset arithmetic.
        let layer_bytes: Vec<usize> = (0..depth).map(|l| self.layer_bytes(l)).collect();
        let mut byte_off = Vec::with_capacity(depth + 1);
        byte_off.push(0usize);
        for l in 0..depth {
            byte_off.push(byte_off[l] + layer_bytes[l]);
        }

        // ---- Forward: puller thread streams segments; main computes. ----
        let (param_tx, param_rx) = mpsc::channel::<(usize, Vec<u8>)>();
        let (stat_tx, stat_rx) = mpsc::channel::<(usize, f64)>();
        let mut puller_conns = Vec::new();
        for c in &self.conns {
            puller_conns.push(c.try_clone()?);
        }
        let shard = self.shard;
        let layer_bytes_puller = layer_bytes.clone();
        let segs = fwd_segs.clone();
        let puller = std::thread::Builder::new()
            .name(format!("puller-{}", self.cfg.id))
            .spawn(move || -> Result<()> {
                for (lo, hi) in segs {
                    let t0 = Instant::now();
                    let mut per_layer: Vec<Option<Vec<u8>>> = vec![None; hi - lo + 1];
                    for sub in shard.sub_requests(lo, hi) {
                        puller_conns[sub.server].send(&Message::Pull {
                            iter,
                            lo: lo as u32,
                            hi: hi as u32,
                        })?;
                        let data = match puller_conns[sub.server].recv()? {
                            Message::PullReply { data, .. } => data,
                            m => anyhow::bail!("bad pull reply: {m:?}"),
                        };
                        // The reply concatenates this shard's owned layers
                        // ascending; slice it into per-layer slabs.
                        let mut off = 0;
                        for l in sub.layers() {
                            let n = layer_bytes_puller[l];
                            anyhow::ensure!(off + n <= data.len(), "short pull reply");
                            per_layer[l - lo] = Some(data[off..off + n].to_vec());
                            off += n;
                        }
                    }
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let bytes: usize = (lo..=hi).map(|l| layer_bytes_puller[l]).sum();
                    let _ = stat_tx.send((bytes, ms));
                    for (off, p) in per_layer.into_iter().enumerate() {
                        let p = p.context("server returned no data for layer")?;
                        let _ = param_tx.send((lo + off, p));
                    }
                }
                Ok(())
            })?;

        let mut acts: Vec<Tensor> = Vec::with_capacity(depth + 1);
        acts.push(x.clone());
        let mut params: Vec<Option<(Tensor, Tensor)>> = vec![None; depth];
        for l in 0..depth {
            while params[l].is_none() {
                let (got, flat) = param_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("puller died before layer {l}"))?;
                params[got] = Some(self.split_params(got, &flat)?);
            }
            let (w, b) = params[l].as_ref().unwrap();
            let t0 = Instant::now();
            let y = self.runtime.layer_fwd(l, w, b, &acts[l])?;
            self.profiler.record_fwd(l, t0.elapsed().as_secs_f64() * 1e3);
            acts.push(y);
        }
        puller
            .join()
            .map_err(|_| anyhow::anyhow!("puller panicked"))?
            .context("puller failed")?;
        while let Ok((bytes, ms)) = stat_rx.try_recv() {
            self.profiler.record_pull(bytes, ms);
        }

        // ---- Loss head. ----
        let logits = &acts[depth];
        let (loss, glogits) = self.runtime.loss(logits, onehot)?;
        let top1 = batch_top1(logits, onehot);

        // ---- Backward: main computes; pusher thread flushes segments. ----
        let (grad_tx, grad_rx) = mpsc::channel::<(usize, usize, Vec<u8>)>();
        let mut pusher_conns = Vec::new();
        for c in &self.conns {
            pusher_conns.push(c.try_clone()?);
        }
        let layer_bytes_pusher = layer_bytes.clone();
        let byte_off_pusher = byte_off.clone();
        let pusher = std::thread::Builder::new()
            .name(format!("pusher-{}", self.cfg.id))
            .spawn(move || -> Result<Vec<(usize, f64)>> {
                let mut stats = Vec::new();
                // Receives one message per completed segment: (lo, hi, slab
                // of layers lo..=hi ascending).
                while let Ok((lo, hi, data)) = grad_rx.recv() {
                    let t0 = Instant::now();
                    for sub in shard.sub_requests(lo, hi) {
                        // Extract this shard's layers from the segment
                        // slab: pre-sized buffer, bulk byte copies indexed
                        // by the prefix offsets.
                        let nbytes: usize =
                            sub.layers().map(|l| layer_bytes_pusher[l]).sum();
                        let mut payload = Vec::with_capacity(nbytes);
                        for l in sub.layers() {
                            let off = byte_off_pusher[l] - byte_off_pusher[lo];
                            payload.extend_from_slice(
                                &data[off..off + layer_bytes_pusher[l]],
                            );
                        }
                        pusher_conns[sub.server].send(&Message::Push {
                            iter,
                            lo: lo as u32,
                            hi: hi as u32,
                            data: payload,
                        })?;
                        match pusher_conns[sub.server].recv()? {
                            Message::PushAck { .. } => {}
                            m => anyhow::bail!("bad push ack: {m:?}"),
                        }
                    }
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let bytes: usize = (lo..=hi).map(|l| layer_bytes_pusher[l]).sum();
                    stats.push((bytes, ms));
                }
                Ok(stats)
            })?;

        let mut gy = glogits;
        let mut pending: Vec<Option<Vec<u8>>> = vec![None; depth];
        let mut seg_iter = bwd_segs.iter();
        let mut cur_seg = seg_iter.next().copied();
        for l in (0..depth).rev() {
            let (w, b) = params[l].as_ref().unwrap();
            let t0 = Instant::now();
            let gy_shaped = reshape_like_output(&gy, &self.runtime, l);
            let (gw, gb, gx) = self.runtime.layer_bwd(l, w, b, &acts[l], &gy_shaped)?;
            self.profiler.record_bwd(l, t0.elapsed().as_secs_f64() * 1e3);
            // Encode the layer's gradient slab once, pre-sized.
            let mut flat = Vec::with_capacity(layer_bytes[l]);
            gw.extend_le_bytes(&mut flat);
            gb.extend_le_bytes(&mut flat);
            pending[l] = Some(flat);
            gy = gx;
            // Segment complete once we've computed down to its low layer.
            if let Some((hi, lo)) = cur_seg {
                if l == lo {
                    let mut blob =
                        Vec::with_capacity(byte_off[hi + 1] - byte_off[lo]);
                    for ll in lo..=hi {
                        blob.extend_from_slice(pending[ll].as_ref().unwrap());
                    }
                    grad_tx
                        .send((lo, hi, blob))
                        .map_err(|_| anyhow::anyhow!("pusher died"))?;
                    cur_seg = seg_iter.next().copied();
                }
            }
        }
        drop(grad_tx);
        let stats = pusher
            .join()
            .map_err(|_| anyhow::anyhow!("pusher panicked"))?
            .context("pusher failed")?;
        for (bytes, ms) in stats {
            self.profiler.record_push(bytes, ms);
        }
        Ok((loss, top1))
    }

    /// Pull the parameters as of `iter` (blocks until the BSP clock gets
    /// there) — used for evaluation snapshots.
    pub fn pull_params(&mut self, iter: u64) -> Result<Vec<(Tensor, Tensor)>> {
        let depth = self.depth();
        let mut out = Vec::with_capacity(depth);
        let mut flats: Vec<Option<Vec<u8>>> = vec![None; depth];
        for srv in 0..self.shard.servers {
            self.conns[srv].send(&Message::Pull { iter, lo: 0, hi: depth as u32 - 1 })?;
            let data = match self.conns[srv].recv()? {
                Message::PullReply { data, .. } => data,
                m => anyhow::bail!("bad pull reply: {m:?}"),
            };
            let mut off = 0;
            for l in self.shard.owned_by(srv) {
                let n = self.layer_bytes(l);
                anyhow::ensure!(off + n <= data.len(), "short pull reply");
                flats[l] = Some(data[off..off + n].to_vec());
                off += n;
            }
        }
        for (l, f) in flats.into_iter().enumerate() {
            out.push(self.split_params(l, &f.context("missing layer")?)?);
        }
        Ok(out)
    }

    /// Split a layer's `w‖b` byte slab into its weight and bias tensors —
    /// the only f32 materialization on the pull path, directly into the
    /// final buffers.
    fn split_params(&self, l: usize, flat: &[u8]) -> Result<(Tensor, Tensor)> {
        let a = &self.runtime.manifest.layers[l];
        let wb = 4 * a.w_count();
        anyhow::ensure!(
            flat.len() == wb + 4 * a.b_count(),
            "layer {l}: got {} param bytes, want {}",
            flat.len(),
            wb + 4 * a.b_count()
        );
        let w = Tensor::from_le_bytes(a.w_shape.clone(), &flat[..wb])?;
        let b = Tensor::from_le_bytes(a.b_shape.clone(), &flat[wb..])?;
        Ok((w, b))
    }
}

/// The gradient flowing back from layer `l+1` arrives with that layer's
/// input shape; relabel it to layer `l`'s output shape (same element
/// count — flatten boundaries differ between fc and conv layers).
fn reshape_like_output(gy: &Tensor, runtime: &RuntimeClient, l: usize) -> Tensor {
    let a = &runtime.manifest.layers[l];
    let mut shape = vec![runtime.manifest.batch];
    shape.extend(&a.out_shape);
    Tensor::new(shape, gy.data.clone())
}

/// Fraction of rows whose argmax matches the one-hot label.
pub fn batch_top1(logits: &Tensor, onehot: &Tensor) -> f64 {
    let classes = *logits.shape.last().unwrap();
    let rows = logits.len() / classes;
    let mut hits = 0;
    for r in 0..rows {
        let row = &logits.data[r * classes..(r + 1) * classes];
        let pred = argmax(row);
        let label = argmax(&onehot.data[r * classes..(r + 1) * classes]);
        if pred == label {
            hits += 1;
        }
    }
    hits as f64 / rows as f64
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_top1() {
        let logits = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 0.5, 0.2, 0.3]);
        let onehot = Tensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        // Row 0 correct (argmax 1), row 1 wrong (argmax 0, label 2).
        assert!((batch_top1(&logits, &onehot) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax(&[1.0, 1.0, 0.5]), 0);
    }

    #[test]
    fn connect_retries_until_listener_appears() {
        // Reserve a port, drop the listener (connects now fail), and only
        // bring the real listener up after a delay: the retry loop must
        // bridge the gap — this is the worker/server startup race.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            std::net::TcpListener::bind(addr)
                .ok()
                .and_then(|l| l.accept().ok())
        });
        let stream = connect_with_retry(&addr);
        let accepted = t.join().unwrap();
        // The rebind can race another process grabbing the port; only
        // assert when the listener actually came back.
        if accepted.is_some() {
            assert!(stream.is_ok(), "retry failed: {:?}", stream.err());
        }
    }

    #[test]
    fn connect_retry_gives_up_eventually() {
        // A port with nothing listening: bounded retry must return an
        // error rather than spin forever.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let t0 = Instant::now();
        let r = connect_with_retry(&addr);
        // Either some other process reused the port (fine), or we erred
        // out within the deadline window.
        if let Err(e) = r {
            assert!(t0.elapsed() < Duration::from_secs(30), "unbounded retry");
            assert!(format!("{e:#}").contains("retries exhausted"), "{e:#}");
        }
    }
}
