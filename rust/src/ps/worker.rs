//! The edge worker: executes the paper's iteration
//! `[pt, fc, bc, gt]` with **segmented, overlapped** communication.
//!
//! A puller thread streams parameter segments (per the forward
//! decomposition `D_f`) while the main thread runs per-layer PJRT forward
//! compute; a pusher thread flushes gradient segments (per `D_b`) while the
//! main thread continues backward compute. That is exactly the execution
//! model of Fig. 2(c) / Fig. 3, with a pluggable [`Scheduler`] deciding the
//! segment boundaries at run time from profiled cost vectors (Section IV).
//!
//! Schedules are consumed in **compiled** form: every re-plan is resolved
//! once into an [`ExecPlan`] (0-based segments, prefix byte offsets,
//! per-segment shard sub-requests), so `iteration` performs no segment or
//! offset arithmetic of its own. Tensor traffic stays in wire form
//! (little-endian byte slabs, see `docs/WIRE.md`) end to end, through
//! pooled buffers (`docs/PERF.md`): the puller receives each shard reply
//! straight into a pool checkout and hands each layer a [`SlabSlice`] view
//! of it (no copies between the socket and tensor materialization), the
//! backward path encodes each layer's gradient exactly once into a pooled
//! slab pre-sized from the plan's byte tables, and the pusher sends each
//! shard's payload gather-style (`send_push_parts`) straight from those
//! per-layer slabs — no segment blob, no payload assembly, no steady-state
//! slab allocations. Under a negotiated compressing codec (`net::codec`,
//! protocol v3) the same tables carry wire sizes: pulled replies decode
//! into pooled scratch, gradients are quantized into pooled wire slabs,
//! and the profiler is fed *wire* bytes so re-planning sees compressed
//! transfer costs.

use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::Strategy;
use crate::net::codec::ef::ErrorFeedback;
use crate::net::codec::{CodecId, CodecStats, CodecStatsTable};
use crate::net::pool::{SlabCheckout, SlabPool};
use crate::net::{Connection, LinkShaper, Message, RecvMsg, TraceCtx, PROTOCOL_VERSION};
use crate::profiler::Profiler;
use crate::ps::exec::{ExecPlan, SegmentPull, SlabSlice};
use crate::ps::sharding::ShardMap;
use crate::ps::sync::{SyncConfig, SyncMode};
use crate::runtime::{RuntimeClient, Tensor};
use crate::util::rng::Rng;
use crate::sched::registry::{self, SchedulerParams};
use crate::sched::{Decomposition, SchedulePlan, Scheduler};

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub id: usize,
    pub strategy: Strategy,
    pub artifacts_dir: String,
    pub server_addrs: Vec<std::net::SocketAddr>,
    /// Uplink shaper (worker → cloud); cloned per connection so all of this
    /// worker's traffic serializes on one emulated link.
    pub shaper: Option<LinkShaper>,
    /// Profiling switch (Table II measures its cost).
    pub profiling: bool,
    /// Re-run the scheduler every this many iterations ("once per epoch",
    /// Section IV-C). Also the amortization horizon the AUTO gain
    /// threshold uses.
    pub reschedule_every: usize,
    /// Gain threshold for DynaComm's cached re-planning, ms: skip the
    /// O(L^3) DP when a fresh plan cannot gain more than this. `0.0`
    /// re-plans every time; **negative selects AUTO**, deriving the
    /// threshold from the measured DP wall-clock vs the iteration's comm
    /// idle window (see `sched::dynacomm::DynaCommScheduler`).
    pub gain_threshold_ms: f64,
    /// Preferred wire codec (`net::codec`): proposed to every shard at
    /// registration; the session falls back to fp32 unless all shards
    /// agree, so mixed fleets keep training.
    pub codec: CodecId,
    /// The synchronization mode this worker expects its shards to run
    /// (`ps::sync`, `--sync`). Proposed to every shard at registration;
    /// unlike codecs there is no safe fallback between consistency
    /// models, so a disagreeing shard fails the connect loudly.
    pub sync: SyncMode,
    /// Expected SSP staleness bound (`--staleness-bound`); the server's
    /// answer is authoritative and adopted for the client-side check.
    pub staleness_bound: u32,
    /// EF-SGD error feedback (`net::codec::ef`): under a lossy codec,
    /// carry each layer's quantization error into the next iteration's
    /// gradient instead of dropping it. On by default; no-op under fp32.
    pub error_feedback: bool,
    /// Pull/push I/O deadline, ms (`--io-timeout-ms`); 0 disables. With a
    /// deadline armed, a shard that dies mid-reply fails the worker's
    /// recv within the window instead of blocking forever — the hook
    /// [`EdgeWorker::reconnect_shard`] recovers from (`docs/FAULTS.md`).
    /// Leave 0 under BSP unless the deadline comfortably exceeds the
    /// slowest straggler: barrier waits are served through the same
    /// sockets.
    pub io_timeout_ms: u64,
    /// Re-probe the per-shard clock offsets every this many iterations
    /// (`--clock-probe-every`; 0 disables periodic probing). A burst
    /// always runs at connect, so the merged fleet trace has an offset
    /// for every peer lane (`docs/OBSERVABILITY.md`); periodic re-probes
    /// track drift on long runs.
    pub clock_probe_every: usize,
}

/// Per-run observability, returned to the trainer.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    pub iter_ms: Vec<f64>,
    pub losses: Vec<f32>,
    pub batch_top1: Vec<f64>,
    /// Scheduler wall-clock per re-plan call, ms (Table I) — reused calls
    /// included, which is where gain-thresholding shows its savings.
    pub sched_ms: Vec<f64>,
    /// The scheduler's own predicted iteration finish time per re-plan
    /// call, ms (aligned with `sched_ms`); compare against the measured
    /// `iter_ms` to judge the cost model.
    pub sched_predicted_ms: Vec<f64>,
    /// One entry per plan change (re-plan calls that reused the cache do
    /// not appear here — they are counted in `sched_reused`).
    pub plans: Vec<PlanChange>,
    /// Re-plan calls answered from the scheduler's cache (predicted gain
    /// under the threshold): the expensive decision procedure ran only
    /// `sched_ms.len() - sched_reused` times.
    pub sched_reused: usize,
    /// Max staleness observed per iteration (`iter − applied`, in
    /// iterations, over the iteration's pull segments): identically 0
    /// under BSP, bounded by `--staleness-bound` under SSP, and the
    /// measured consistency cost under ASP.
    pub staleness: Vec<u64>,
    /// Obs-registry snapshot taken at the end of the run (series name with
    /// labels → value; histograms expand to `_count` / `_sum` rows): the
    /// same numbers a `--metrics-addr` scrape reports, embedded so the
    /// trainer and bench JSON carry them without a listener.
    pub metrics: Vec<(String, f64)>,
}

/// One recorded plan change, carrying the wall-clock of the re-plan call
/// that actually produced it (so reporting cannot mis-attribute a cheap
/// cached-reuse call's time to the call that ran the DP).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanChange {
    pub iter: u64,
    pub fwd_segments: usize,
    pub bwd_segments: usize,
    /// Scheduler wall-clock of this specific re-plan, ms.
    pub sched_ms: f64,
}

/// Outcome of one [`EdgeWorker::reschedule`] call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reschedule {
    /// Scheduler wall-clock, ms.
    pub sched_ms: f64,
    /// The cached plan was reused; no new `ExecPlan` was compiled.
    pub reused: bool,
    /// A fresh plan actually *differed* from the current one (a fresh
    /// re-plan can reproduce the same decomposition on a stable profile —
    /// that is not a plan change).
    pub changed: bool,
    /// The scheduler's own predicted iteration finish time, ms.
    pub predicted_ms: f64,
}

/// One edge device, connected to every shard.
pub struct EdgeWorker {
    cfg: WorkerConfig,
    pub runtime: RuntimeClient,
    conns: Vec<Connection>,
    shard: ShardMap,
    pub profiler: Profiler,
    scheduler: Box<dyn Scheduler>,
    plan: SchedulePlan,
    /// The current plan compiled against the model + shard map (it also
    /// owns the per-layer byte-size tables and the slab pool); shared with
    /// the puller/pusher threads, rebuilt only when the plan changes.
    exec: Arc<ExecPlan>,
    /// The worker's slab pool: reply frames, gradient slabs, and codec
    /// decode scratch recycle through it across iterations *and* re-plans.
    pool: Arc<SlabPool>,
    /// The wire codec every shard agreed to for this session.
    codec: CodecId,
    /// Worker-side per-codec counters (gradient encodes, reply decodes).
    codec_stats: Arc<CodecStatsTable>,
    /// The synchronization mode every shard confirmed at registration.
    sync: SyncMode,
    /// The servers' authoritative SSP staleness bound (0 outside SSP);
    /// replies are checked against it client-side.
    staleness_bound: u32,
    /// EF-SGD residuals, kept iff `error_feedback` and the codec is lossy.
    ef: Option<ErrorFeedback>,
    /// Max staleness the latest iteration observed (see
    /// [`WorkerReport::staleness`]).
    last_staleness: u64,
    /// The latest re-plan's predicted (fwd, bwd) pass finish times, ms —
    /// the overlap audit's baseline (`dynacomm_overlap_drift_ms`,
    /// docs/OBSERVABILITY.md).
    last_predicted: Option<(f64, f64)>,
    /// Worker-side obs-registry instruments.
    obs: WorkerObs,
}

/// Worker-side obs-registry instruments (docs/OBSERVABILITY.md),
/// registered once per worker (each instance carries its own `inst`
/// label).
struct WorkerObs {
    iterations: crate::obs::Counter,
    iter_ms: crate::obs::Histogram,
    staleness: crate::obs::Histogram,
}

impl WorkerObs {
    fn new() -> WorkerObs {
        let inst = crate::obs::next_inst();
        WorkerObs {
            iterations: crate::obs_counter!("dynacomm_worker_iterations_total", "", inst),
            iter_ms: crate::obs_histogram!("dynacomm_worker_iter_ms", "", inst),
            staleness: crate::obs_histogram!("dynacomm_sync_staleness", "", inst),
        }
    }
}

/// Record one overlap-audit sample: the absolute drift (ms) between a
/// re-plan's predicted pass finish time and the measured span timeline,
/// as the `dynacomm_overlap_drift_ms` histogram (`pass="fwd"` /
/// `pass="bwd"`). Public so harnesses without a PJRT runtime (the obs
/// e2e test) can feed the audit exactly the way [`EdgeWorker::run`] does.
pub fn record_overlap_drift(fwd_pass: bool, predicted_ms: f64, measured_ms: f64) {
    static CELL: std::sync::OnceLock<[crate::obs::Histogram; 2]> = std::sync::OnceLock::new();
    let hists = CELL.get_or_init(|| {
        let inst = crate::obs::next_inst();
        let h = |pass: &str| {
            crate::obs_histogram!("dynacomm_overlap_drift_ms", format!("pass=\"{pass}\""), inst)
        };
        [h("fwd"), h("bwd")]
    });
    hists[if fwd_pass { 0 } else { 1 }].observe((predicted_ms - measured_ms).abs());
}

/// Propose a session codec on one shard connection; returns what the
/// server agreed to (its fallback is always fp32). Shared with the
/// regional aggregator's upstream sessions (`ps::agg`).
pub(crate) fn propose_codec(conn: &mut Connection, pref: CodecId) -> Result<CodecId> {
    conn.send(&Message::CodecPropose { pref })?;
    match conn.recv()? {
        Message::CodecAgree { codec } => Ok(codec),
        m => anyhow::bail!("bad codec agreement: {m:?}"),
    }
}

/// Announce the worker's expected sync configuration to one shard; the
/// server answers with its own, which must match the expected mode — two
/// consistency models cannot train one job, so a mismatch is a loud
/// connect failure, not a fallback. Returns the server's authoritative
/// staleness bound. Shared with the regional aggregator's upstream
/// sessions (`ps::agg`).
pub(crate) fn propose_sync(conn: &mut Connection, mode: SyncMode, bound: u32) -> Result<u32> {
    conn.send(&Message::SyncPropose { mode, bound })?;
    match conn.recv()? {
        Message::SyncAgree { mode: got, bound } => {
            anyhow::ensure!(
                got == mode,
                "sync mode mismatch: worker configured for {}, shard runs {}",
                mode.name(),
                got.name()
            );
            Ok(bound)
        }
        m => anyhow::bail!("bad sync agreement: {m:?}"),
    }
}

/// `--io-timeout-ms` to the transport's form: 0 means "no deadline".
pub(crate) fn io_timeout_of(ms: u64) -> Option<Duration> {
    (ms > 0).then(|| Duration::from_millis(ms))
}

/// Deterministic, bounded jitter for one retry-backoff step: uniform in
/// `[0, backoff]`, drawn from a PRNG seeded by `(seed, attempt)` alone —
/// the same dialer replays the same schedule (the fault-injection harness
/// relies on this), while differently-seeded dialers decorrelate instead
/// of thundering back in lockstep after a shard restart.
pub(crate) fn retry_jitter(seed: u64, attempt: u32, backoff: Duration) -> Duration {
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ attempt as u64);
    Duration::from_nanos(rng.below(backoff.as_nanos() as usize + 1) as u64)
}

/// Bounded retry-with-backoff for the worker→shard TCP connect: workers
/// and servers boot concurrently (and shards restart mid-run), so a
/// dialer may hit a shard whose accept loop is not listening yet.
/// Exponential backoff from 1 ms, capped at 100 ms per attempt and ~5 s
/// overall, each step stretched by the caller-seeded [`retry_jitter`].
/// Shared with the regional aggregator's upstream sessions (`ps::agg`).
pub(crate) fn connect_with_retry(
    addr: &std::net::SocketAddr,
    jitter_seed: u64,
) -> Result<TcpStream> {
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut backoff = Duration::from_millis(1);
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    return Err(e).with_context(|| {
                        format!("connecting to shard {addr} (retries exhausted)")
                    });
                }
                std::thread::sleep(backoff + retry_jitter(jitter_seed, attempt, backoff));
                attempt += 1;
                backoff = (backoff * 2).min(Duration::from_millis(100));
            }
        }
    }
}

/// (Re-)establish one registered shard session end to end: jittered
/// bounded-retry dial, `Hello` + protocol-version check both ways, sync
/// agreement against the session's authoritative bound, codec
/// re-negotiation (the shard must agree — a reconnect cannot fall back to
/// fp32, the worker's compiled byte tables are fixed), and the optional
/// pull/push I/O deadline. The mid-run recovery path of
/// [`EdgeWorker::reconnect_shard`] and the churn harness
/// (`tests/churn_integration.rs`).
pub(crate) fn establish_session(
    addr: &std::net::SocketAddr,
    worker: u32,
    sync: SyncConfig,
    codec: CodecId,
    shaper: Option<LinkShaper>,
    io_timeout: Option<Duration>,
) -> Result<Connection> {
    let stream = connect_with_retry(addr, worker as u64)?;
    let mut conn = Connection::new(stream, shaper);
    conn.set_io_timeout(io_timeout)?;
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION })?;
    match conn.recv()? {
        Message::HelloAck { version, .. } if version == PROTOCOL_VERSION => {}
        Message::HelloAck { version, .. } => anyhow::bail!(
            "protocol version mismatch with shard {addr}: \
             worker speaks v{PROTOCOL_VERSION}, server v{version}"
        ),
        m => anyhow::bail!("bad hello ack: {m:?}"),
    }
    let got = propose_sync(&mut conn, sync.mode, sync.staleness_bound)?;
    anyhow::ensure!(
        got == sync.staleness_bound,
        "shard {addr} answered staleness bound {got}, session runs {}",
        sync.staleness_bound
    );
    if codec != CodecId::Fp32 {
        anyhow::ensure!(
            propose_codec(&mut conn, codec)? == codec,
            "shard {addr} refused codec {} on reconnect",
            codec.name()
        );
    }
    Ok(conn)
}

impl EdgeWorker {
    /// Load the runtime, connect to all shards (with bounded retry — the
    /// server accept loop may still be coming up), register and check the
    /// protocol version both ways.
    pub fn connect(cfg: WorkerConfig) -> Result<EdgeWorker> {
        let runtime = RuntimeClient::load(&cfg.artifacts_dir)?;
        let depth = runtime.manifest.depth();
        let shard = ShardMap::new(cfg.server_addrs.len(), depth);
        let mut conns = Vec::with_capacity(cfg.server_addrs.len());
        for addr in &cfg.server_addrs {
            let stream = connect_with_retry(addr, cfg.id as u64)?;
            let mut conn = Connection::new(stream, cfg.shaper.clone());
            conn.set_io_timeout(io_timeout_of(cfg.io_timeout_ms))?;
            conn.send(&Message::Hello {
                worker: cfg.id as u32,
                version: PROTOCOL_VERSION,
            })?;
            match conn.recv()? {
                Message::HelloAck { version, .. } if version == PROTOCOL_VERSION => {}
                Message::HelloAck { version, .. } => anyhow::bail!(
                    "protocol version mismatch with shard {addr}: \
                     worker speaks v{PROTOCOL_VERSION}, server v{version}"
                ),
                m => anyhow::bail!("bad hello ack: {m:?}"),
            }
            conns.push(conn);
        }
        // Announce the expected sync configuration to every shard (the
        // flags configure workers and servers from the same source, so a
        // mismatch is a deployment bug worth failing loudly); the shards'
        // answer fixes the staleness bound the replies are checked
        // against — every shard must agree on it, or the single
        // client-side bound check would be wrong for all but one of them.
        // Validated first so a bogus bound never hits the wire.
        let sync_cfg =
            crate::ps::sync::SyncConfig::new(cfg.sync, cfg.staleness_bound)?;
        let mut staleness_bound = sync_cfg.staleness_bound;
        for (i, conn) in conns.iter_mut().enumerate() {
            let got = propose_sync(conn, sync_cfg.mode, sync_cfg.staleness_bound)?;
            anyhow::ensure!(
                i == 0 || got == staleness_bound,
                "staleness bound disagreement across shards: {} vs {}",
                staleness_bound,
                got
            );
            staleness_bound = got;
        }
        // Negotiate the session's wire codec with every shard: all must
        // agree on the preference, otherwise the whole worker unifies on
        // the fp32 fallback (a split-codec worker would need per-shard
        // byte tables for no benefit).
        let mut codec = cfg.codec;
        if codec != CodecId::Fp32 {
            for conn in conns.iter_mut() {
                if propose_codec(conn, codec)? != codec {
                    codec = CodecId::Fp32;
                    break;
                }
            }
            if codec == CodecId::Fp32 {
                for conn in conns.iter_mut() {
                    let agreed = propose_codec(conn, CodecId::Fp32)?;
                    anyhow::ensure!(
                        agreed == CodecId::Fp32,
                        "shard refused the mandatory fp32 fallback"
                    );
                }
            }
        }
        let layer_bytes: Vec<usize> =
            runtime.manifest.layers.iter().map(|l| l.param_bytes()).collect();
        // The profiler models *transmissions*, so it is fed wire sizes:
        // its fitted rate is per wire byte and the reconstructed pt/gt are
        // codec-aware — exactly what the DP scheduler should re-segment
        // against when compression shrinks transfers.
        let wire_layer_bytes: Vec<usize> =
            layer_bytes.iter().map(|&b| codec.wire_len(b)).collect();
        let mut profiler = Profiler::new(wire_layer_bytes);
        profiler.enabled = cfg.profiling;
        let scheduler = registry::create_for_with(
            cfg.strategy,
            SchedulerParams {
                gain_threshold_ms: cfg.gain_threshold_ms,
                replan_horizon_iters: cfg.reschedule_every.max(1),
            },
        );
        // Bootstrap plan: LBL gives size-diverse per-layer transfer samples
        // for the profiler's Δt/rate fit; fixed strategies start as
        // themselves.
        let boot = match cfg.strategy {
            Strategy::Sequential => Decomposition::sequential(depth),
            _ => Decomposition::layer_by_layer(depth),
        };
        let plan = SchedulePlan { fwd: boot.clone(), bwd: boot };
        // The backward pass holds one gradient slab per layer (plus reply
        // frames in flight), so the retention bound must scale with depth
        // or wide-segment plans would re-allocate most slabs every
        // iteration and silently void the zero-allocation contract.
        let pool = SlabPool::with_max_retained(depth + 16);
        // EF-SGD residuals: only worth carrying under a lossy codec (the
        // identity codec's error is identically zero).
        let ef = if cfg.error_feedback && codec != CodecId::Fp32 {
            let elems: Vec<usize> = layer_bytes.iter().map(|b| b / 4).collect();
            Some(ErrorFeedback::new(&elems))
        } else {
            None
        };
        let exec =
            Arc::new(ExecPlan::compile(&plan, &layer_bytes, shard, pool.clone(), codec));
        let mut worker = EdgeWorker {
            cfg,
            runtime,
            conns,
            shard,
            profiler,
            scheduler,
            plan,
            exec,
            pool,
            codec,
            codec_stats: Arc::new(CodecStatsTable::new()),
            sync: sync_cfg.mode,
            staleness_bound,
            ef,
            last_staleness: 0,
            last_predicted: None,
            obs: WorkerObs::new(),
        };
        // Align clocks with every peer at establish (docs/OBSERVABILITY.md):
        // a short burst, keeping the minimum-uncertainty sample per peer.
        worker.probe_clocks(3)?;
        Ok(worker)
    }

    /// Re-measure the per-peer clock offsets over the registered sessions
    /// (a burst of `rounds` NTP-style probes each, the tightest round-trip
    /// kept). Callable only at lock-step points — between iterations or
    /// right after connect — where no pull/push is in flight on these
    /// sockets. Peers are named by their dialed port (`shard-{port}`),
    /// matching the lane name a shard derives for itself.
    pub fn probe_clocks(&mut self, rounds: usize) -> Result<()> {
        for (conn, addr) in self.conns.iter_mut().zip(&self.cfg.server_addrs) {
            crate::obs::clock::probe_and_note(conn, &format!("shard-{}", addr.port()), rounds)
                .with_context(|| format!("clock probe against {addr}"))?;
        }
        Ok(())
    }

    /// The synchronization mode every shard confirmed for this session.
    pub fn sync_mode(&self) -> SyncMode {
        self.sync
    }

    /// Mid-run recovery: re-dial and fully re-register shard `srv` after
    /// an I/O failure (shard restart, network partition, tripped
    /// `--io-timeout-ms` deadline). The replacement session must agree on
    /// the sync configuration and the already-negotiated codec — the
    /// compiled byte tables are fixed for the run — so a shard that came
    /// back different fails loudly instead of training inconsistently.
    /// The dial itself retries with capped exponential backoff and
    /// deterministic jitter, bridging the restart window.
    pub fn reconnect_shard(&mut self, srv: usize) -> Result<()> {
        anyhow::ensure!(srv < self.conns.len(), "no shard {srv} to reconnect");
        let addr = self.cfg.server_addrs[srv];
        let sync = SyncConfig::new(self.sync, self.staleness_bound)?;
        let conn = establish_session(
            &addr,
            self.cfg.id as u32,
            sync,
            self.codec,
            self.cfg.shaper.clone(),
            io_timeout_of(self.cfg.io_timeout_ms),
        )
        .with_context(|| format!("reconnecting worker {} to shard {srv}", self.cfg.id))?;
        self.conns[srv] = conn;
        Ok(())
    }

    /// The servers' authoritative SSP staleness bound (0 outside SSP).
    pub fn staleness_bound(&self) -> u32 {
        self.staleness_bound
    }

    /// Whether EF-SGD residuals are being carried this session.
    pub fn error_feedback_active(&self) -> bool {
        self.ef.is_some()
    }

    /// The wire codec this session negotiated with its shards.
    pub fn codec(&self) -> CodecId {
        self.codec
    }

    /// Worker-side per-codec counters (gradient encodes, reply decodes),
    /// indexed by [`CodecId::tag`].
    pub fn codec_stats(&self) -> [CodecStats; 3] {
        self.codec_stats.snapshot()
    }

    pub fn depth(&self) -> usize {
        self.runtime.manifest.depth()
    }

    pub fn plan(&self) -> &SchedulePlan {
        &self.plan
    }

    /// The compiled form of [`EdgeWorker::plan`] that `iteration` executes.
    pub fn exec_plan(&self) -> &ExecPlan {
        &self.exec
    }

    /// Counters of the worker's slab pool (reply frames + gradient slabs).
    pub fn pool_stats(&self) -> crate::net::pool::PoolStats {
        self.pool.stats()
    }

    /// Re-run the scheduler from the latest profile; returns the call's
    /// outcome, or None if the profiler has no signal yet. When the
    /// scheduler reuses its cached plan the compiled `ExecPlan` is kept
    /// as-is (no recompilation). Re-compiles reuse the same slab pool, so
    /// warm buffers survive plan changes.
    pub fn reschedule(&mut self) -> Option<Reschedule> {
        let cv = self.profiler.cost_vectors()?;
        let t0 = Instant::now();
        let sp = self.scheduler.plan(&cv);
        let sched_ms = t0.elapsed().as_secs_f64() * 1e3;
        let outcome = Reschedule {
            sched_ms,
            reused: sp.reused,
            changed: !sp.reused && sp.plan != self.plan,
            predicted_ms: sp.predicted_ms(),
        };
        crate::sched::note_replan(sp.reused);
        // The per-pass predictions seed the overlap audit: the next
        // iterations' measured fwd/bwd timelines are compared against them.
        self.last_predicted = Some((sp.predicted_fwd_ms, sp.predicted_bwd_ms));
        if outcome.changed {
            let exec = ExecPlan::compile(
                &sp.plan,
                &self.exec.layer_bytes,
                self.shard,
                self.pool.clone(),
                self.codec,
            );
            self.exec = Arc::new(exec);
            self.plan = sp.plan;
        }
        Some(outcome)
    }

    /// Run `iters` iterations, fetching batches from `next_batch`.
    pub fn run(
        &mut self,
        iters: u64,
        mut next_batch: impl FnMut(u64) -> (Tensor, Tensor),
    ) -> Result<WorkerReport> {
        let mut report = WorkerReport::default();
        // This worker's lane in the merged fleet trace: the main thread
        // and the per-iteration puller/pusher threads all record onto it.
        crate::obs::trace::adopt_node(&format!("worker-{}", self.cfg.id));
        for i in 0..iters {
            if self.cfg.clock_probe_every > 0
                && i > 0
                && (i as usize) % self.cfg.clock_probe_every == 0
            {
                // Between iterations the sessions are lock-step idle: a
                // probe frame cannot interleave with a pull or push.
                self.probe_clocks(1)?;
            }
            if i > 0 && (i as usize) % self.cfg.reschedule_every == 0 {
                if let Some(r) = self.reschedule() {
                    report.sched_ms.push(r.sched_ms);
                    report.sched_predicted_ms.push(r.predicted_ms);
                    if r.reused {
                        report.sched_reused += 1;
                    } else if r.changed {
                        report.plans.push(PlanChange {
                            iter: i,
                            fwd_segments: self.plan.fwd.num_transmissions(),
                            bwd_segments: self.plan.bwd.num_transmissions(),
                            sched_ms: r.sched_ms,
                        });
                    }
                }
            }
            let (x, onehot) = next_batch(i);
            let t0 = Instant::now();
            let (loss, top1) = {
                let _sp = crate::obs::trace::span(crate::obs::trace::SPAN_ITERATION);
                self.iteration(i, &x, &onehot)?
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            self.obs.iterations.inc();
            self.obs.iter_ms.observe(ms);
            self.obs.staleness.observe(self.last_staleness as f64);
            report.iter_ms.push(ms);
            report.losses.push(loss);
            report.batch_top1.push(top1);
            report.staleness.push(self.last_staleness);
        }
        report.metrics = crate::obs::snapshot_pairs();
        Ok(report)
    }

    /// One BSP iteration: segmented pulls + layer-wise fwd, loss,
    /// layer-wise bwd + segmented pushes — all driven by the precompiled
    /// [`ExecPlan`], no per-iteration segment or offset recomputation, and
    /// no slab allocations once the pool is warm.
    pub fn iteration(&mut self, iter: u64, x: &Tensor, onehot: &Tensor) -> Result<(f32, f64)> {
        let depth = self.depth();
        let exec = self.exec.clone();
        let t_fwd = Instant::now();

        // ---- Forward: puller thread streams segments; main computes. ----
        let (param_tx, param_rx) = mpsc::channel::<(usize, SlabSlice)>();
        let (stat_tx, stat_rx) = mpsc::channel::<SegmentPull>();
        let mut puller_conns = Vec::new();
        for c in &self.conns {
            puller_conns.push(c.try_clone()?);
        }
        let exec_pull = exec.clone();
        let pull_pool = self.pool.clone();
        let pull_stats = self.codec_stats.clone();
        let pull_node = format!("worker-{}", self.cfg.id);
        let puller = std::thread::Builder::new()
            .name(format!("puller-{}", self.cfg.id))
            .spawn(move || -> Result<()> {
                crate::obs::trace::adopt_node(&pull_node);
                for seg in &exec_pull.fwd {
                    let mut sp = crate::obs::trace::span(crate::obs::trace::SPAN_PULL_SEG);
                    let t0 = Instant::now();
                    // Oldest snapshot served across the segment's shards.
                    let mut seg_applied = u64::MAX;
                    for sub in &seg.subs {
                        puller_conns[sub.server].send(&Message::Pull {
                            iter,
                            lo: seg.lo as u32,
                            hi: seg.hi as u32,
                        })?;
                        // The reply lands straight in a pooled frame; each
                        // layer gets a view of it — no copies on the pull
                        // path, and the frame recycles when the last view
                        // is consumed.
                        let (rcodec, applied, data) =
                            match puller_conns[sub.server].recv_pooled(&pull_pool)? {
                                RecvMsg::PullReply { codec, applied, data, ctx, .. } => {
                                    if let Some(c) = ctx.filter(|c| c.is_reply()) {
                                        // Stitch the serving assembly into
                                        // this segment's lane: an arrow,
                                        // not a parent — reply windows do
                                        // not nest inside the puller's.
                                        sp.set_flow_from(c.parent_span);
                                    }
                                    (codec, applied, data)
                                }
                                m => anyhow::bail!("bad pull reply: {m:?}"),
                            };
                        seg_applied = seg_applied.min(applied);
                        anyhow::ensure!(
                            rcodec == exec_pull.codec,
                            "pull reply codec mismatch: got {}, session speaks {}",
                            rcodec.name(),
                            exec_pull.codec.name()
                        );
                        anyhow::ensure!(
                            data.len() == sub.wire_bytes,
                            "pull reply size mismatch: got {}, want {}",
                            data.len(),
                            sub.wire_bytes
                        );
                        if exec_pull.codec == CodecId::Fp32 {
                            for sl in &sub.slices {
                                let _ = param_tx
                                    .send((sl.layer, data.slice(sl.reply_off, sl.len)));
                            }
                        } else {
                            // Compressed reply: decode each layer's
                            // encoding into one pooled scratch buffer
                            // (recycled — the decode path stays
                            // allocation-free once warm), then hand out
                            // raw-offset views of the frozen scratch.
                            let _sp = crate::obs::trace::span(
                                crate::obs::trace::SPAN_DECODE_SEG,
                            );
                            let wc = exec_pull.codec.codec();
                            let mut raw = pull_pool.checkout(sub.bytes);
                            let td = Instant::now();
                            for sl in &sub.slices {
                                wc.decode(
                                    &data[sl.wire_off..sl.wire_off + sl.wire_len],
                                    &mut raw,
                                )?;
                            }
                            pull_stats.record_decode(
                                exec_pull.codec,
                                sub.bytes,
                                sub.wire_bytes,
                                td.elapsed().as_nanos() as u64,
                            );
                            anyhow::ensure!(
                                raw.len() == sub.bytes,
                                "codec decode size mismatch: got {}, want {}",
                                raw.len(),
                                sub.bytes
                            );
                            let decoded = raw.freeze();
                            for sl in &sub.slices {
                                let _ = param_tx.send((
                                    sl.layer,
                                    SlabSlice::new(decoded.clone(), sl.reply_off, sl.len),
                                ));
                            }
                        }
                    }
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let _ = stat_tx.send(SegmentPull {
                        wire_bytes: seg.wire_bytes,
                        ms,
                        applied: if seg_applied == u64::MAX { iter } else { seg_applied },
                    });
                }
                Ok(())
            })?;

        let mut acts: Vec<Tensor> = Vec::with_capacity(depth + 1);
        acts.push(x.clone());
        let mut params: Vec<Option<(Tensor, Tensor)>> = vec![None; depth];
        for l in 0..depth {
            while params[l].is_none() {
                let (got, flat) = param_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("puller died before layer {l}"))?;
                params[got] = Some(self.split_params(got, &flat)?);
            }
            let (w, b) = params[l].as_ref().unwrap();
            let t0 = Instant::now();
            let y = {
                let _sp = crate::obs::trace::span(crate::obs::trace::SPAN_FWD_LAYER);
                self.runtime.layer_fwd(l, w, b, &acts[l])?
            };
            self.profiler.record_fwd(l, t0.elapsed().as_secs_f64() * 1e3);
            acts.push(y);
        }
        puller
            .join()
            .map_err(|_| anyhow::anyhow!("puller panicked"))?
            .context("puller failed")?;
        let mut max_stale = 0u64;
        while let Ok(sp) = stat_rx.try_recv() {
            // The sample's wall-clock was measured under the live sync
            // policy, so the profiler's Δt/rate fit — and the DP that
            // consumes it — costs the mode's actual wait window.
            self.profiler.record_pull(sp.wire_bytes, sp.ms);
            max_stale = max_stale.max(iter.saturating_sub(sp.applied));
        }
        // Client-side check of the server's staleness contract: under SSP
        // no admitted pull may be served a snapshot older than the bound.
        if self.sync == SyncMode::Ssp {
            anyhow::ensure!(
                max_stale <= self.staleness_bound as u64,
                "SSP staleness violated: observed {max_stale} > bound {}",
                self.staleness_bound
            );
        }
        self.last_staleness = max_stale;
        let fwd_ms = t_fwd.elapsed().as_secs_f64() * 1e3;

        // ---- Loss head. ----
        let logits = &acts[depth];
        let (loss, glogits) = {
            let _sp = crate::obs::trace::span(crate::obs::trace::SPAN_LOSS);
            self.runtime.loss(logits, onehot)?
        };
        let top1 = batch_top1(logits, onehot);
        let t_bwd = Instant::now();

        // ---- Backward: main computes; pusher thread flushes segments. ----
        // Channel carries (index into exec.bwd, the segment's per-layer
        // pooled gradient slabs in ascending layer order).
        let (grad_tx, grad_rx) = mpsc::channel::<(usize, Vec<SlabCheckout>)>();
        let mut pusher_conns = Vec::new();
        for c in &self.conns {
            pusher_conns.push(c.try_clone()?);
        }
        let exec_push = exec.clone();
        let push_node = format!("worker-{}", self.cfg.id);
        let pusher = std::thread::Builder::new()
            .name(format!("pusher-{}", self.cfg.id))
            .spawn(move || -> Result<Vec<(usize, f64)>> {
                crate::obs::trace::adopt_node(&push_node);
                let mut stats = Vec::new();
                while let Ok((si, slabs)) = grad_rx.recv() {
                    let sp = crate::obs::trace::span(crate::obs::trace::SPAN_PUSH_SEG);
                    let seg = &exec_push.bwd[si];
                    anyhow::ensure!(
                        slabs.len() == seg.hi - seg.lo + 1,
                        "segment slab count mismatch: got {}, want {}",
                        slabs.len(),
                        seg.hi - seg.lo + 1
                    );
                    let t0 = Instant::now();
                    for sub in &seg.subs {
                        // Gather this shard's layers straight from the
                        // per-layer (codec-encoded) slabs: the payload is
                        // never assembled, it goes out vectored.
                        let mut parts: Vec<&[u8]> = Vec::with_capacity(sub.slices.len());
                        for sl in &sub.slices {
                            let s = &slabs[sl.layer - seg.lo];
                            anyhow::ensure!(
                                s.len() == sl.wire_len,
                                "layer {} grad slab: got {}, want {}",
                                sl.layer,
                                s.len(),
                                sl.wire_len
                            );
                            parts.push(&s[..]);
                        }
                        // The receiver (shard apply / aggregator fan-in)
                        // records its span with this segment span as its
                        // remote parent: the push is ack-synchronous, so
                        // the receiver's work nests inside this window.
                        let ctx = if sp.id() != 0 {
                            Some(TraceCtx::sampled(
                                crate::obs::trace::trace_id_for(iter),
                                sp.id(),
                            ))
                        } else {
                            None
                        };
                        pusher_conns[sub.server].send_push_parts(
                            iter,
                            seg.lo as u32,
                            seg.hi as u32,
                            exec_push.codec,
                            &parts,
                            ctx,
                        )?;
                        match pusher_conns[sub.server].recv()? {
                            Message::PushAck { .. } => {}
                            m => anyhow::bail!("bad push ack: {m:?}"),
                        }
                    }
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    stats.push((seg.wire_bytes, ms));
                    // `slabs` drops here → gradient buffers return to the
                    // pool for the next iteration.
                }
                Ok(stats)
            })?;

        let mut gy = glogits;
        let mut pending: Vec<Option<SlabCheckout>> = (0..depth).map(|_| None).collect();
        let mut seg_iter = exec.bwd.iter().enumerate();
        let mut cur_seg = seg_iter.next();
        for l in (0..depth).rev() {
            let (w, b) = params[l].as_ref().unwrap();
            let t0 = Instant::now();
            let (gw, gb, gx) = {
                let _sp = crate::obs::trace::span(crate::obs::trace::SPAN_BWD_LAYER);
                let gy_shaped = reshape_like_output(&gy, &self.runtime, l);
                self.runtime.layer_bwd(l, w, b, &acts[l], &gy_shaped)?
            };
            self.profiler.record_bwd(l, t0.elapsed().as_secs_f64() * 1e3);
            // Flatten the layer's gradient once, into a pooled buffer
            // pre-sized from the plan's byte tables; under a compressing
            // codec it is then encoded into a second pre-sized checkout
            // (both recycle — the raw scratch returns to the pool here).
            let mut flat = exec.checkout_layer(l);
            gw.extend_le_bytes(&mut flat);
            gb.extend_le_bytes(&mut flat);
            pending[l] = Some(if exec.codec == CodecId::Fp32 {
                flat
            } else {
                let _sp = crate::obs::trace::span(crate::obs::trace::SPAN_GRAD_ENCODE);
                let wc = exec.codec.codec();
                let mut wire = exec.checkout_layer_wire(l);
                let te = Instant::now();
                // EF-SGD: fold the carried residual into the gradient
                // before quantizing and bank this step's rounding error
                // for the next iteration (`net::codec::ef`).
                let err = match self.ef.as_mut() {
                    Some(ef) => ef.encode(l, wc, &mut flat[..], &mut wire)?,
                    None => wc.encode(&flat, &mut wire),
                };
                self.codec_stats.record_encode(
                    exec.codec,
                    flat.len(),
                    wire.len(),
                    te.elapsed().as_nanos() as u64,
                    err,
                );
                wire
            });
            gy = gx;
            // Segment complete once we've computed down to its low layer.
            if let Some((si, seg)) = cur_seg {
                if l == seg.lo {
                    let slabs: Vec<SlabCheckout> = (seg.lo..=seg.hi)
                        .map(|ll| pending[ll].take().unwrap())
                        .collect();
                    grad_tx
                        .send((si, slabs))
                        .map_err(|_| anyhow::anyhow!("pusher died"))?;
                    cur_seg = seg_iter.next();
                }
            }
        }
        drop(grad_tx);
        let stats = pusher
            .join()
            .map_err(|_| anyhow::anyhow!("pusher panicked"))?
            .context("pusher failed")?;
        for (bytes, ms) in stats {
            self.profiler.record_push(bytes, ms);
        }
        // Overlap audit: drift between the latest re-plan's predicted pass
        // finish times and the measured timelines (docs/OBSERVABILITY.md).
        if let Some((pf, pb)) = self.last_predicted {
            let bwd_ms = t_bwd.elapsed().as_secs_f64() * 1e3;
            record_overlap_drift(true, pf, fwd_ms);
            record_overlap_drift(false, pb, bwd_ms);
        }
        Ok((loss, top1))
    }

    /// Pull the parameters as of `iter` (blocks until the BSP clock gets
    /// there) — used for evaluation snapshots. Cold path, but the same
    /// slicing discipline: layers are split straight out of each shard's
    /// reply, no intermediate per-layer buffers.
    pub fn pull_params(&mut self, iter: u64) -> Result<Vec<(Tensor, Tensor)>> {
        let depth = self.depth();
        let wc = self.codec.codec();
        let mut out: Vec<Option<(Tensor, Tensor)>> = vec![None; depth];
        let mut scratch = Vec::new();
        for srv in 0..self.shard.servers {
            self.conns[srv].send(&Message::Pull { iter, lo: 0, hi: depth as u32 - 1 })?;
            let (rcodec, data) = match self.conns[srv].recv()? {
                Message::PullReply { codec, data, .. } => (codec, data),
                m => anyhow::bail!("bad pull reply: {m:?}"),
            };
            anyhow::ensure!(
                rcodec == self.codec,
                "pull reply codec mismatch: got {}, session speaks {}",
                rcodec.name(),
                self.codec.name()
            );
            let mut off = 0;
            for l in self.shard.owned_by(srv) {
                let n = self.exec.wire_layer_bytes[l];
                anyhow::ensure!(off + n <= data.len(), "short pull reply");
                out[l] = Some(if self.codec == CodecId::Fp32 {
                    // Uncompressed: split straight out of the reply, no
                    // intermediate per-layer buffer.
                    self.split_params(l, &data[off..off + n])?
                } else {
                    scratch.clear();
                    wc.decode(&data[off..off + n], &mut scratch)?;
                    self.split_params(l, &scratch)?
                });
                off += n;
            }
        }
        out.into_iter()
            .enumerate()
            .map(|(l, p)| p.with_context(|| format!("missing layer {l}")))
            .collect()
    }

    /// Split a layer's `w‖b` byte slab into its weight and bias tensors —
    /// the only f32 materialization on the pull path, directly into the
    /// final buffers.
    fn split_params(&self, l: usize, flat: &[u8]) -> Result<(Tensor, Tensor)> {
        let a = &self.runtime.manifest.layers[l];
        let wb = 4 * a.w_count();
        anyhow::ensure!(
            flat.len() == wb + 4 * a.b_count(),
            "layer {l}: got {} param bytes, want {}",
            flat.len(),
            wb + 4 * a.b_count()
        );
        let w = Tensor::from_le_bytes(a.w_shape.clone(), &flat[..wb])?;
        let b = Tensor::from_le_bytes(a.b_shape.clone(), &flat[wb..])?;
        Ok((w, b))
    }
}

/// The gradient flowing back from layer `l+1` arrives with that layer's
/// input shape; relabel it to layer `l`'s output shape (same element
/// count — flatten boundaries differ between fc and conv layers).
fn reshape_like_output(gy: &Tensor, runtime: &RuntimeClient, l: usize) -> Tensor {
    let a = &runtime.manifest.layers[l];
    let mut shape = vec![runtime.manifest.batch];
    shape.extend(&a.out_shape);
    Tensor::new(shape, gy.data.clone())
}

/// Fraction of rows whose argmax matches the one-hot label.
pub fn batch_top1(logits: &Tensor, onehot: &Tensor) -> f64 {
    let classes = *logits.shape.last().unwrap();
    let rows = logits.len() / classes;
    let mut hits = 0;
    for r in 0..rows {
        let row = &logits.data[r * classes..(r + 1) * classes];
        let pred = argmax(row);
        let label = argmax(&onehot.data[r * classes..(r + 1) * classes]);
        if pred == label {
            hits += 1;
        }
    }
    hits as f64 / rows as f64
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_top1() {
        let logits = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 0.5, 0.2, 0.3]);
        let onehot = Tensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        // Row 0 correct (argmax 1), row 1 wrong (argmax 0, label 2).
        assert!((batch_top1(&logits, &onehot) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax(&[1.0, 1.0, 0.5]), 0);
    }

    #[test]
    fn connect_retries_until_listener_appears() {
        // Reserve a port, drop the listener (connects now fail), and only
        // bring the real listener up after a delay: the retry loop must
        // bridge the gap — this is the worker/server startup race.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            std::net::TcpListener::bind(addr)
                .ok()
                .and_then(|l| l.accept().ok())
        });
        let stream = connect_with_retry(&addr, 0);
        let accepted = t.join().unwrap();
        // The rebind can race another process grabbing the port; only
        // assert when the listener actually came back.
        if accepted.is_some() {
            assert!(stream.is_ok(), "retry failed: {:?}", stream.err());
        }
    }

    #[test]
    fn connect_retry_gives_up_eventually() {
        // A port with nothing listening: bounded retry must return an
        // error rather than spin forever.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let t0 = Instant::now();
        let r = connect_with_retry(&addr, 0);
        // Either some other process reused the port (fine), or we erred
        // out within the deadline window.
        if let Err(e) = r {
            assert!(t0.elapsed() < Duration::from_secs(30), "unbounded retry");
            assert!(format!("{e:#}").contains("retries exhausted"), "{e:#}");
        }
    }

    /// The satellite contract: jitter is a pure function of
    /// `(seed, attempt)` and never exceeds the backoff step it stretches.
    #[test]
    fn retry_jitter_is_deterministic_and_bounded() {
        for attempt in 0..32 {
            for &ms in &[1u64, 2, 8, 100] {
                let backoff = Duration::from_millis(ms);
                let a = retry_jitter(42, attempt, backoff);
                let b = retry_jitter(42, attempt, backoff);
                assert_eq!(a, b, "same (seed, attempt) must jitter identically");
                assert!(a <= backoff, "jitter {a:?} exceeds backoff {backoff:?}");
            }
        }
        // Different seeds decorrelate: over 32 attempts at the 100 ms
        // step, two dialers must not replay the same schedule.
        let backoff = Duration::from_millis(100);
        let schedule = |seed| -> Vec<Duration> {
            (0..32).map(|i| retry_jitter(seed, i, backoff)).collect()
        };
        assert_ne!(schedule(1), schedule(2), "seeds must decorrelate dialers");
    }

    /// One call re-establishes a fully registered session: dial, version
    /// check, sync agreement, I/O deadline — the worker's mid-run
    /// reconnect path, exercised against a real shard.
    #[test]
    fn establish_session_registers_and_serves() {
        use crate::ps::server::{ParamServer, ServerConfig};
        let mut layers = std::collections::HashMap::new();
        layers.insert(0, vec![1.0f32, 2.0]);
        let srv =
            ParamServer::start(ServerConfig { workers: 1, lr: 0.5 }, layers, None).unwrap();
        let mut conn = establish_session(
            &srv.handle().addr,
            7,
            SyncConfig::default(),
            CodecId::Fp32,
            None,
            io_timeout_of(2_000),
        )
        .unwrap();
        conn.send(&Message::Pull { iter: 0, lo: 0, hi: 0 }).unwrap();
        match conn.recv().unwrap() {
            Message::PullReply { data, .. } => {
                assert_eq!(crate::net::slab::to_f32s(&data), vec![1.0, 2.0]);
            }
            m => panic!("{m:?}"),
        }
    }
}
