//! The shared pull-reply broadcast cache (single-flight assembly).
//!
//! Extracted from the server so the regional aggregation tier
//! ([`crate::ps::agg`]) can reuse the exact same seam: every same-key
//! puller of a segment shares one assembly, concurrent pullers for an
//! in-flight key park on the condvar instead of duplicating the work, and
//! finished keys' slabs return to the pool. The cache itself is policy-free
//! — who builds, what the key means, and when entries are evicted stays
//! with the caller (`ps/server.rs` and `ps/agg` both implement the
//! `Building`/`Ready` single-flight dance around it).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::net::codec::CodecId;
use crate::net::pool::PooledSlab;
use crate::obs::Counter;

/// State of one reply-cache entry (single-flight assembly).
pub(crate) enum ReplyState {
    /// A handler is assembling this reply; others wait on the condvar.
    Building,
    /// Assembled: slab + the snapshot's applied iteration + the span id of
    /// the assembly that built it (0 when tracing is disarmed). The span
    /// id rides along so cache-hit replies still carry a valid v7 trace
    /// context pointing at the assembly they reuse.
    Ready(Arc<PooledSlab>, u64, u32),
}

/// The shared pull-reply broadcast cache, keyed by
/// `(key_iter, lo, hi, codec)` — sessions speaking different codecs need
/// different reply bytes, but every same-codec puller of a segment still
/// shares one single-flight assembly. `key_iter` is the requested
/// iteration under the BSP barrier (byte-identical replies per iteration,
/// the historical key) and an apply/forward-event counter under
/// immediate-apply modes (a fresh apply invalidates the broadcast, so
/// "freshest applied snapshot" and "assemble once per snapshot" coexist).
pub(crate) struct ReplyCache {
    pub(crate) entries: Mutex<HashMap<(u64, u32, u32, CodecId), ReplyState>>,
    /// Signals entry transitions (Building → Ready/removed) and shutdown.
    pub(crate) ready: Condvar,
    /// Pulls answered from an already-assembled slab (obs registry
    /// series, labelled by owning component).
    pub(crate) hits: Counter,
    /// Successful assemblies (== distinct `(iter, lo, hi)` keys served).
    pub(crate) builds: Counter,
}

impl ReplyCache {
    /// `component` labels this cache's obs series (`"server"` at the
    /// cloud shard, `"agg"` at the regional aggregator).
    pub(crate) fn new(component: &str) -> ReplyCache {
        let lbl = format!("component=\"{component}\"");
        let inst = crate::obs::next_inst();
        ReplyCache {
            entries: Mutex::new(HashMap::new()),
            ready: Condvar::new(),
            hits: crate::obs_counter!("dynacomm_reply_cache_hits_total", lbl, inst),
            builds: crate::obs_counter!("dynacomm_reply_cache_builds_total", lbl, inst),
        }
    }
}
