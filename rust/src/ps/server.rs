//! A parameter-server shard.
//!
//! Holds the flat `w‖b` parameter vector for each layer it owns, serves
//! `Pull`s (blocking until the layer's version reaches the requested
//! iteration — this is the BSP clock), accumulates `Push`ed gradients, and
//! applies averaged SGD once every registered worker has contributed.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::net::{Connection, Message, ShaperSpec};

#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Workers that must push before an update is applied (BSP).
    pub workers: usize,
    /// SGD learning rate applied server-side.
    pub lr: f32,
}

struct LayerSlot {
    /// Flat parameters, weights then bias.
    params: Vec<f32>,
    /// Number of iterations already applied; a `Pull { iter }` waits until
    /// `version >= iter`.
    version: u64,
    grad_sum: Vec<f32>,
    grad_count: usize,
}

struct Shared {
    cfg: ServerConfig,
    /// layer id -> guarded slot (only layers this shard owns).
    slots: HashMap<usize, (Mutex<LayerSlot>, Condvar)>,
    shutting_down: AtomicBool,
    connected: AtomicU32,
}

/// A running shard: background accept loop + handler threads.
pub struct ParamServer {
    #[allow(dead_code)]
    shared: Arc<Shared>,
    listener_thread: Option<JoinHandle<()>>,
    addr: std::net::SocketAddr,
}

/// Cheap handle for clients: address + graceful shutdown.
#[derive(Clone)]
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    #[allow(dead_code)]
    shared: Arc<Shared>,
}

impl ParamServer {
    /// Start a shard on an ephemeral loopback port. `layers` maps layer id
    /// to its initial flat parameters. Server→worker replies are shaped
    /// with a fresh shaper per accepted connection when `shaper` is given
    /// (the downlink half of each worker's emulated edge link;
    /// worker→server requests are shaped on the worker side).
    pub fn start(
        cfg: ServerConfig,
        layers: HashMap<usize, Vec<f32>>,
        shaper: Option<ShaperSpec>,
    ) -> Result<ParamServer> {
        let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
        let addr = listener.local_addr()?;
        let slots = layers
            .into_iter()
            .map(|(l, params)| {
                let n = params.len();
                (
                    l,
                    (
                        Mutex::new(LayerSlot {
                            params,
                            version: 0,
                            grad_sum: vec![0.0; n],
                            grad_count: 0,
                        }),
                        Condvar::new(),
                    ),
                )
            })
            .collect();
        let shared = Arc::new(Shared {
            cfg,
            slots,
            shutting_down: AtomicBool::new(false),
            connected: AtomicU32::new(0),
        });
        let shared2 = shared.clone();
        let listener_thread = std::thread::Builder::new()
            .name(format!("ps-accept-{}", addr.port()))
            .spawn(move || accept_loop(listener, shared2, shaper))?;
        Ok(ParamServer { shared, listener_thread: Some(listener_thread), addr })
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { addr: self.addr, shared: self.shared.clone() }
    }

    /// Read back the current parameters of a layer (test/eval support).
    pub fn snapshot(&self, layer: usize) -> Option<Vec<f32>> {
        let (m, _) = self.shared.slots.get(&layer)?;
        Some(m.lock().unwrap().params.clone())
    }

    /// Stop accepting and unblock handler threads.
    pub fn shutdown(&mut self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        // Wake any pull waiting on a version bump.
        for (m, cv) in self.shared.slots.values() {
            let _guard = m.lock().unwrap();
            cv.notify_all();
            drop(_guard);
        }
    }
}

impl Drop for ParamServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, shaper: Option<ShaperSpec>) {
    let mut handlers = Vec::new();
    loop {
        let Ok((stream, _)) = listener.accept() else { break };
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let shared = shared.clone();
        let shaper = shaper.map(|s| s.build());
        handlers.push(std::thread::spawn(move || {
            let conn = Connection::new(stream, shaper);
            if let Err(e) = handle_conn(conn, &shared) {
                crate::debug!("ps", "handler exit: {e:#}");
            }
        }));
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_conn(mut conn: Connection, shared: &Shared) -> Result<()> {
    loop {
        let msg = match conn.recv() {
            Ok(m) => m,
            // Peer hung up: normal teardown.
            Err(_) => return Ok(()),
        };
        match msg {
            Message::Hello { worker: _ } => {
                shared.connected.fetch_add(1, Ordering::SeqCst);
                conn.send(&Message::HelloAck {
                    workers: shared.cfg.workers as u32,
                })?;
            }
            Message::Pull { iter, lo, hi } => {
                let mut data = Vec::new();
                for l in lo as usize..=hi as usize {
                    let Some((m, cv)) = shared.slots.get(&l) else { continue };
                    let mut slot = m.lock().unwrap();
                    while slot.version < iter
                        && !shared.shutting_down.load(Ordering::SeqCst)
                    {
                        let (s, _timeout) = cv
                            .wait_timeout(slot, std::time::Duration::from_millis(200))
                            .unwrap();
                        slot = s;
                    }
                    data.extend_from_slice(&slot.params);
                }
                conn.send(&Message::PullReply { iter, lo, hi, data })?;
            }
            Message::Push { iter, lo, hi, data } => {
                let mut off = 0usize;
                for l in lo as usize..=hi as usize {
                    let Some((m, cv)) = shared.slots.get(&l) else { continue };
                    let mut slot = m.lock().unwrap();
                    let n = slot.params.len();
                    anyhow::ensure!(
                        off + n <= data.len(),
                        "push payload too small for layers {lo}..={hi}"
                    );
                    for (g, d) in slot.grad_sum.iter_mut().zip(&data[off..off + n]) {
                        *g += d;
                    }
                    off += n;
                    slot.grad_count += 1;
                    if slot.grad_count == shared.cfg.workers {
                        // Averaged SGD, then advance the BSP clock.
                        let scale = shared.cfg.lr / shared.cfg.workers as f32;
                        // Split borrows: update params from grad_sum.
                        let LayerSlot { params, grad_sum, version, grad_count } =
                            &mut *slot;
                        for (w, g) in params.iter_mut().zip(grad_sum.iter()) {
                            *w -= scale * *g;
                        }
                        grad_sum.iter_mut().for_each(|g| *g = 0.0);
                        *grad_count = 0;
                        *version = iter + 1;
                        cv.notify_all();
                    }
                }
                anyhow::ensure!(off == data.len(), "push payload size mismatch");
                conn.send(&Message::PushAck { iter, lo, hi })?;
            }
            Message::Shutdown => return Ok(()),
            other => anyhow::bail!("unexpected message at server: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn connect(addr: std::net::SocketAddr) -> Connection {
        Connection::new(TcpStream::connect(addr).unwrap(), None)
    }

    fn start_two_layer(workers: usize) -> ParamServer {
        let mut layers = HashMap::new();
        layers.insert(0, vec![1.0f32, 2.0]);
        layers.insert(1, vec![10.0f32]);
        ParamServer::start(ServerConfig { workers, lr: 0.5 }, layers, None).unwrap()
    }

    #[test]
    fn pull_initial_params() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { data, .. } => assert_eq!(data, vec![1.0, 2.0, 10.0]),
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn push_applies_averaged_sgd() {
        let srv = start_two_layer(2);
        let mut a = connect(srv.handle().addr);
        let mut b = connect(srv.handle().addr);
        // Worker A pushes grad [2, 0] for layer 0; worker B pushes [0, 4].
        a.send(&Message::Push { iter: 0, lo: 0, hi: 0, data: vec![2.0, 0.0] }).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::PushAck { .. }));
        // Not applied yet (1 of 2 workers).
        assert_eq!(srv.snapshot(0).unwrap(), vec![1.0, 2.0]);
        b.send(&Message::Push { iter: 0, lo: 0, hi: 0, data: vec![0.0, 4.0] }).unwrap();
        assert!(matches!(b.recv().unwrap(), Message::PushAck { .. }));
        // w -= 0.5 * avg = 0.5*[1,2] ⇒ [0.5, 1.0].
        assert_eq!(srv.snapshot(0).unwrap(), vec![0.5, 1.0]);
    }

    #[test]
    fn pull_blocks_until_version_advances() {
        let srv = start_two_layer(1);
        let addr = srv.handle().addr;
        let t = std::thread::spawn(move || {
            let mut c = connect(addr);
            // iteration 1 params are only available after the iter-0 push.
            c.send(&Message::Pull { iter: 1, lo: 0, hi: 0 }).unwrap();
            let t0 = std::time::Instant::now();
            let reply = c.recv().unwrap();
            (t0.elapsed(), reply)
        });
        std::thread::sleep(std::time::Duration::from_millis(120));
        let mut p = connect(addr);
        p.send(&Message::Push { iter: 0, lo: 0, hi: 0, data: vec![2.0, 2.0] }).unwrap();
        p.recv().unwrap();
        let (elapsed, reply) = t.join().unwrap();
        assert!(elapsed.as_millis() >= 100, "pull did not block: {elapsed:?}");
        match reply {
            Message::PullReply { data, .. } => assert_eq!(data, vec![0.0, 1.0]),
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn ignores_unowned_layers_in_range() {
        // Shard owns layers {0, 1}; a pull of [0, 5] returns only owned data.
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 5 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { data, .. } => assert_eq!(data.len(), 3),
            m => panic!("{m:?}"),
        }
    }
}
