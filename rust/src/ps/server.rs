//! A parameter-server shard.
//!
//! Holds the flat `w‖b` parameter vector for each layer it owns, serves
//! `Pull`s, accumulates or applies `Push`ed gradients, and runs SGD
//! server-side. *When* a pull may proceed and *when* a push is applied is
//! no longer hard-coded BSP: every consistency decision is delegated to a
//! pluggable [`crate::ps::sync::SyncPolicy`] ([`ServerOptions::sync`],
//! `docs/SYNC.md`) — `bsp` reproduces the historical barrier exactly
//! (pulls park on the per-layer version condvars until the requested
//! iteration is applied; pushes barrier on the full worker count), `ssp`
//! gates pulls on a bounded staleness window and applies pushes
//! immediately, `asp` never gates at all. Replies carry the `applied`
//! iteration of the snapshot they serve (protocol v4).
//!
//! Protocol v5 adds the hierarchical aggregation tier
//! ([`crate::ps::agg`], `docs/TOPOLOGY.md`): a session may register as a
//! regional aggregator (`AggHello`) whose combined pushes carry its
//! group's worker count as barrier weight, and BSP membership is elastic
//! — an identity that disconnects releases the barrier weight it was
//! holding instead of stalling the survivors forever.
//!
//! Parameters live as little-endian f32 byte slabs — the exact bytes a
//! `PullReply` carries — so serving a pull is a bulk `extend_from_slice`
//! with zero f32 conversions; gradient accumulation and SGD read/write the
//! slab through safe 4-byte chunked views (`net::slab`).
//!
//! The steady-state wire path is copy- and allocation-free (`docs/PERF.md`):
//!
//! * **Shared pull-reply broadcast** — under BSP every worker pulls
//!   byte-identical parameters each iteration, so the reply slab for an
//!   `(iter, lo, hi)` key is assembled **once** into a pooled `Arc` slab
//!   (single-flight: concurrent pullers for the same key wait for the one
//!   assembler) and every worker is served a cheap clone. Server-side
//!   copies drop from O(workers × bytes) to O(bytes) per iteration; the
//!   hit counter is exported through [`WireStats`].
//! * **Vectored send** — the cached slab goes out borrowed via
//!   `Connection::send_ref` (`[header][slab]` scatter-gather), never
//!   memcpy'd into a frame buffer.
//! * **Borrowed receive** — `Push` gradients are accumulated straight out
//!   of the connection's receive scratch (`Connection::recv_ref`), never
//!   copied into an owned message.
//! * **Negotiated wire codecs** (protocol v3, `net::codec`) — a session
//!   may speak fp16 or int8 on the wire (`CodecPropose`/`CodecAgree`):
//!   replies are codec-encoded per layer during assembly (the cache is
//!   keyed by codec so same-codec broadcasts stay single-flight), pushes
//!   are decode-accumulated by their frame's codec tag, and per-codec
//!   counters (bytes saved, encode/decode ns, max quantization error) are
//!   exported through [`WireStats`]. Un-negotiated sessions are fp32 and
//!   byte-identical to v2.

use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::net::codec::{self, CodecId, CodecStats, CodecStatsTable};
use crate::net::pool::{PoolStats, PooledSlab, SlabPool};
use crate::net::{
    slab, Connection, Message, MessageRef, PeerRole, ShaperSpec, TraceCtx, PROTOCOL_VERSION,
};
use crate::ps::checkpoint::{Checkpoint, LayerRecord};
use crate::ps::reply_cache::{ReplyCache, ReplyState};
use crate::ps::sync::{self, PullGate, PushApply, SyncConfig, SyncMode, SyncPolicy};
use crate::util::sync::{lock_or_die, wait_or_die};

#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Workers that must push before an update is applied under the BSP
    /// barrier; SSP/ASP apply each push scaled by `1 / workers` instead.
    pub workers: usize,
    /// SGD learning rate applied server-side.
    pub lr: f32,
}

/// Tuning knobs beyond the core [`ServerConfig`] — kept separate so every
/// existing `ParamServer::start` call site keeps its exact shape (and the
/// BSP default keeps its exact behavior).
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// The shard's synchronization policy (`--sync` / `--staleness-bound`).
    pub sync: SyncConfig,
    /// Cap on concurrently live connection-handler threads
    /// (`--handler-threads`). Connections past the cap queue in the kernel
    /// accept backlog — and are refused by the OS once it fills — until a
    /// slot frees: backpressure instead of unbounded thread growth.
    ///
    /// The effective cap is never below [`ServerConfig::workers`]: every
    /// registered worker holds one long-lived connection whose handler may
    /// legitimately park at the barrier, so a smaller cap would deadlock
    /// the fleet against itself — the backpressure is for connections
    /// *beyond* the fleet, not the fleet.
    pub handler_threads: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { sync: SyncConfig::default(), handler_threads: 64 }
    }
}

struct LayerSlot {
    /// Flat parameters (weights then bias) as a little-endian f32 byte
    /// slab — wire-ready for `PullReply` without conversion.
    params: Vec<u8>,
    /// Number of iterations already applied; a `Pull { iter }` waits until
    /// `version >= iter`.
    version: u64,
    /// f32 accumulator for pushed gradient slabs.
    grad_sum: Vec<f32>,
    grad_count: usize,
    /// Iteration of the gradients currently accumulating — what the
    /// version clock advances to if a departure releases the barrier
    /// before the last contribution arrives (`docs/TOPOLOGY.md`).
    pending_iter: u64,
}

impl LayerSlot {
    /// Averaged SGD directly over the slab (`w -= scale * g` through
    /// `slab`'s chunked f32 views); resets the accumulator.
    fn apply_sgd(&mut self, scale: f32) {
        slab::zip_map_f32s(&mut self.params, &self.grad_sum, |w, g| w - scale * g);
        self.grad_sum.iter_mut().for_each(|g| *g = 0.0);
        self.grad_count = 0;
    }
}

/// Barrier-weight accounting for registered identities
/// (`docs/TOPOLOGY.md`). A plain worker registers weight 1 (`Hello`); a
/// regional aggregator registers its group's worker count (`AggHello`,
/// protocol v5) and may hold several sessions under one identity (its
/// pull and push connections), which must count toward the barrier —
/// and toward departure — exactly once.
struct Registry {
    /// identity -> (barrier weight, live sessions sharing the identity).
    peers: HashMap<u32, (u32, u32)>,
    /// Total barrier weight of fully departed identities: the BSP barrier
    /// shrinks by this much so survivors are not stalled forever by a
    /// peer that hung up mid-iteration.
    departed: u32,
}

struct Shared {
    cfg: ServerConfig,
    /// The shard's synchronization policy: every pull-admission and
    /// push-application decision routes through it (`ps::sync`).
    sync: Box<dyn SyncPolicy>,
    /// Cap on live handler threads (see [`ServerOptions`]).
    handler_threads: usize,
    /// Immediate-mode apply events (SSP/ASP): the reply cache's version
    /// key — a new apply invalidates the shared broadcast. Registered as
    /// `dynacomm_server_apply_events_total` in the obs registry.
    apply_events: crate::obs::Counter,
    /// Handler threads currently alive (bounded by `handler_threads`).
    live_handlers: AtomicU32,
    /// layer id -> guarded slot (only layers this shard owns).
    slots: HashMap<usize, (Mutex<LayerSlot>, Condvar)>,
    /// layer id -> slab size in bytes (immutable; lets pulls pre-size
    /// their reply buffer without touching the slot locks).
    layer_bytes: HashMap<usize, usize>,
    /// Reusable buffers for reply assembly (and anything else wire-sized).
    pool: Arc<SlabPool>,
    /// Assemble-once broadcast cache for BSP pull replies.
    reply_cache: ReplyCache,
    /// Registered identities and their barrier weights (`Hello` /
    /// `AggHello`): elastic BSP membership.
    registry: Mutex<Registry>,
    /// Total `Push` payload bytes received — the shard's tensor ingress,
    /// what the tier bench compares flat vs tiered topologies on
    /// (`dynacomm_server_ingress_bytes_total`).
    ingress_bytes: crate::obs::Counter,
    /// Per-codec encode/decode counters (bytes saved, wall-clock, max
    /// quantization error) — exported through [`WireStats`].
    codec_stats: CodecStatsTable,
    shutting_down: AtomicBool,
    connected: AtomicU32,
    /// Pulls currently parked on a version condvar (observability: lets
    /// tests and shutdown reason about parked handlers without sleeping).
    /// An obs-registry gauge: `dynacomm_server_pull_waiters`.
    pull_waiters: crate::obs::Gauge,
    /// Pulls successfully served — cache hit or fresh assembly
    /// (`dynacomm_server_pull_replies_total`).
    pull_replies: crate::obs::Counter,
    /// Live worker sockets (slot per accepted connection; a handler clears
    /// its slot on exit so fds don't leak across reconnects). Shut down on
    /// drain so blocked `recv`s return deterministically instead of
    /// waiting on peers.
    conns: Mutex<Vec<Option<TcpStream>>>,
}

/// Server-side wire-path counters: the shared-broadcast cache plus the
/// slab pool — what `benches/ps_throughput.rs` reports into
/// `BENCH_wire.json` and the steady-state tests assert on.
#[derive(Debug, Clone, Copy)]
pub struct WireStats {
    /// Pulls served from an already-assembled reply slab.
    pub reply_cache_hits: u64,
    /// Reply slabs actually assembled.
    pub reply_cache_builds: u64,
    /// Entries currently cached (bounded: stale iterations are evicted).
    pub reply_cache_entries: usize,
    /// Total `Push` payload bytes this shard received (tensor ingress) —
    /// the tier bench's flat-vs-tiered comparison metric.
    pub ingress_bytes: u64,
    pub pool: PoolStats,
    /// Per-codec counters, indexed by [`CodecId::tag`]: raw vs wire bytes
    /// (bytes saved), encode/decode wall-clock, max quantization error.
    pub codecs: [CodecStats; 3],
}

impl WireStats {
    /// One codec's counters.
    pub fn codec(&self, id: CodecId) -> CodecStats {
        self.codecs[id.tag() as usize]
    }
}

/// A running shard: background accept loop + handler threads.
pub struct ParamServer {
    shared: Arc<Shared>,
    listener_thread: Option<JoinHandle<()>>,
    addr: std::net::SocketAddr,
    /// Periodic checkpoint writer ([`ParamServer::enable_checkpointing`]).
    checkpoint_thread: Option<JoinHandle<()>>,
    /// Where the final on-shutdown checkpoint goes (taken once, so a
    /// `shutdown` followed by `Drop` writes it exactly once).
    checkpoint_path: Option<PathBuf>,
}

/// Cheap handle for clients: address + shared-state observability.
#[derive(Clone)]
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Wire-path counters of the shard behind this handle.
    pub fn wire_stats(&self) -> WireStats {
        wire_stats(&self.shared)
    }
}

fn wire_stats(shared: &Shared) -> WireStats {
    WireStats {
        reply_cache_hits: shared.reply_cache.hits.get(),
        reply_cache_builds: shared.reply_cache.builds.get(),
        reply_cache_entries: lock_or_die(&shared.reply_cache.entries, "reply_cache.entries").len(),
        ingress_bytes: shared.ingress_bytes.get(),
        pool: shared.pool.stats(),
        codecs: shared.codec_stats.snapshot(),
    }
}

impl ParamServer {
    /// Start a shard on an ephemeral loopback port. `layers` maps layer id
    /// to its initial flat parameters. Server→worker replies are shaped
    /// with a fresh shaper per accepted connection when `shaper` is given
    /// (the downlink half of each worker's emulated edge link;
    /// worker→server requests are shaped on the worker side).
    pub fn start(
        cfg: ServerConfig,
        layers: HashMap<usize, Vec<f32>>,
        shaper: Option<ShaperSpec>,
    ) -> Result<ParamServer> {
        ParamServer::start_with(cfg, layers, shaper, ServerOptions::default())
    }

    /// [`ParamServer::start`] with explicit [`ServerOptions`]: the sync
    /// policy (BSP barrier / bounded-staleness SSP / async ASP) and the
    /// handler-pool cap.
    pub fn start_with(
        cfg: ServerConfig,
        layers: HashMap<usize, Vec<f32>>,
        shaper: Option<ShaperSpec>,
        opts: ServerOptions,
    ) -> Result<ParamServer> {
        let init = layers
            .into_iter()
            .map(|(l, p)| (l, (slab::from_f32s(&p), 0u64)))
            .collect();
        ParamServer::start_inner(cfg, init, shaper, opts, &[])
    }

    /// Start a shard resuming from a [`Checkpoint`] (`--restore <path>`,
    /// `docs/FAULTS.md`): parameter slabs and version clocks are adopted
    /// **byte-identically** and the sync policy's per-worker clocks are
    /// re-imported, so reconnecting workers continue at the iteration the
    /// checkpoint captured instead of resetting training. The checkpoint's
    /// sync configuration must match the shard's — resuming an SSP run
    /// under a different consistency model has no sound meaning.
    pub fn start_restored(
        cfg: ServerConfig,
        shaper: Option<ShaperSpec>,
        opts: ServerOptions,
        ck: &Checkpoint,
    ) -> Result<ParamServer> {
        anyhow::ensure!(
            ck.sync_mode == opts.sync.mode
                && ck.staleness_bound == opts.sync.staleness_bound,
            "checkpoint was taken under sync {} (bound {}) but the shard is \
             configured {} (bound {}) — restore with the original sync config",
            ck.sync_mode.name(),
            ck.staleness_bound,
            opts.sync.mode.name(),
            opts.sync.staleness_bound
        );
        let mut init = HashMap::with_capacity(ck.layers.len());
        for r in &ck.layers {
            anyhow::ensure!(
                r.params.len() % slab::ELEM == 0,
                "restored layer {} slab length {} is not f32-aligned",
                r.layer,
                r.params.len()
            );
            anyhow::ensure!(
                init.insert(r.layer as usize, (r.params.clone(), r.version)).is_none(),
                "checkpoint repeats layer {}",
                r.layer
            );
        }
        ParamServer::start_inner(cfg, init, shaper, opts, &ck.clocks)
    }

    fn start_inner(
        cfg: ServerConfig,
        layers: HashMap<usize, (Vec<u8>, u64)>,
        shaper: Option<ShaperSpec>,
        opts: ServerOptions,
        clocks: &[(u32, u64)],
    ) -> Result<ParamServer> {
        opts.sync.validate()?;
        let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
        let addr = listener.local_addr()?;
        let layer_bytes: HashMap<usize, usize> =
            layers.iter().map(|(&l, (p, _))| (l, p.len())).collect();
        let slots = layers
            .into_iter()
            .map(|(l, (params, version))| {
                let n = params.len() / slab::ELEM;
                (
                    l,
                    (
                        Mutex::new(LayerSlot {
                            params,
                            version,
                            grad_sum: vec![0.0; n],
                            grad_count: 0,
                            pending_iter: version,
                        }),
                        Condvar::new(),
                    ),
                )
            })
            .collect();
        let sync = sync::create(opts.sync);
        sync.import_clocks(clocks);
        // One inst for all of this server instance's series, so a scrape
        // can join them per shard.
        let inst = crate::obs::next_inst();
        let shared = Arc::new(Shared {
            cfg,
            sync,
            // Never cap below the registered fleet: `workers` handlers can
            // all be parked at the barrier at once, and a smaller pool
            // would wedge training with the rest of the fleet stuck in the
            // accept backlog (see [`ServerOptions::handler_threads`]).
            handler_threads: opts.handler_threads.max(cfg.workers).max(1),
            apply_events: crate::obs_counter!("dynacomm_server_apply_events_total", "", inst),
            live_handlers: AtomicU32::new(0),
            slots,
            layer_bytes,
            pool: SlabPool::new(),
            reply_cache: ReplyCache::new("server"),
            registry: Mutex::new(Registry { peers: HashMap::new(), departed: 0 }),
            ingress_bytes: crate::obs_counter!("dynacomm_server_ingress_bytes_total", "", inst),
            codec_stats: CodecStatsTable::new(),
            shutting_down: AtomicBool::new(false),
            connected: AtomicU32::new(0),
            pull_waiters: crate::obs_gauge!("dynacomm_server_pull_waiters", "", inst),
            pull_replies: crate::obs_counter!("dynacomm_server_pull_replies_total", "", inst),
            conns: Mutex::new(Vec::new()),
        });
        let shared2 = shared.clone();
        let listener_thread = std::thread::Builder::new()
            .name(format!("ps-accept-{}", addr.port()))
            .spawn(move || accept_loop(listener, shared2, shaper))?;
        Ok(ParamServer {
            shared,
            listener_thread: Some(listener_thread),
            addr,
            checkpoint_thread: None,
            checkpoint_path: None,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { addr: self.addr, shared: self.shared.clone() }
    }

    /// Read back the current parameters of a layer (test/eval support).
    pub fn snapshot(&self, layer: usize) -> Option<Vec<f32>> {
        let (m, _) = self.shared.slots.get(&layer)?;
        Some(slab::to_f32s(&lock_or_die(m, "layer.slot").params))
    }

    /// Number of pulls currently parked waiting for a version bump.
    pub fn pull_waiters(&self) -> u32 {
        self.shared.pull_waiters.get() as u32
    }

    /// The shard's synchronization mode.
    pub fn sync_mode(&self) -> SyncMode {
        self.shared.sync.mode()
    }

    /// Pulls currently parked inside the sync policy's staleness gate
    /// (SSP); 0 elsewhere.
    pub fn sync_waiters(&self) -> u32 {
        self.shared.sync.waiters()
    }

    /// The slowest registered worker's iteration clock, as the sync
    /// policy tracks it (0 under BSP, which keeps no clocks).
    pub fn slowest_worker_iter(&self) -> u64 {
        self.shared.sync.slowest()
    }

    /// Immediate-mode apply events so far (SSP/ASP; 0 under BSP).
    pub fn apply_events(&self) -> u64 {
        self.shared.apply_events.get()
    }

    /// Handler threads currently alive (bounded by
    /// [`ServerOptions::handler_threads`]).
    pub fn live_handlers(&self) -> u32 {
        self.shared.live_handlers.load(Ordering::SeqCst)
    }

    /// Wire-path counters (reply cache + pool).
    pub fn wire_stats(&self) -> WireStats {
        wire_stats(&self.shared)
    }

    /// Serialize the shard's current durable state — every owned layer's
    /// parameter slab + version clock plus the sync policy's worker
    /// clocks — to `path` (atomic tmp+rename write). Each layer is
    /// captured under its own slot lock; for the byte-identical restore
    /// guarantee, checkpoint a quiesced shard (shutdown does).
    pub fn write_checkpoint(&self, path: &Path) -> Result<()> {
        export_checkpoint(&self.shared).write_to(path)
    }

    /// Start writing periodic checkpoints of this shard to `path` every
    /// `every` (plus a final one on shutdown). The writer thread is joined
    /// by [`ParamServer::shutdown`].
    pub fn enable_checkpointing(&mut self, path: PathBuf, every: Duration) {
        let shared = self.shared.clone();
        let target = path.clone();
        self.checkpoint_path = Some(path);
        self.checkpoint_thread = Some(std::thread::spawn(move || {
            let mut last = Instant::now();
            while !shared.shutting_down.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(2));
                if last.elapsed() >= every {
                    if let Err(e) = export_checkpoint(&shared).write_to(&target) {
                        crate::debug!("ps", "periodic checkpoint failed: {e:#}");
                    }
                    last = Instant::now();
                }
            }
        }));
    }

    /// Drain and stop: wake parked pulls and cache waiters, kill live
    /// worker sockets so blocked reads return, then join the accept loop
    /// (which joins every handler). Condition-based — no timing
    /// assumptions.
    pub fn shutdown(&mut self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake every parked pull so its handler observes the flag.
        for (m, cv) in self.shared.slots.values() {
            let _guard = lock_or_die(m, "layer.slot");
            cv.notify_all();
        }
        // Wake pulls parked inside the sync policy's staleness gate.
        self.shared.sync.interrupt();
        // Wake pullers waiting on an in-flight reply assembly.
        {
            let _entries = lock_or_die(&self.shared.reply_cache.entries, "reply_cache.entries");
            self.shared.reply_cache.ready.notify_all();
        }
        // Kill live worker connections: blocked recv()s fail immediately
        // instead of waiting for the peer to hang up.
        for slot in lock_or_die(&self.shared.conns, "server.conns").iter_mut() {
            if let Some(stream) = slot.take() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        // Unblock the accept loop with a dummy connection, then join it;
        // it joins the handler threads, so return == fully drained.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        // Handlers are drained — the shard is quiesced — so the final
        // checkpoint captures a consistent, restorable state.
        if let Some(t) = self.checkpoint_thread.take() {
            let _ = t.join();
        }
        if let Some(path) = self.checkpoint_path.take() {
            if let Err(e) = export_checkpoint(&self.shared).write_to(&path) {
                crate::debug!("ps", "final checkpoint failed: {e:#}");
            }
        }
    }
}

impl Drop for ParamServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, shaper: Option<ShaperSpec>) {
    let mut handlers = Vec::new();
    // The shard's node lane in the merged fleet trace: derived from the
    // bound port, so no config plumbing is needed to tell shards apart.
    let node = format!(
        "shard-{}",
        listener.local_addr().map(|a| a.port()).unwrap_or(0)
    );
    loop {
        // Bounded handler pool: never hold more than `handler_threads`
        // live handlers. At the cap, stop accepting — further connections
        // queue in the kernel backlog (and the OS refuses them once it
        // fills), so an over-subscribed shard pushes back instead of
        // spawning a thread per peer. The reap below doubles as the slot
        // wait.
        loop {
            // Reap finished handler threads so the handle list stays
            // bounded by the number of *live* connections.
            handlers.retain(|h: &std::thread::JoinHandle<()>| !h.is_finished());
            if handlers.len() < shared.handler_threads
                || shared.shutting_down.load(Ordering::SeqCst)
            {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let Ok((stream, _)) = listener.accept() else { break };
        // Every handled connection MUST be in the kill registry, or a
        // quiet peer could block shutdown's join forever; refuse the
        // connection if the registry clone cannot be made.
        let Ok(dup) = stream.try_clone() else {
            drop(stream);
            continue;
        };
        // Register BEFORE checking the flag: shutdown() sets the flag and
        // then drains the registry, so either the drain sees this entry
        // (and kills it), or the flag check below observes true (and this
        // arm kills it) — no window where an unregistered handler can
        // block shutdown's join. Freed slots are reused so a long-lived
        // shard doesn't grow the registry per reconnect.
        let conn_id = {
            let mut conns = lock_or_die(&shared.conns, "server.conns");
            match conns.iter_mut().position(|slot| slot.is_none()) {
                Some(i) => {
                    conns[i] = Some(dup);
                    i
                }
                None => {
                    conns.push(Some(dup));
                    conns.len() - 1
                }
            }
        };
        if shared.shutting_down.load(Ordering::SeqCst) {
            let _ = stream.shutdown(Shutdown::Both);
            break;
        }
        let shared2 = shared.clone();
        let shaper = shaper.map(|s| s.build());
        let node2 = node.clone();
        shared.live_handlers.fetch_add(1, Ordering::SeqCst);
        // Named handler threads so their span rings key stably and group
        // into the shard's node lane in the merged trace.
        let spawned = std::thread::Builder::new()
            .name(format!("{node}-h{conn_id}"))
            .spawn(move || {
                crate::obs::trace::adopt_node(&node2);
                let conn = Connection::new(stream, shaper);
                if let Err(e) = handle_conn(conn, &shared2) {
                    crate::debug!("ps", "handler exit: {e:#}");
                }
                // Free the registry slot (drops the duplicate fd) for reuse.
                lock_or_die(&shared2.conns, "server.conns")[conn_id] = None;
                shared2.live_handlers.fetch_sub(1, Ordering::SeqCst);
            });
        match spawned {
            Ok(h) => handlers.push(h),
            Err(e) => {
                // The closure never ran: undo its bookkeeping here.
                crate::debug!("ps", "handler spawn failed: {e}");
                lock_or_die(&shared.conns, "server.conns")[conn_id] = None;
                shared.live_handlers.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// Assemble the `[lo, hi]` reply slab into a pooled buffer — each owned
/// layer's params encoded by the session `codec`, concatenated — honoring
/// the sync policy's `gate`: `WaitFor` parks on the version condvars until
/// the clock gets there (the BSP barrier), `Fresh` encodes whatever is
/// applied right now. Returns the slab plus the snapshot's `applied`
/// iteration (the min applied version among the served layers) and the
/// assembly's span id (0 when tracing is disarmed; the reply-direction
/// trace context points at it), or `None` when shutdown interrupts the
/// wait.
// dynalint: hot-path
fn assemble_reply(
    shared: &Shared,
    gate: PullGate,
    lo: u32,
    hi: u32,
    codec_id: CodecId,
) -> Option<(Arc<PooledSlab>, u64, u32)> {
    let sp = crate::obs::trace::span(crate::obs::trace::SPAN_ASSEMBLE);
    // Pre-size from the immutable size map: one pooled checkout, then pure
    // per-layer codec appends under the slot locks (fp32 encodes as a bulk
    // `extend_from_slice`, so the uncompressed path is unchanged).
    let wc = codec_id.codec();
    let cap: usize = (lo as usize..=hi as usize)
        .filter_map(|l| shared.layer_bytes.get(&l))
        .map(|&b| wc.wire_len(b))
        .sum();
    let mut data = shared.pool.checkout(cap);
    let (mut raw_total, mut enc_ns, mut max_err) = (0usize, 0u64, 0.0f32);
    let mut applied = u64::MAX;
    for l in lo as usize..=hi as usize {
        let Some((m, cv)) = shared.slots.get(&l) else { continue };
        let mut slot = lock_or_die(m, "layer.slot");
        if let PullGate::WaitFor { min } = gate {
            while slot.version < min {
                if shared.shutting_down.load(Ordering::SeqCst) {
                    return None;
                }
                // Condition-based park: woken by the push that advances
                // the version, or by shutdown.
                shared.pull_waiters.add(1.0);
                let woken = wait_or_die(cv, slot, "layer.slot");
                shared.pull_waiters.add(-1.0);
                slot = woken;
            }
        }
        applied = applied.min(slot.version);
        let t0 = Instant::now();
        let err = wc.encode(&slot.params, &mut data);
        enc_ns += t0.elapsed().as_nanos() as u64;
        raw_total += slot.params.len();
        max_err = max_err.max(err);
    }
    if applied == u64::MAX {
        // No owned layers in range: report the gate's own clock.
        applied = match gate {
            PullGate::WaitFor { min } => min,
            PullGate::Fresh => 0,
        };
    }
    shared
        .codec_stats
        .record_encode(codec_id, raw_total, data.len(), enc_ns, max_err);
    Some((data.freeze(), applied, sp.id()))
}

/// Serve a pull from the shared broadcast cache, assembling at most once
/// per `(key_iter, lo, hi, codec)` across all concurrent pullers
/// (single-flight). Returns `None` only on shutdown.
// dynalint: hot-path
fn pull_reply(
    shared: &Shared,
    key_iter: u64,
    gate: PullGate,
    lo: u32,
    hi: u32,
    codec_id: CodecId,
) -> Option<(Arc<PooledSlab>, u64, u32)> {
    /// Snapshot of a cache entry's state, owned (no borrow spans the
    /// condvar wait or the insert below).
    enum Peek {
        Hit(Arc<PooledSlab>, u64, u32),
        Wait,
        Vacant,
    }

    let key = (key_iter, lo, hi, codec_id);
    let cache = &shared.reply_cache;
    let mut entries = lock_or_die(&cache.entries, "reply_cache.entries");
    loop {
        if shared.shutting_down.load(Ordering::SeqCst) {
            return None;
        }
        let peek = match entries.get(&key) {
            // dynalint: allow(alloc, Arc refcount bump on the cached slab, not a byte copy)
            Some(ReplyState::Ready(slab, applied, aspan)) => {
                Peek::Hit(slab.clone(), *applied, *aspan)
            }
            Some(ReplyState::Building) => Peek::Wait,
            None => Peek::Vacant,
        };
        match peek {
            Peek::Hit(slab, applied, aspan) => {
                cache.hits.inc();
                return Some((slab, applied, aspan));
            }
            Peek::Wait => {
                // Another handler is assembling this exact reply; wait for
                // it instead of duplicating the work.
                entries = wait_or_die(&cache.ready, entries, "reply_cache.entries");
            }
            Peek::Vacant => {
                entries.insert(key, ReplyState::Building);
                drop(entries);
                let built = assemble_reply(shared, gate, lo, hi, codec_id);
                let mut relocked = lock_or_die(&cache.entries, "reply_cache.entries");
                let out = match built {
                    Some((slab, applied, aspan)) => {
                        cache.builds.inc();
                        // dynalint: allow(alloc, Arc refcount bump shares the slab with the cache)
                        relocked.insert(key, ReplyState::Ready(slab.clone(), applied, aspan));
                        // In-flight pulls stay within one key of each other
                        // (BSP: one iteration; SSP/ASP: one apply event);
                        // drop finished keys' slabs back to the pool so the
                        // cache stays O(segments). `Building` markers are
                        // never evicted — removing one would break
                        // single-flight: its waiters would see the slot
                        // vacant and start a duplicate assembly. A stale
                        // `Ready` entry a lagging builder re-inserts
                        // survives at most until the next build sweeps it.
                        relocked.retain(|k, v| {
                            matches!(v, ReplyState::Building) || k.0 + 1 >= key_iter
                        });
                        Some((slab, applied, aspan))
                    }
                    None => {
                        // Interrupted by shutdown: clear the Building
                        // marker so waiters don't park forever.
                        relocked.remove(&key);
                        None
                    }
                };
                drop(relocked);
                cache.ready.notify_all();
                return out;
            }
        }
    }
}

/// The full pull path: ask the sync policy to admit the request (which may
/// park — the SSP staleness gate), derive the broadcast-cache key its gate
/// implies, and serve from the shared cache. Returns `None` on shutdown.
// dynalint: hot-path
fn serve_pull(
    shared: &Shared,
    worker: Option<u32>,
    iter: u64,
    lo: u32,
    hi: u32,
    codec_id: CodecId,
) -> Option<(Arc<PooledSlab>, u64, u32)> {
    let gate = shared.sync.admit_pull(worker, iter, &shared.shutting_down)?;
    let key_iter = match gate {
        // The barrier makes replies byte-identical per iteration — the
        // historical BSP key.
        PullGate::WaitFor { min } => min,
        // Fresh snapshots change with every apply: key by the apply-event
        // counter so pulls between applies still share one assembly.
        PullGate::Fresh => shared.apply_events.get(),
    };
    let out = pull_reply(shared, key_iter, gate, lo, hi, codec_id);
    if out.is_some() {
        shared.pull_replies.inc();
    }
    out
}

/// Collect the shard's durable state ([`Checkpoint`]): owned layers in
/// ascending order (slab + version, each under its slot lock) plus the
/// sync policy's exported worker clocks.
fn export_checkpoint(shared: &Shared) -> Checkpoint {
    let mut ids: Vec<usize> = shared.slots.keys().copied().collect();
    ids.sort_unstable();
    let layers = ids
        .into_iter()
        .map(|l| {
            let (m, _) = &shared.slots[&l];
            let slot = lock_or_die(m, "layer.slot");
            LayerRecord {
                layer: l as u32,
                version: slot.version,
                params: slot.params.clone(),
            }
        })
        .collect();
    Checkpoint {
        sync_mode: shared.sync.mode(),
        staleness_bound: shared.sync.staleness_bound(),
        clocks: shared.sync.export_clocks(),
        layers,
    }
}

/// The BSP barrier threshold right now: the configured fleet minus every
/// fully departed identity's weight, floored at 1 so a shard with only
/// departures left cannot divide training by zero. Callers read it
/// *before* taking any `layer.slot` lock (declared order: the registry
/// sits above the slots).
fn barrier_target(shared: &Shared) -> usize {
    let departed = lock_or_die(&shared.registry, "server.registry").departed as usize;
    shared.cfg.workers.saturating_sub(departed).max(1)
}

/// Record a registered identity (weight 1 for a `Hello` worker, the group
/// worker-count for an `AggHello` aggregator). Returns `true` when this is
/// the identity's first live session — only then does the sync policy see
/// a registration (an aggregator's pull and push connections share one
/// clock). A returning identity re-arms the barrier weight it released on
/// departure (elastic membership).
fn register_identity(shared: &Shared, id: u32, weight: u32) -> bool {
    let mut reg = lock_or_die(&shared.registry, "server.registry");
    match reg.peers.get_mut(&id) {
        Some(entry) => {
            entry.1 += 1;
            false
        }
        None => {
            reg.departed = reg.departed.saturating_sub(weight);
            reg.peers.insert(id, (weight, 1));
            true
        }
    }
}

/// A registered session ended. When the identity's *last* session is gone
/// its weight moves to `departed` (shrinking the BSP barrier), the sync
/// policy drops its clock, and any barrier the departure just satisfied
/// fires — a peer that hung up mid-iteration must not stall the
/// survivors forever (`docs/TOPOLOGY.md`).
fn deregister_identity(shared: &Shared, id: u32) {
    let fully_departed = {
        let mut reg = lock_or_die(&shared.registry, "server.registry");
        match reg.peers.get_mut(&id) {
            Some(entry) if entry.1 > 1 => {
                entry.1 -= 1;
                false
            }
            Some(_) => {
                let (weight, _) = reg.peers.remove(&id).expect("entry just matched");
                reg.departed += weight;
                true
            }
            None => false,
        }
    };
    if fully_departed {
        shared.sync.deregister_worker(id);
        release_satisfied_barriers(shared);
    }
}

/// After a departure shrinks the barrier target, any slot whose
/// accumulated weight already meets the new target applies its pending
/// gradients and advances the version clock; every version waiter is
/// woken either way to re-check its predicate. Only the BSP barrier ever
/// leaves `grad_count > 0` (immediate modes zero it on every apply), so
/// this is a no-op under SSP/ASP.
fn release_satisfied_barriers(shared: &Shared) {
    let target = barrier_target(shared);
    let scale = shared.cfg.lr / shared.cfg.workers as f32;
    for (m, cv) in shared.slots.values() {
        let mut slot = lock_or_die(m, "layer.slot");
        if slot.grad_count > 0 && slot.grad_count >= target {
            slot.apply_sgd(scale);
            slot.version = slot.pending_iter + 1;
        }
        cv.notify_all();
    }
}

/// Consume a pushed gradient slab (borrowed straight from the receive
/// scratch, decoded by the codec the frame is tagged with — per layer, so
/// the offsets come from the immutable size map) the way the sync policy
/// decided: `Barrier` accumulates `weight` contributions (1 for a worker,
/// the group size for an aggregator's combined push) and applies averaged
/// SGD + advances the BSP clock once the barrier target is met;
/// `Immediate` applies this gradient now (scaled `lr / workers`) and
/// bumps the apply-event counter so the next fresh pull re-assembles.
// dynalint: hot-path
fn apply_push(
    shared: &Shared,
    apply: PushApply,
    iter: u64,
    lo: u32,
    hi: u32,
    codec_id: CodecId,
    data: &[u8],
    weight: u32,
    ctx: Option<TraceCtx>,
) -> Result<()> {
    let wc = codec_id.codec();
    // Read the elastic barrier target before any slot lock (lock order);
    // `>=` because a shrinking target can leave an accumulator past it.
    let target = barrier_target(shared);
    let scale = shared.cfg.lr / shared.cfg.workers as f32;
    let mut sp = crate::obs::trace::span(crate::obs::trace::SPAN_APPLY);
    if let Some(c) = ctx {
        if !c.is_reply() {
            // Push direction is ack-synchronous, so this apply nests
            // inside the sender's span window: a containment parent.
            sp.set_remote_parent(c.parent_span);
        }
    }
    shared.ingress_bytes.add(data.len() as u64);
    let mut off = 0usize;
    let (mut raw_total, mut dec_ns) = (0usize, 0u64);
    for l in lo as usize..=hi as usize {
        let Some((m, cv)) = shared.slots.get(&l) else { continue };
        let mut slot = lock_or_die(m, "layer.slot");
        let n = wc.wire_len(slot.params.len());
        anyhow::ensure!(
            off + n <= data.len(),
            "push payload too small for layers {lo}..={hi}"
        );
        // Decode-accumulate straight off the wire slab (fp32 degenerates
        // to the bulk add of the uncompressed path).
        let t0 = Instant::now();
        wc.accumulate(&mut slot.grad_sum, &data[off..off + n])?;
        dec_ns += t0.elapsed().as_nanos() as u64;
        raw_total += slot.params.len();
        off += n;
        match apply {
            PushApply::Barrier => {
                slot.grad_count += weight as usize;
                slot.pending_iter = iter;
                if slot.grad_count >= target {
                    // Averaged SGD, then advance the BSP clock.
                    slot.apply_sgd(scale);
                    slot.version = iter + 1;
                    cv.notify_all();
                }
            }
            PushApply::Immediate => {
                // The accumulator held only this push (it is zeroed by
                // every apply), so the same averaged step applies it alone.
                slot.apply_sgd(scale);
                // Clocks never move backwards: a straggler's late push for
                // an old iteration still applies, but cannot rewind the
                // version a faster worker already advanced.
                slot.version = slot.version.max(iter + 1);
                cv.notify_all();
            }
        }
    }
    anyhow::ensure!(off == data.len(), "push payload size mismatch");
    if apply == PushApply::Immediate {
        shared.apply_events.inc();
    }
    shared.codec_stats.record_decode(codec_id, raw_total, off, dec_ns);
    Ok(())
}

/// What a received message asks the handler to do once the receive borrow
/// is released (replies are sent outside the borrow of the recv scratch).
enum Action {
    Hello { worker: u32, version: u16 },
    AggHello { role: PeerRole, group: u32, workers: u32, version: u16 },
    Reply(Message),
    ReplyShared {
        iter: u64,
        lo: u32,
        hi: u32,
        applied: u64,
        slab: Arc<PooledSlab>,
        /// Span id of the assembly serving this reply (0 = untraced):
        /// sent as the reply-direction trace context.
        aspan: u32,
    },
    ReplySnapshot { iter: u64, lo: u32, hi: u32, slab: Arc<PooledSlab> },
    /// Answer a clock probe: `t1` echoed, `t2` stamped at decode; `t3` is
    /// stamped at the send itself so it excludes handler queueing.
    ReplyClock { t1: u64, t2: u64 },
    Close,
}

fn handle_conn(mut conn: Connection, shared: &Shared) -> Result<()> {
    // The session's negotiated wire codec: fp32 until the worker proposes
    // otherwise (so sessions that never negotiate behave exactly like v2
    // ones). Replies are encoded with it; pushes are decoded by the codec
    // their frame is tagged with.
    let mut session_codec = CodecId::Fp32;
    // The identity this session registered as (`Hello` worker id or
    // `AggHello` group id): what the sync policy's per-worker clocks and
    // the barrier-weight registry key on. Anonymous sessions are served
    // but never gate anyone.
    let mut session_worker: Option<u32> = None;
    // Barrier weight of this session's pushes: 1 for a worker, the group
    // worker-count for a regional aggregator's combined pushes.
    let mut session_weight: u32 = 1;
    let result = handle_conn_inner(
        &mut conn,
        shared,
        &mut session_codec,
        &mut session_worker,
        &mut session_weight,
    );
    // However the session ends, its clock must stop gating SSP peers and
    // its weight must stop holding the BSP barrier open.
    if let Some(w) = session_worker {
        deregister_identity(shared, w);
    }
    result
}

// dynalint: hot-path
fn handle_conn_inner(
    conn: &mut Connection,
    shared: &Shared,
    session_codec: &mut CodecId,
    session_worker: &mut Option<u32>,
    session_weight: &mut u32,
) -> Result<()> {
    loop {
        let action = {
            let (msg, ctx) = match conn.recv_ref_ctx() {
                Ok(m) => m,
                // Peer hung up (or shutdown killed the socket): normal
                // teardown.
                Err(_) => return Ok(()),
            };
            match msg {
                MessageRef::Hello { worker, version } => Action::Hello { worker, version },
                MessageRef::AggHello { role, group, workers, version } => {
                    Action::AggHello { role, group, workers, version }
                }
                MessageRef::CodecPropose { pref } => {
                    // First supported preference wins; fp32 is the
                    // mandatory fallback, so mixed fleets keep training.
                    *session_codec = codec::negotiate(&[pref], &codec::SUPPORTED);
                    Action::Reply(Message::CodecAgree { codec: *session_codec })
                }
                MessageRef::SyncPropose { .. } => {
                    // Unlike codecs there is no safe fallback between
                    // consistency models: answer with the shard's own
                    // configuration and let the worker refuse a mismatch.
                    Action::Reply(Message::SyncAgree {
                        mode: shared.sync.mode(),
                        bound: shared.sync.staleness_bound(),
                    })
                }
                MessageRef::Pull { iter, lo, hi } => {
                    match serve_pull(shared, *session_worker, iter, lo, hi, *session_codec) {
                        Some((slab, applied, aspan)) => {
                            Action::ReplyShared { iter, lo, hi, applied, slab, aspan }
                        }
                        // Shutting down: no reply, drop the session.
                        None => Action::Close,
                    }
                }
                MessageRef::Push { iter, lo, hi, codec, data } => {
                    // Gradients are consumed borrowed — no payload copy —
                    // decoded by the frame's own codec tag, applied as the
                    // sync policy decides (barrier vs immediate). The
                    // frame's trace context (if any) parents the apply
                    // span to the sender's push/forward span.
                    let apply = shared.sync.on_push(*session_worker, iter);
                    apply_push(shared, apply, iter, lo, hi, codec, data, *session_weight, ctx)?;
                    Action::Reply(Message::PushAck { iter, lo, hi })
                }
                MessageRef::ClockProbe { t1 } => {
                    // Answered ungated — a probe must never park at a
                    // barrier, or it would measure the sync policy instead
                    // of the clock.
                    Action::ReplyClock { t1, t2: crate::obs::trace::now_ns() }
                }
                MessageRef::SnapshotReq { lo, hi } => {
                    // Mid-run join (`docs/FAULTS.md`): serve the freshest
                    // applied state ungated — the joiner is not yet part
                    // of any barrier, so nothing may park this request —
                    // with the shard's clock so it enters at the right
                    // iteration. Rare (once per join), so assembling
                    // outside the broadcast cache is fine.
                    match assemble_reply(shared, PullGate::Fresh, lo, hi, *session_codec)
                    {
                        Some((slab, applied, _)) => {
                            Action::ReplySnapshot { iter: applied, lo, hi, slab }
                        }
                        None => Action::Close,
                    }
                }
                MessageRef::Shutdown => Action::Close,
                other => {
                    anyhow::bail!("unexpected message at server: {:?}", other.into_owned())
                }
            }
        };
        match action {
            Action::Hello { worker, version } => {
                // Always answer with our version — on mismatch the worker
                // names both sides in its error — then refuse the session
                // so a mixed deployment cannot corrupt tensors later.
                conn.send(&Message::HelloAck {
                    workers: shared.cfg.workers as u32,
                    version: PROTOCOL_VERSION,
                })?;
                anyhow::ensure!(
                    version == PROTOCOL_VERSION,
                    "protocol version mismatch: worker {worker} speaks \
                     v{version}, server v{PROTOCOL_VERSION}"
                );
                *session_worker = Some(worker);
                *session_weight = 1;
                if register_identity(shared, worker, 1) {
                    shared.sync.register_worker(worker);
                }
                shared.connected.fetch_add(1, Ordering::SeqCst);
            }
            Action::AggHello { role, group, workers, version } => {
                // Same contract as `Hello`: always answer with our
                // version, then refuse a mismatched session.
                conn.send(&Message::HelloAck {
                    workers: shared.cfg.workers as u32,
                    version: PROTOCOL_VERSION,
                })?;
                anyhow::ensure!(
                    version == PROTOCOL_VERSION,
                    "protocol version mismatch: {} {group} speaks \
                     v{version}, server v{PROTOCOL_VERSION}",
                    role.name()
                );
                // An aggregator's sessions (pull + push connections)
                // share one identity: the sync policy sees one clock, the
                // barrier registry counts the group weight once.
                *session_worker = Some(group);
                *session_weight = workers;
                if register_identity(shared, group, workers) {
                    shared.sync.register_worker(group);
                }
                shared.connected.fetch_add(1, Ordering::SeqCst);
            }
            Action::Reply(m) => conn.send(&m)?,
            Action::ReplyShared { iter, lo, hi, applied, slab, aspan } => {
                // The cached slab goes out borrowed, scatter-gather — the
                // broadcast bytes are written once per worker but copied
                // zero times. When traced, the reply carries an arrow-only
                // context pointing at the assembly span (reply windows do
                // not nest inside the puller's).
                let ctx = if aspan != 0 {
                    Some(TraceCtx::reply(crate::obs::trace::trace_id_for(iter), aspan))
                } else {
                    None
                };
                conn.send_ref_ctx(
                    MessageRef::PullReply {
                        iter,
                        lo,
                        hi,
                        applied,
                        codec: *session_codec,
                        data: &slab[..],
                    },
                    ctx,
                )?;
            }
            Action::ReplySnapshot { iter, lo, hi, slab } => {
                // Floor at 1: the frame's fleet size is malformed at 0,
                // matching the barrier-target floor.
                conn.send_ref(MessageRef::SnapshotReply {
                    iter,
                    lo,
                    hi,
                    workers: (shared.cfg.workers as u32).max(1),
                    codec: *session_codec,
                    data: &slab[..],
                })?;
            }
            Action::ReplyClock { t1, t2 } => {
                conn.send(&Message::ClockReply {
                    t1,
                    t2,
                    t3: crate::obs::trace::now_ns(),
                })?;
            }
            Action::Close => return Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Barrier;
    use std::time::{Duration, Instant};

    fn connect(addr: std::net::SocketAddr) -> Connection {
        Connection::new(TcpStream::connect(addr).unwrap(), None)
    }

    fn start_two_layer(workers: usize) -> ParamServer {
        let mut layers = HashMap::new();
        layers.insert(0, vec![1.0f32, 2.0]);
        layers.insert(1, vec![10.0f32]);
        ParamServer::start(ServerConfig { workers, lr: 0.5 }, layers, None).unwrap()
    }

    /// Poll a condition with a hard deadline — condition-based waiting
    /// without the old fixed-sleep timing assumptions.
    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn pull_initial_params() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { data, .. } => {
                assert_eq!(slab::to_f32s(&data), vec![1.0, 2.0, 10.0])
            }
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn push_applies_averaged_sgd() {
        let srv = start_two_layer(2);
        let mut a = connect(srv.handle().addr);
        let mut b = connect(srv.handle().addr);
        // Worker A pushes grad [2, 0] for layer 0; worker B pushes [0, 4].
        a.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[2.0, 0.0]),
        })
        .unwrap();
        assert!(matches!(a.recv().unwrap(), Message::PushAck { .. }));
        // Not applied yet (1 of 2 workers).
        assert_eq!(srv.snapshot(0).unwrap(), vec![1.0, 2.0]);
        b.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[0.0, 4.0]),
        })
        .unwrap();
        assert!(matches!(b.recv().unwrap(), Message::PushAck { .. }));
        // w -= 0.5 * avg = 0.5*[1,2] ⇒ [0.5, 1.0].
        assert_eq!(srv.snapshot(0).unwrap(), vec![0.5, 1.0]);
    }

    #[test]
    fn pull_blocks_until_version_advances() {
        let srv = start_two_layer(1);
        let addr = srv.handle().addr;
        let t = std::thread::spawn(move || {
            let mut c = connect(addr);
            // iteration 1 params are only available after the iter-0 push.
            c.send(&Message::Pull { iter: 1, lo: 0, hi: 0 }).unwrap();
            c.recv().unwrap()
        });
        // Condition-based: wait until the server has actually parked the
        // pull on the version condvar (no fixed sleeps, no timing asserts).
        wait_until("pull to park", || srv.pull_waiters() > 0);
        let mut p = connect(addr);
        p.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[2.0, 2.0]),
        })
        .unwrap();
        p.recv().unwrap();
        match t.join().unwrap() {
            Message::PullReply { data, .. } => {
                assert_eq!(slab::to_f32s(&data), vec![0.0, 1.0])
            }
            m => panic!("{m:?}"),
        }
    }

    /// The shared-broadcast contract: K concurrent pullers of the same
    /// `(iter, lo, hi)` trigger exactly one assembly; the other K−1 are
    /// cache hits, and everyone gets byte-identical data.
    #[test]
    fn concurrent_pulls_share_one_assembly() {
        const K: usize = 4;
        let srv = start_two_layer(1);
        let addr = srv.handle().addr;
        let barrier = Arc::new(Barrier::new(K));
        let mut threads = Vec::new();
        for _ in 0..K {
            let barrier = barrier.clone();
            threads.push(std::thread::spawn(move || {
                let mut c = connect(addr);
                barrier.wait();
                c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
                match c.recv().unwrap() {
                    Message::PullReply { data, .. } => data,
                    m => panic!("{m:?}"),
                }
            }));
        }
        let replies: Vec<Vec<u8>> =
            threads.into_iter().map(|t| t.join().unwrap()).collect();
        for r in &replies[1..] {
            assert_eq!(r, &replies[0], "broadcast bytes diverged");
        }
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 1, "reply assembled more than once");
        assert_eq!(ws.reply_cache_hits, (K - 1) as u64);
    }

    /// Steady-state pulls allocate nothing: after the first assembly per
    /// key, the pool's allocation counter stays flat and repeated pulls of
    /// the same iteration are pure cache hits.
    #[test]
    fn repeated_pulls_are_allocation_free() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        for _ in 0..10 {
            c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
            let _ = c.recv().unwrap();
        }
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 1);
        assert_eq!(ws.reply_cache_hits, 9);
        assert_eq!(ws.pool.allocations, 1, "pulls allocated past warm-up");
    }

    /// The cache is bounded: advancing the BSP clock evicts reply slabs of
    /// finished iterations (they return to the pool for reuse).
    #[test]
    fn reply_cache_evicts_finished_iterations() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        for iter in 0..4u64 {
            c.send(&Message::Pull { iter, lo: 0, hi: 1 }).unwrap();
            assert!(matches!(c.recv().unwrap(), Message::PullReply { .. }));
            c.send(&Message::Push {
                iter,
                lo: 0,
                hi: 1,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&[0.0, 0.0, 0.0]),
            })
            .unwrap();
            assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
        }
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 4);
        assert!(
            ws.reply_cache_entries <= 2,
            "stale entries retained: {}",
            ws.reply_cache_entries
        );
        // Evicted slabs were recycled, not leaked: the cache retains at
        // most two iterations, so at most three buffers ever existed (two
        // cached + one in flight before the first eviction).
        assert!(ws.pool.allocations <= 3, "allocations: {:?}", ws.pool);
    }

    #[test]
    fn shutdown_drains_parked_pulls_deterministically() {
        let mut srv = start_two_layer(1);
        let addr = srv.handle().addr;
        let t = std::thread::spawn(move || {
            let mut c = connect(addr);
            // A pull that can never be satisfied: it parks forever.
            c.send(&Message::Pull { iter: 99, lo: 0, hi: 1 }).unwrap();
            c.recv()
        });
        wait_until("pull to park", || srv.pull_waiters() > 0);
        // Shutdown must wake the parked handler and join it — if draining
        // regresses, this join hangs and the suite times out.
        srv.shutdown();
        assert_eq!(srv.pull_waiters(), 0, "handlers drained");
        // The client got a dead socket (no stale reply is served on
        // shutdown) — but the thread must have been released either way.
        let _ = t.join().unwrap();
    }

    #[test]
    fn hello_with_matching_version_registers() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Hello { worker: 0, version: PROTOCOL_VERSION }).unwrap();
        match c.recv().unwrap() {
            Message::HelloAck { workers, version } => {
                assert_eq!(workers, 1);
                assert_eq!(version, PROTOCOL_VERSION);
            }
            m => panic!("{m:?}"),
        }
        // The session stays usable.
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 0 }).unwrap();
        assert!(matches!(c.recv().unwrap(), Message::PullReply { .. }));
    }

    #[test]
    fn hello_version_mismatch_is_refused_after_naming_versions() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Hello { worker: 7, version: PROTOCOL_VERSION + 1 })
            .unwrap();
        // The server still answers with its own version (that is what lets
        // the worker report "worker v3, server v2")...
        match c.recv().unwrap() {
            Message::HelloAck { version, .. } => assert_eq!(version, PROTOCOL_VERSION),
            m => panic!("{m:?}"),
        }
        // ...then tears the session down: no cross-version serving.
        let _ = c.send(&Message::Pull { iter: 0, lo: 0, hi: 0 });
        assert!(c.recv().is_err(), "mismatched session must not be served");
    }

    #[test]
    fn ignores_unowned_layers_in_range() {
        // Shard owns layers {0, 1}; a pull of [0, 5] returns only owned data.
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 5 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { data, .. } => assert_eq!(slab::to_f32s(&data).len(), 3),
            m => panic!("{m:?}"),
        }
    }

    /// Negotiate a session codec on a fresh connection.
    fn negotiate_session(c: &mut Connection, pref: CodecId) -> CodecId {
        c.send(&Message::CodecPropose { pref }).unwrap();
        match c.recv().unwrap() {
            Message::CodecAgree { codec } => codec,
            m => panic!("{m:?}"),
        }
    }

    /// A negotiated session is served codec-encoded replies and may push
    /// codec-encoded gradients; the decoded math matches fp32 up to the
    /// codec's quantization error.
    #[test]
    fn quantized_sessions_pull_and_push() {
        for pref in [CodecId::Fp16, CodecId::Int8] {
            let srv = start_two_layer(1);
            let mut c = connect(srv.handle().addr);
            assert_eq!(negotiate_session(&mut c, pref), pref);
            c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
            let wc = pref.codec();
            match c.recv().unwrap() {
                Message::PullReply { codec, data, .. } => {
                    assert_eq!(codec, pref);
                    // Per-layer encodings: layer 0 (2 f32s) then 1 (1 f32).
                    assert_eq!(data.len(), wc.wire_len(8) + wc.wire_len(4));
                    let mut raw = Vec::new();
                    wc.decode(&data[..wc.wire_len(8)], &mut raw).unwrap();
                    wc.decode(&data[wc.wire_len(8)..], &mut raw).unwrap();
                    let vals = slab::to_f32s(&raw);
                    assert!((vals[0] - 1.0).abs() < 1e-2, "{vals:?}");
                    assert!((vals[1] - 2.0).abs() < 1e-2, "{vals:?}");
                    assert!((vals[2] - 10.0).abs() < 1e-1, "{vals:?}");
                }
                m => panic!("{m:?}"),
            }
            // Push an encoded gradient for layer 0: w -= 0.5 * [2, 2].
            let mut wire = Vec::new();
            wc.encode(&slab::from_f32s(&[2.0, 2.0]), &mut wire);
            c.send(&Message::Push { iter: 0, lo: 0, hi: 0, codec: pref, data: wire })
                .unwrap();
            assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
            let got = srv.snapshot(0).unwrap();
            assert!((got[0] - 0.0).abs() < 1e-2, "{got:?}");
            assert!((got[1] - 1.0).abs() < 1e-2, "{got:?}");
            // Counters moved: the reply was encoded, the push decoded.
            let ws = srv.wire_stats();
            let cs = ws.codec(pref);
            assert!(cs.encodes >= 1 && cs.decodes >= 1, "{cs:?}");
            assert_eq!(cs.raw_bytes, 12, "{cs:?}");
            assert_eq!(cs.wire_bytes, (wc.wire_len(8) + wc.wire_len(4)) as u64);
            assert!(cs.max_quant_error >= 0.0);
            // fp32 counters untouched by this session's tensor traffic.
            assert_eq!(ws.codec(CodecId::Fp32).encodes, 0);
        }
    }

    /// Sessions speaking different codecs each get their own single-flight
    /// reply assembly, but same-codec pullers still share one.
    #[test]
    fn reply_cache_is_keyed_per_codec() {
        let srv = start_two_layer(2);
        let mut a = connect(srv.handle().addr);
        let mut b = connect(srv.handle().addr);
        assert_eq!(negotiate_session(&mut b, CodecId::Int8), CodecId::Int8);
        for c in [&mut a, &mut b] {
            c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
            assert!(matches!(c.recv().unwrap(), Message::PullReply { .. }));
        }
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 2, "codecs must not share bytes");
        assert_eq!(ws.reply_cache_hits, 0);
        // A second int8 puller is a pure cache hit.
        let mut b2 = connect(srv.handle().addr);
        assert_eq!(negotiate_session(&mut b2, CodecId::Int8), CodecId::Int8);
        b2.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        match b2.recv().unwrap() {
            Message::PullReply { codec, .. } => assert_eq!(codec, CodecId::Int8),
            m => panic!("{m:?}"),
        }
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 2);
        assert_eq!(ws.reply_cache_hits, 1);
    }

    /// Regression: an int8 frame carrying several per-layer encodings can
    /// have a total length that is NOT a valid *single* chunked slab
    /// (layers of 1023 + 1 elements → 1031 + 9 = 1040 wire bytes, where
    /// `raw_len(1040)` has no solution). The transport must still accept
    /// the frame — per-layer framing is the endpoint's job — and the
    /// decoded layers must roundtrip.
    #[test]
    fn int8_multi_layer_frames_with_awkward_total_lengths_survive() {
        let mut layers = HashMap::new();
        let big: Vec<f32> = (0..1023).map(|i| i as f32 * 0.01).collect();
        layers.insert(0, big.clone());
        layers.insert(1, vec![5.0f32]);
        let srv =
            ParamServer::start(ServerConfig { workers: 1, lr: 0.5 }, layers, None).unwrap();
        let mut c = connect(srv.handle().addr);
        assert_eq!(negotiate_session(&mut c, CodecId::Int8), CodecId::Int8);
        let wc = CodecId::Int8.codec();
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { codec, data, .. } => {
                assert_eq!(codec, CodecId::Int8);
                let (n0, n1) = (wc.wire_len(4 * 1023), wc.wire_len(4));
                assert_eq!(data.len(), n0 + n1);
                assert!(
                    wc.raw_len(data.len()).is_err(),
                    "this regression test needs an invalid single-slab total"
                );
                let mut raw = Vec::new();
                wc.decode(&data[..n0], &mut raw).unwrap();
                wc.decode(&data[n0..], &mut raw).unwrap();
                let vals = slab::to_f32s(&raw);
                let bound = (big[1022] - big[0]) / 254.0;
                for (a, b) in vals[..1023].iter().zip(&big) {
                    assert!((a - b).abs() <= bound, "{a} vs {b}");
                }
                assert_eq!(vals[1023], 5.0, "single-element layer is exact");
            }
            m => panic!("{m:?}"),
        }
        // And the awkward-length push direction works too.
        let mut wire = Vec::new();
        wc.encode(&slab::from_f32s(&vec![0.0; 1023]), &mut wire);
        wc.encode(&slab::from_f32s(&[2.0]), &mut wire);
        c.send(&Message::Push { iter: 0, lo: 0, hi: 1, codec: CodecId::Int8, data: wire })
            .unwrap();
        assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
        // w1 -= 0.5 * 2.0
        assert_eq!(srv.snapshot(1).unwrap(), vec![4.0]);
    }

    /// An un-negotiated v3 session is pure fp32 — same bytes, same cache
    /// behavior as v2 — and a proposal the server cannot serve falls back
    /// to fp32 instead of refusing the session.
    #[test]
    fn sessions_default_to_fp32() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        match c.recv().unwrap() {
            Message::PullReply { codec, data, .. } => {
                assert_eq!(codec, CodecId::Fp32);
                assert_eq!(slab::to_f32s(&data), vec![1.0, 2.0, 10.0]);
            }
            m => panic!("{m:?}"),
        }
    }

    // ---- Synchronization subsystem (ps/sync) ----

    fn start_two_layer_with(workers: usize, opts: ServerOptions) -> ParamServer {
        let mut layers = HashMap::new();
        layers.insert(0, vec![1.0f32, 2.0]);
        layers.insert(1, vec![10.0f32]);
        ParamServer::start_with(ServerConfig { workers, lr: 0.5 }, layers, None, opts)
            .unwrap()
    }

    fn ssp_opts(bound: u32) -> ServerOptions {
        ServerOptions {
            sync: SyncConfig::new(SyncMode::Ssp, bound).unwrap(),
            ..ServerOptions::default()
        }
    }

    fn asp_opts() -> ServerOptions {
        ServerOptions {
            sync: SyncConfig::new(SyncMode::Asp, 0).unwrap(),
            ..ServerOptions::default()
        }
    }

    fn hello(c: &mut Connection, worker: u32) {
        c.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
        assert!(matches!(c.recv().unwrap(), Message::HelloAck { .. }));
    }

    /// `SyncAgree` reports the shard's own configuration, whatever the
    /// worker proposed — consistency models have no safe fallback.
    #[test]
    fn sync_agree_is_server_authoritative() {
        let srv = start_two_layer_with(1, ssp_opts(3));
        assert_eq!(srv.sync_mode(), SyncMode::Ssp);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::SyncPropose { mode: SyncMode::Bsp, bound: 0 }).unwrap();
        match c.recv().unwrap() {
            Message::SyncAgree { mode, bound } => {
                assert_eq!(mode, SyncMode::Ssp);
                assert_eq!(bound, 3);
            }
            m => panic!("{m:?}"),
        }
        // The default server answers BSP.
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        c.send(&Message::SyncPropose { mode: SyncMode::Asp, bound: 0 }).unwrap();
        match c.recv().unwrap() {
            Message::SyncAgree { mode, bound } => {
                assert_eq!(mode, SyncMode::Bsp);
                assert_eq!(bound, 0);
            }
            m => panic!("{m:?}"),
        }
    }

    /// BSP replies name the iteration they serve: `applied == iter`.
    #[test]
    fn bsp_replies_carry_the_barrier_iteration() {
        let srv = start_two_layer(1);
        let mut c = connect(srv.handle().addr);
        for iter in 0..3u64 {
            c.send(&Message::Pull { iter, lo: 0, hi: 1 }).unwrap();
            match c.recv().unwrap() {
                Message::PullReply { applied, .. } => assert_eq!(applied, iter),
                m => panic!("{m:?}"),
            }
            c.send(&Message::Push {
                iter,
                lo: 0,
                hi: 1,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&[0.0, 0.0, 0.0]),
            })
            .unwrap();
            assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
        }
    }

    /// ASP applies each push the moment it arrives — no barrier on the
    /// other worker — scaled `lr / workers`, and serves pulls fresh (no
    /// version wait, `applied` reporting the snapshot's clock).
    #[test]
    fn asp_applies_on_push_and_serves_fresh() {
        let srv = start_two_layer_with(2, asp_opts());
        let mut a = connect(srv.handle().addr);
        hello(&mut a, 0);
        a.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[2.0, 0.0]),
        })
        .unwrap();
        assert!(matches!(a.recv().unwrap(), Message::PushAck { .. }));
        // Applied immediately with scale lr/workers = 0.25 — under BSP
        // this would still be parked waiting for worker 1.
        assert_eq!(srv.snapshot(0).unwrap(), vec![0.5, 2.0]);
        assert_eq!(srv.apply_events(), 1);
        // A pull far past the applied clock is served immediately with
        // the *actual* snapshot iteration, not the requested one.
        a.send(&Message::Pull { iter: 40, lo: 0, hi: 0 }).unwrap();
        match a.recv().unwrap() {
            Message::PullReply { applied, data, .. } => {
                assert_eq!(applied, 1);
                assert_eq!(slab::to_f32s(&data), vec![0.5, 2.0]);
            }
            m => panic!("{m:?}"),
        }
        assert_eq!(srv.pull_waiters(), 0, "asp never parks on versions");
    }

    /// A straggler's late push still applies under ASP but cannot rewind
    /// the version clock a faster worker already advanced.
    #[test]
    fn asp_late_pushes_apply_without_rewinding_the_clock() {
        let srv = start_two_layer_with(2, asp_opts());
        let mut fast = connect(srv.handle().addr);
        let mut slow = connect(srv.handle().addr);
        hello(&mut fast, 0);
        hello(&mut slow, 1);
        for iter in 0..4u64 {
            fast.send(&Message::Push {
                iter,
                lo: 0,
                hi: 0,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&[0.4, 0.0]),
            })
            .unwrap();
            assert!(matches!(fast.recv().unwrap(), Message::PushAck { .. }));
        }
        slow.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[0.4, 0.0]),
        })
        .unwrap();
        assert!(matches!(slow.recv().unwrap(), Message::PushAck { .. }));
        // All five pushes applied: w0 = 1 − 5·0.25·0.4 = 0.5.
        let got = srv.snapshot(0).unwrap();
        assert!((got[0] - 0.5).abs() < 1e-6, "{got:?}");
        // The clock stayed at the fast worker's 4, not the late 1.
        slow.send(&Message::Pull { iter: 0, lo: 0, hi: 0 }).unwrap();
        match slow.recv().unwrap() {
            Message::PullReply { applied, .. } => assert_eq!(applied, 4),
            m => panic!("{m:?}"),
        }
    }

    /// The SSP gate: a pull past `slowest + bound` parks in the policy
    /// (not on version condvars) until the slowest worker advances; the
    /// served snapshot is then fresh.
    #[test]
    fn ssp_parks_past_the_window_and_releases_on_progress() {
        let srv = start_two_layer_with(2, ssp_opts(1));
        let addr = srv.handle().addr;
        let mut fast = connect(addr);
        let mut slow = connect(addr);
        hello(&mut fast, 0);
        hello(&mut slow, 1);
        // Within the window: slowest = 0, bound 1 → iter 1 passes.
        fast.send(&Message::Pull { iter: 1, lo: 0, hi: 1 }).unwrap();
        assert!(matches!(fast.recv().unwrap(), Message::PullReply { .. }));
        // Past it: iter 2 > 0 + 1 parks in the sync gate.
        fast.send(&Message::Pull { iter: 2, lo: 0, hi: 1 }).unwrap();
        wait_until("ssp gate to park", || srv.sync_waiters() > 0);
        assert_eq!(srv.pull_waiters(), 0, "ssp parks in the policy, not on versions");
        // The slow worker pulling iteration 1 moves slowest to 1 → 2 is
        // admitted.
        slow.send(&Message::Pull { iter: 1, lo: 0, hi: 1 }).unwrap();
        assert!(matches!(slow.recv().unwrap(), Message::PullReply { .. }));
        assert!(matches!(fast.recv().unwrap(), Message::PullReply { .. }));
        assert_eq!(srv.sync_waiters(), 0);
        assert_eq!(srv.slowest_worker_iter(), 1);
    }

    /// A parked SSP pull is released when the straggler's session closes —
    /// a departed worker must not gate the survivors forever.
    #[test]
    fn ssp_departed_worker_releases_the_gate() {
        let srv = start_two_layer_with(2, ssp_opts(0));
        let addr = srv.handle().addr;
        let mut fast = connect(addr);
        let mut slow = connect(addr);
        hello(&mut fast, 0);
        hello(&mut slow, 1);
        fast.send(&Message::Pull { iter: 3, lo: 0, hi: 0 }).unwrap();
        wait_until("ssp gate to park", || srv.sync_waiters() > 0);
        drop(slow); // worker 1 hangs up → deregistered
        assert!(matches!(fast.recv().unwrap(), Message::PullReply { .. }));
    }

    /// Shutdown drains pulls parked in the SSP gate deterministically,
    /// exactly like the BSP version waiters.
    #[test]
    fn shutdown_drains_ssp_gate_waiters() {
        let mut srv = start_two_layer_with(2, ssp_opts(0));
        let addr = srv.handle().addr;
        let t = std::thread::spawn(move || {
            let mut c = connect(addr);
            hello(&mut c, 0);
            let mut other = connect(addr);
            hello(&mut other, 1);
            c.send(&Message::Pull { iter: 9, lo: 0, hi: 0 }).unwrap();
            c.recv()
        });
        wait_until("ssp gate to park", || srv.sync_waiters() > 0);
        srv.shutdown();
        assert_eq!(srv.sync_waiters(), 0, "gate drained");
        let _ = t.join().unwrap();
    }

    /// Under immediate-apply modes the broadcast cache is keyed by apply
    /// events: pulls between applies share one assembly; an apply
    /// invalidates it.
    #[test]
    fn fresh_reply_cache_is_versioned_by_apply_events() {
        let srv = start_two_layer_with(1, asp_opts());
        let mut c = connect(srv.handle().addr);
        hello(&mut c, 0);
        for iter in [0u64, 1, 2] {
            c.send(&Message::Pull { iter, lo: 0, hi: 1 }).unwrap();
            assert!(matches!(c.recv().unwrap(), Message::PullReply { .. }));
        }
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 1, "no apply between pulls → one build");
        assert_eq!(ws.reply_cache_hits, 2);
        c.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 1,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[0.0, 0.0, 0.0]),
        })
        .unwrap();
        assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
        c.send(&Message::Pull { iter: 3, lo: 0, hi: 1 }).unwrap();
        assert!(matches!(c.recv().unwrap(), Message::PullReply { .. }));
        let ws = srv.wire_stats();
        assert_eq!(ws.reply_cache_builds, 2, "the apply must invalidate the broadcast");
    }

    // ---- Bounded handler pool ----

    /// The pool cap holds: with `handler_threads = 1`, a second connection
    /// is not served until the first hangs up — backpressure through the
    /// accept backlog, never a second thread.
    #[test]
    fn handler_pool_defers_connections_past_the_cap() {
        let opts = ServerOptions { handler_threads: 1, ..ServerOptions::default() };
        let srv = start_two_layer_with(1, opts);
        let addr = srv.handle().addr;
        let mut a = connect(addr);
        a.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::PullReply { .. }));
        assert_eq!(srv.live_handlers(), 1);
        // Second connection: accepted by the kernel, but no handler slot —
        // its pull stays unanswered while `a` is alive.
        let mut b = connect(addr);
        b.send(&Message::Pull { iter: 0, lo: 0, hi: 1 }).unwrap();
        assert_eq!(srv.live_handlers(), 1, "cap exceeded");
        drop(a);
        // The freed slot picks `b` up and serves the queued pull.
        assert!(matches!(b.recv().unwrap(), Message::PullReply { .. }));
        assert!(srv.live_handlers() <= 1);
    }

    /// The cap is clamped to the worker count: a fleet larger than the
    /// configured pool must still be fully served concurrently — `workers`
    /// handlers can all be parked at the barrier at once, so a smaller
    /// pool would deadlock training against its own backpressure.
    #[test]
    fn handler_pool_never_caps_below_the_fleet() {
        let opts = ServerOptions { handler_threads: 1, ..ServerOptions::default() };
        let srv = start_two_layer_with(2, opts);
        let addr = srv.handle().addr;
        let mut a = connect(addr);
        let mut b = connect(addr);
        // The barrier needs both pushes; with a cap of 1 the second
        // connection would never be accepted and this would hang.
        for c in [&mut a, &mut b] {
            c.send(&Message::Push {
                iter: 0,
                lo: 0,
                hi: 0,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&[2.0, 0.0]),
            })
            .unwrap();
        }
        for c in [&mut a, &mut b] {
            assert!(matches!(c.recv().unwrap(), Message::PushAck { .. }));
        }
        // w0 -= (0.5/2) * (2 + 2) = 1; w1 untouched.
        assert_eq!(srv.snapshot(0).unwrap(), vec![0.0, 2.0]);
        assert_eq!(srv.live_handlers(), 2, "clamped cap admits the whole fleet");
    }

    // ---- Hierarchical aggregation tier (v5: AggHello, weighted pushes,
    // ---- elastic barrier) ----

    fn agg_hello(c: &mut Connection, group: u32, workers: u32) {
        c.send(&Message::AggHello {
            role: PeerRole::Regional,
            group,
            workers,
            version: PROTOCOL_VERSION,
        })
        .unwrap();
        assert!(matches!(c.recv().unwrap(), Message::HelloAck { .. }));
    }

    /// A regional aggregator's combined push carries its group's barrier
    /// weight: a fleet of 4 completes with one weight-3 push plus one
    /// plain worker push, and the ingress counter sees exactly the bytes
    /// that crossed the cloud boundary.
    #[test]
    fn aggregator_push_carries_group_weight() {
        let srv = start_two_layer(4);
        let addr = srv.handle().addr;
        let mut agg = connect(addr);
        agg_hello(&mut agg, 100, 3);
        agg.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[4.0, 0.0]),
        })
        .unwrap();
        assert!(matches!(agg.recv().unwrap(), Message::PushAck { .. }));
        // 3 of 4 contributions: the barrier must hold.
        assert_eq!(srv.snapshot(0).unwrap(), vec![1.0, 2.0]);
        let mut w = connect(addr);
        hello(&mut w, 3);
        w.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[4.0, 0.0]),
        })
        .unwrap();
        assert!(matches!(w.recv().unwrap(), Message::PushAck { .. }));
        // w0 -= (0.5/4) * (4 + 4) = 1.
        assert_eq!(srv.snapshot(0).unwrap(), vec![0.0, 2.0]);
        // Two fp32 pushes of 2 f32s each crossed the boundary.
        assert_eq!(srv.wire_stats().ingress_bytes, 16);
    }

    /// Extends the SSP deregistration release to BSP: a fleet member that
    /// hangs up mid-iteration shrinks the barrier target, applying the
    /// survivors' accumulated gradients instead of parking them forever.
    #[test]
    fn bsp_departed_worker_releases_the_barrier() {
        let srv = start_two_layer(2);
        let addr = srv.handle().addr;
        let mut alive = connect(addr);
        let mut doomed = connect(addr);
        hello(&mut alive, 0);
        hello(&mut doomed, 1);
        alive
            .send(&Message::Push {
                iter: 0,
                lo: 0,
                hi: 0,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&[4.0, 0.0]),
            })
            .unwrap();
        assert!(matches!(alive.recv().unwrap(), Message::PushAck { .. }));
        // The survivor parks at the barrier for iteration 1.
        alive.send(&Message::Pull { iter: 1, lo: 0, hi: 0 }).unwrap();
        wait_until("the survivor to park at the barrier", || srv.pull_waiters() > 0);
        // Worker 1 dies → target shrinks to 1 → the pending gradient
        // applies (still scaled by the configured fleet: lr / 2) and the
        // parked pull is released.
        drop(doomed);
        match alive.recv().unwrap() {
            Message::PullReply { applied, data, .. } => {
                assert_eq!(applied, 1);
                assert_eq!(slab::to_f32s(&data), vec![0.0, 2.0]);
            }
            m => panic!("{m:?}"),
        }
    }

    /// An aggregator's pull and push connections register the same group
    /// identity: the weight counts once, survives one of the two sessions
    /// closing, and departs only with the last.
    #[test]
    fn same_identity_sessions_count_weight_once() {
        let srv = start_two_layer(3);
        let addr = srv.handle().addr;
        let mut agg_pull = connect(addr);
        let mut agg_push = connect(addr);
        agg_hello(&mut agg_pull, 100, 2);
        agg_hello(&mut agg_push, 100, 2);
        let mut w = connect(addr);
        hello(&mut w, 2);
        w.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[4.0, 0.0]),
        })
        .unwrap();
        assert!(matches!(w.recv().unwrap(), Message::PushAck { .. }));
        assert_eq!(srv.snapshot(0).unwrap(), vec![1.0, 2.0], "1 of 3: barrier holds");
        // One of the aggregator's two sessions closes: the group is still
        // live, so the barrier target must not shrink.
        let live_before = srv.live_handlers();
        drop(agg_pull);
        wait_until("the dropped session's handler to exit", || {
            srv.live_handlers() < live_before
        });
        assert_eq!(srv.snapshot(0).unwrap(), vec![1.0, 2.0], "group still registered");
        // The last session closes: weight 2 departs, target drops to 1,
        // and the pending gradient applies.
        drop(agg_push);
        wait_until("the departed group to release the barrier", || {
            srv.snapshot(0).unwrap() == vec![0.0, 2.0]
        });
    }

    // ---- Fault tolerance (v6: snapshot join, checkpoint/restore —
    // ---- docs/FAULTS.md) ----

    fn test_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dynacomm-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A mid-run joiner's `SnapshotReq` is served ungated with the current
    /// parameters, the shard's clock, and the configured fleet size.
    #[test]
    fn snapshot_req_serves_fresh_params_and_the_shard_clock() {
        let srv = start_two_layer(1);
        let addr = srv.handle().addr;
        let mut w = connect(addr);
        hello(&mut w, 0);
        w.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 1,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[2.0, 2.0, 2.0]),
        })
        .unwrap();
        assert!(matches!(w.recv().unwrap(), Message::PushAck { .. }));
        // A late joiner asks before saying Hello: snapshots are ungated.
        let mut joiner = connect(addr);
        joiner.send(&Message::SnapshotReq { lo: 0, hi: 1 }).unwrap();
        match joiner.recv().unwrap() {
            Message::SnapshotReply { iter, lo, hi, workers, codec, data } => {
                assert_eq!((iter, lo, hi, workers), (1, 0, 1, 1));
                assert_eq!(codec, CodecId::Fp32);
                assert_eq!(slab::to_f32s(&data), vec![0.0, 1.0, 9.0]);
            }
            m => panic!("{m:?}"),
        }
    }

    /// Kill-a-shard/restore: the restored shard's slabs, versions, and a
    /// re-checkpoint are byte-identical, and the resumed clock serves the
    /// next iteration's pull without parking.
    #[test]
    fn checkpoint_restore_resumes_byte_identically() {
        let dir = test_dir("srv-ckpt-roundtrip");
        let path = dir.join("shard.ckpt");
        let mut srv = start_two_layer(1);
        let addr = srv.handle().addr;
        let mut w = connect(addr);
        hello(&mut w, 0);
        for iter in 0..2 {
            w.send(&Message::Push {
                iter,
                lo: 0,
                hi: 1,
                codec: CodecId::Fp32,
                data: slab::from_f32s(&[1.0, 2.0, 3.0]),
            })
            .unwrap();
            assert!(matches!(w.recv().unwrap(), Message::PushAck { .. }));
        }
        let before0 = srv.snapshot(0).unwrap();
        let before1 = srv.snapshot(1).unwrap();
        srv.write_checkpoint(&path).unwrap();
        drop(w);
        srv.shutdown();
        drop(srv);
        let ck = Checkpoint::read_from(&path).unwrap();
        let restored = ParamServer::start_restored(
            ServerConfig { workers: 1, lr: 0.5 },
            None,
            ServerOptions::default(),
            &ck,
        )
        .unwrap();
        assert_eq!(restored.snapshot(0).unwrap(), before0);
        assert_eq!(restored.snapshot(1).unwrap(), before1);
        // Slab-for-slab byte identity: re-checkpointing the restored
        // shard reproduces the original file exactly.
        let path2 = dir.join("shard-again.ckpt");
        restored.write_checkpoint(&path2).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&path2).unwrap(),
            "restored state is byte-identical"
        );
        // The version clock resumed: iteration 2's pull is served fresh.
        let mut r = connect(restored.handle().addr);
        r.send(&Message::Pull { iter: 2, lo: 0, hi: 1 }).unwrap();
        match r.recv().unwrap() {
            Message::PullReply { applied, data, .. } => {
                assert_eq!(applied, 2);
                assert_eq!(slab::to_f32s(&data), [before0.clone(), before1].concat());
            }
            m => panic!("{m:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Restoring under a different consistency model is refused by name.
    #[test]
    fn restore_refuses_a_sync_mode_mismatch() {
        let ck = Checkpoint {
            sync_mode: SyncMode::Ssp,
            staleness_bound: 2,
            clocks: vec![(0, 5)],
            layers: Vec::new(),
        };
        let err = ParamServer::start_restored(
            ServerConfig { workers: 1, lr: 0.1 },
            None,
            ServerOptions::default(),
            &ck,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sync ssp (bound 2)"), "{msg}");
        assert!(msg.contains("configured bsp"), "{msg}");
    }

    /// A restored SSP shard re-imports the per-worker clocks, so the
    /// staleness window resumes where the checkpoint captured it.
    #[test]
    fn restored_ssp_shard_resumes_worker_clocks() {
        let ck = Checkpoint {
            sync_mode: SyncMode::Ssp,
            staleness_bound: 1,
            clocks: vec![(0, 4), (1, 6)],
            layers: vec![LayerRecord {
                layer: 0,
                version: 5,
                params: slab::from_f32s(&[1.0]),
            }],
        };
        let opts = ServerOptions {
            sync: SyncConfig::new(SyncMode::Ssp, 1).unwrap(),
            handler_threads: 4,
        };
        let restored = ParamServer::start_restored(
            ServerConfig { workers: 2, lr: 0.1 },
            None,
            opts,
            &ck,
        )
        .unwrap();
        assert_eq!(restored.slowest_worker_iter(), 4);
        assert_eq!(restored.snapshot(0).unwrap(), vec![1.0]);
    }

    /// Periodic checkpointing writes while the shard runs, and shutdown
    /// writes a final checkpoint capturing the last applied state.
    #[test]
    fn periodic_checkpointing_writes_and_shutdown_finalizes() {
        let dir = test_dir("srv-ckpt-periodic");
        let path = dir.join("shard.ckpt");
        let mut srv = start_two_layer(1);
        srv.enable_checkpointing(path.clone(), Duration::from_millis(5));
        wait_until("a periodic checkpoint to appear", || path.exists());
        assert!(Checkpoint::read_from(&path).is_ok());
        let mut w = connect(srv.handle().addr);
        hello(&mut w, 0);
        w.send(&Message::Push {
            iter: 0,
            lo: 0,
            hi: 1,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&[2.0, 2.0, 2.0]),
        })
        .unwrap();
        assert!(matches!(w.recv().unwrap(), Message::PushAck { .. }));
        drop(w);
        srv.shutdown();
        let ck = Checkpoint::read_from(&path).unwrap();
        let l0 = ck.layers.iter().find(|l| l.layer == 0).unwrap();
        assert_eq!(l0.version, 1, "final checkpoint saw the applied push");
        assert_eq!(slab::to_f32s(&l0.params), vec![0.0, 1.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
