//! Compiled execution plans: a [`crate::sched::SchedulePlan`] bound to a
//! concrete model (per-layer byte sizes) and cluster (shard map), with
//! every per-iteration quantity the worker used to recompute — 0-based
//! segments, prefix byte offsets, per-segment shard sub-requests and the
//! byte ranges of each layer inside both the segment blob and the shard
//! payload — materialized **once per re-plan**. `EdgeWorker::iteration`
//! then runs off pure table lookups.
//!
//! The plan also carries the worker's [`SlabPool`]: since the tables
//! already know every buffer size the iteration will need, the per-layer
//! gradient slabs are checked out **pre-sized** through
//! [`ExecPlan::checkout_layer`] and recycled across iterations — zero
//! steady-state slab allocations.
//!
//! Plans are **codec-aware**: the session's negotiated wire codec
//! ([`crate::net::codec`]) changes every on-wire byte count (compressed
//! layer sizes differ from `4·elems`), so `compile` resolves a parallel
//! set of wire tables — `wire_len`/`wire_off` per slice, `wire_bytes` per
//! sub-request and segment, [`ExecPlan::wire_layer_bytes`] per layer —
//! once per re-plan, and the iteration's encode/decode paths run off pure
//! lookups exactly like the raw-byte paths do.

use std::sync::Arc;

use crate::net::codec::CodecId;
use crate::net::pool::{SlabCheckout, SlabPool};
use crate::ps::sharding::ShardMap;
use crate::sched::SchedulePlan;

pub use crate::net::pool::SlabSlice;

/// One executed pull segment's outcome, reported by the puller thread to
/// the profiler: the wire bytes and wall-clock of the transfer plus the
/// server's `applied` iteration for the served snapshot (protocol v4, min
/// over the segment's shard sub-requests). The wall-clock is measured
/// under the live sync policy — under BSP it embeds the real barrier
/// wait, under SSP/ASP it does not — so the profiler's transmission fit,
/// and therefore the DynaComm DP, costs the *actual* wait window of the
/// configured mode instead of assuming a full barrier; `applied` is what
/// the worker's staleness accounting (and the SSP bound check) reads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SegmentPull {
    pub wire_bytes: usize,
    pub ms: f64,
    pub applied: u64,
}

/// One layer's byte placement inside a segment and its shard payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecSlice {
    /// 0-based layer index.
    pub layer: usize,
    /// Byte length of the layer's flat `w‖b` slab (raw f32).
    pub len: usize,
    /// Byte offset of this layer inside the segment blob (layers of the
    /// segment concatenated in ascending order).
    pub seg_off: usize,
    /// Byte offset of this layer's **decoded** slab inside the owning
    /// shard's payload (the shard's owned layers of the segment,
    /// ascending).
    pub reply_off: usize,
    /// Byte length of this layer's codec-encoded slab on the wire.
    pub wire_len: usize,
    /// Byte offset of this layer's encoding inside the shard's wire
    /// payload (per-layer encodings concatenated, ascending).
    pub wire_off: usize,
}

/// One shard's share of a segment: the sub-request the worker issues.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecSub {
    pub server: usize,
    /// Total decoded payload bytes this shard sends/receives for the
    /// segment.
    pub bytes: usize,
    /// Total codec-encoded bytes of this shard's payload on the wire.
    pub wire_bytes: usize,
    /// The shard's owned layers of the segment, ascending.
    pub slices: Vec<ExecSlice>,
}

/// One transmission mini-procedure, fully resolved.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecSegment {
    /// 0-based inclusive layer range, `lo <= hi` (backward segments keep
    /// their transmission order in [`ExecPlan::bwd`], not in `lo`/`hi`).
    pub lo: usize,
    pub hi: usize,
    /// Total decoded payload bytes of the whole segment.
    pub bytes: usize,
    /// Total codec-encoded bytes of the whole segment on the wire — what
    /// the profiler's transmission model is fed.
    pub wire_bytes: usize,
    pub subs: Vec<ExecSub>,
}

/// A schedule compiled against a concrete model, cluster and wire codec.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub depth: usize,
    /// The session's negotiated wire codec the tables were resolved for.
    pub codec: CodecId,
    /// Flat `w‖b` slab size per 0-based layer (raw f32).
    pub layer_bytes: Vec<usize>,
    /// Codec-encoded slab size per 0-based layer.
    pub wire_layer_bytes: Vec<usize>,
    /// Prefix byte offsets: `byte_off[l]` = bytes of layers `0..l`
    /// (`depth + 1` entries).
    pub byte_off: Vec<usize>,
    /// Forward segments in transmission order (ascending layers).
    pub fwd: Vec<ExecSegment>,
    /// Backward segments in transmission order (deepest layers first).
    pub bwd: Vec<ExecSegment>,
    /// The worker's slab pool; survives re-plans (the same `Arc` is passed
    /// to every `compile`), so warm buffers carry across plan changes.
    pub pool: Arc<SlabPool>,
}

impl ExecPlan {
    /// Resolve `plan` against the model's per-layer byte sizes and the
    /// cluster's shard map. O(L) per segment; runs once per re-plan.
    /// `pool` is the buffer pool iteration checkouts draw from — pass the
    /// worker's long-lived pool so buffers recycle across re-plans too.
    pub fn compile(
        plan: &SchedulePlan,
        layer_bytes: &[usize],
        shard: ShardMap,
        pool: Arc<SlabPool>,
        codec: CodecId,
    ) -> ExecPlan {
        let depth = layer_bytes.len();
        assert_eq!(plan.fwd.depth(), depth, "plan depth != model depth");
        assert_eq!(plan.bwd.depth(), depth, "plan depth != model depth");
        assert_eq!(shard.depth, depth, "shard map depth != model depth");
        let wire_layer_bytes: Vec<usize> =
            layer_bytes.iter().map(|&b| codec.wire_len(b)).collect();
        let mut byte_off = Vec::with_capacity(depth + 1);
        byte_off.push(0usize);
        for l in 0..depth {
            byte_off.push(byte_off[l] + layer_bytes[l]);
        }

        let seg = |lo: usize, hi: usize| -> ExecSegment {
            let mut wire_bytes = 0usize;
            let subs: Vec<ExecSub> = shard
                .sub_requests(lo, hi)
                .map(|sub| {
                    let mut slices = Vec::with_capacity(sub.count);
                    let mut reply_off = 0usize;
                    let mut wire_off = 0usize;
                    for layer in sub.layers() {
                        let len = layer_bytes[layer];
                        let wire_len = wire_layer_bytes[layer];
                        slices.push(ExecSlice {
                            layer,
                            len,
                            seg_off: byte_off[layer] - byte_off[lo],
                            reply_off,
                            wire_len,
                            wire_off,
                        });
                        reply_off += len;
                        wire_off += wire_len;
                    }
                    wire_bytes += wire_off;
                    ExecSub {
                        server: sub.server,
                        bytes: reply_off,
                        wire_bytes: wire_off,
                        slices,
                    }
                })
                .collect();
            ExecSegment {
                lo,
                hi,
                bytes: byte_off[hi + 1] - byte_off[lo],
                wire_bytes,
                subs,
            }
        };

        let fwd = plan
            .fwd
            .fwd_segments()
            .into_iter()
            .map(|(a, b)| seg(a - 1, b - 1)) // 1-based inclusive → 0-based
            .collect();
        let bwd = plan
            .bwd
            .bwd_segments()
            .into_iter()
            .map(|(hi, lo)| seg(lo - 1, hi - 1))
            .collect();
        ExecPlan {
            depth,
            codec,
            layer_bytes: layer_bytes.to_vec(),
            wire_layer_bytes,
            byte_off,
            fwd,
            bwd,
            pool,
        }
    }

    /// Check out an empty pooled buffer pre-sized for layer `l`'s flat
    /// `w‖b` gradient slab (the tables know the exact size).
    // dynalint: hot-path
    pub fn checkout_layer(&self, l: usize) -> SlabCheckout {
        exec_checkouts().inc();
        self.pool.checkout(self.layer_bytes[l])
    }

    /// Check out an empty pooled buffer pre-sized for layer `l`'s
    /// codec-encoded wire slab.
    // dynalint: hot-path
    pub fn checkout_layer_wire(&self, l: usize) -> SlabCheckout {
        exec_checkouts().inc();
        self.pool.checkout(self.wire_layer_bytes[l])
    }
}

/// Table-presized checkouts served across every `ExecPlan` in the process
/// (obs registry; cold registration, one relaxed op per checkout).
fn exec_checkouts() -> &'static crate::obs::Counter {
    static CELL: std::sync::OnceLock<crate::obs::Counter> = std::sync::OnceLock::new();
    CELL.get_or_init(|| crate::obs_counter!("dynacomm_exec_checkouts_total"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Decomposition, SchedulePlan};
    use crate::util::rng::Rng;

    fn random_plan(rng: &mut Rng, depth: usize) -> SchedulePlan {
        let mut fwd = Decomposition::sequential(depth);
        let mut bwd = Decomposition::sequential(depth);
        for c in fwd.cuts.iter_mut().chain(bwd.cuts.iter_mut()) {
            *c = rng.bool();
        }
        SchedulePlan { fwd, bwd }
    }

    fn random_bytes(rng: &mut Rng, depth: usize) -> Vec<usize> {
        (0..depth).map(|_| 4 * (1 + rng.below(64))).collect()
    }

    /// Every compiled quantity must agree with a from-scratch
    /// recomputation: segments partition the layers, slice offsets tile
    /// both the segment blob and each shard payload exactly (raw *and*
    /// codec-encoded), and the owning servers match the shard map.
    #[test]
    fn compiled_offsets_tile_segments_and_payloads() {
        let mut rng = Rng::new(91);
        let pool = SlabPool::new();
        for round in 0..100 {
            let codec = CodecId::ALL[round % 3];
            let depth = rng.range(1, 20);
            let servers = rng.range(1, 6);
            let shard = ShardMap::new(servers, depth);
            let layer_bytes = random_bytes(&mut rng, depth);
            let plan = random_plan(&mut rng, depth);
            let exec = ExecPlan::compile(&plan, &layer_bytes, shard, pool.clone(), codec);
            assert_eq!(exec.codec, codec);
            assert_eq!(exec.byte_off.len(), depth + 1);
            assert_eq!(exec.byte_off[depth], layer_bytes.iter().sum::<usize>());
            for l in 0..depth {
                assert_eq!(exec.wire_layer_bytes[l], codec.wire_len(layer_bytes[l]));
            }

            for (segs, ascending) in [(&exec.fwd, true), (&exec.bwd, false)] {
                // Transmission order: fwd ascends from layer 0, bwd
                // descends from the last layer.
                if ascending {
                    assert_eq!(segs.first().unwrap().lo, 0);
                    assert_eq!(segs.last().unwrap().hi, depth - 1);
                } else {
                    assert_eq!(segs.first().unwrap().hi, depth - 1);
                    assert_eq!(segs.last().unwrap().lo, 0);
                }
                let mut covered = Vec::new();
                for seg in segs {
                    assert!(seg.lo <= seg.hi);
                    covered.extend(seg.lo..=seg.hi);
                    let seg_bytes: usize =
                        (seg.lo..=seg.hi).map(|l| layer_bytes[l]).sum();
                    assert_eq!(seg.bytes, seg_bytes);
                    assert_eq!(
                        seg.subs.iter().map(|s| s.bytes).sum::<usize>(),
                        seg_bytes
                    );
                    assert_eq!(
                        seg.wire_bytes,
                        seg.subs.iter().map(|s| s.wire_bytes).sum::<usize>()
                    );
                    // Slices tile the segment blob exactly once.
                    let mut seg_ranges: Vec<(usize, usize)> = Vec::new();
                    for sub in &seg.subs {
                        let mut reply_off = 0;
                        let mut wire_off = 0;
                        for sl in &sub.slices {
                            assert_eq!(shard.owner(sl.layer), sub.server);
                            assert_eq!(sl.len, layer_bytes[sl.layer]);
                            assert_eq!(sl.reply_off, reply_off);
                            assert_eq!(
                                sl.seg_off,
                                exec.byte_off[sl.layer] - exec.byte_off[seg.lo]
                            );
                            // Wire offsets tile the encoded payload the
                            // same way the raw offsets tile the decoded
                            // one.
                            assert_eq!(sl.wire_len, codec.wire_len(sl.len));
                            assert_eq!(sl.wire_off, wire_off);
                            reply_off += sl.len;
                            wire_off += sl.wire_len;
                            seg_ranges.push((sl.seg_off, sl.seg_off + sl.len));
                        }
                        assert_eq!(sub.bytes, reply_off);
                        assert_eq!(sub.wire_bytes, wire_off);
                    }
                    seg_ranges.sort_unstable();
                    let mut expect = 0;
                    for (a, b) in seg_ranges {
                        assert_eq!(a, expect, "gap or overlap in segment blob");
                        expect = b;
                    }
                    assert_eq!(expect, seg_bytes);
                }
                covered.sort_unstable();
                assert_eq!(covered, (0..depth).collect::<Vec<_>>());
            }
        }
    }

    /// The byte-table-driven checkouts come back empty, pre-sized, and —
    /// across iterations — recycled rather than re-allocated.
    #[test]
    fn plan_checkouts_are_presized_and_recycled() {
        let pool = SlabPool::new();
        let layer_bytes = vec![1024usize, 64, 4096];
        let plan = SchedulePlan::layer_by_layer(3);
        let exec = ExecPlan::compile(
            &plan,
            &layer_bytes,
            ShardMap::new(2, 3),
            pool,
            CodecId::Fp32,
        );
        for iter in 0..3 {
            let held: Vec<SlabCheckout> =
                (0..3).map(|l| exec.checkout_layer(l)).collect();
            for (l, co) in held.iter().enumerate() {
                assert!(co.is_empty());
                assert!(co.capacity() >= layer_bytes[l]);
            }
            drop(held);
            assert_eq!(
                exec.pool.stats().allocations,
                3,
                "iteration {iter} allocated instead of recycling"
            );
        }
    }

    /// Under a compressing codec the wire tables shrink (and the wire
    /// checkouts are sized off them), while the raw tables are untouched.
    #[test]
    fn wire_tables_shrink_under_compression() {
        let pool = SlabPool::new();
        let layer_bytes = vec![8192usize, 256, 40960];
        let plan = SchedulePlan::layer_by_layer(3);
        let fp16 = ExecPlan::compile(
            &plan,
            &layer_bytes,
            ShardMap::new(2, 3),
            pool.clone(),
            CodecId::Fp16,
        );
        assert_eq!(fp16.wire_layer_bytes, vec![4096, 128, 20480]);
        assert_eq!(fp16.layer_bytes, layer_bytes);
        for seg in fp16.fwd.iter().chain(&fp16.bwd) {
            assert_eq!(seg.wire_bytes * 2, seg.bytes);
        }
        let co = fp16.checkout_layer_wire(0);
        assert!(co.is_empty() && co.capacity() >= 4096);
        // Fp32 wire tables degenerate to the raw ones.
        let fp32 = ExecPlan::compile(
            &plan,
            &layer_bytes,
            ShardMap::new(2, 3),
            pool,
            CodecId::Fp32,
        );
        assert_eq!(fp32.wire_layer_bytes, fp32.layer_bytes);
    }
}
