//! DynaComm — dynamic communication scheduling for distributed CNN training
//! between edges and clouds (reproduction of Cai et al., IEEE JSAC 2021).
//!
//! The crate is organized as a three-layer system:
//!
//! * **Coordinator (Rust, this crate)** — the paper's contribution: the
//!   [`sched`] module implements the Sequential / layer-by-layer / iBatch /
//!   DynaComm schedulers over per-layer cost vectors; [`ps`] and [`net`]
//!   provide the parameter-server framework and the emulated edge network;
//!   [`sim`] reproduces the paper's evaluation with a discrete-event model;
//!   [`profiler`] measures real cost vectors at run time.
//! * **Model (JAX, build time)** — `python/compile/model.py` lowers a
//!   layer-wise CNN (fwd and bwd per layer) to HLO text artifacts.
//! * **Kernels (Pallas, build time)** — `python/compile/kernels/` holds the
//!   tiled-matmul / conv kernels used by the model, checked against a
//!   pure-jnp oracle.
//!
//! The [`runtime`] module loads the HLO artifacts through PJRT so the Rust
//! hot path never touches Python.

pub mod analysis;
pub mod config;
pub mod figures;
pub mod models;
pub mod net;
pub mod obs;
pub mod profiler;
pub mod ps;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod training;
pub mod util;
