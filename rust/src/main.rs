//! `dynacomm` — the launcher.
//!
//! Subcommands:
//!
//! * `schedule`  — run all four schedulers on a model's cost profile and
//!                 print the decomposition decisions + timeline breakdowns.
//! * `simulate`  — normalized pass times for all models (Figs. 5–8 cells).
//! * `sweep`     — batch / bandwidth / worker sensitivity (Figs. 9, 11).
//! * `train`     — real end-to-end EdgeCNN training through the PS
//!                 framework and PJRT artifacts (Fig. 10 / Table II).
//! * `bench-sched` — scheduler wall-clock vs depth (Fig. 12).
//!
//! Common flags: `--model`, `--batch`, `--strategy`, `--workers`,
//! `--servers`, `--rtt-ms`, `--bandwidth-gbps`, `--delta-t-ms`, `--gflops`.

use anyhow::{Context, Result};

use dynacomm::config::{Strategy, SystemConfig};
use dynacomm::figures::{self, Pass};
use dynacomm::models;
use dynacomm::sim;
use dynacomm::training::{train, TrainConfig};
use dynacomm::util::cli::Args;
use dynacomm::util::log;

fn main() -> Result<()> {
    log::init_from_env();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "schedule" => cmd_schedule(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "train" => cmd_train(&args),
        "bench-sched" => cmd_bench_sched(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
dynacomm — dynamic communication scheduling for edge CNN training

USAGE: dynacomm <schedule|simulate|sweep|train|bench-sched> [flags]

  schedule     print decomposition decisions + timelines for one model
  simulate     normalized fwd/bwd execution times (Figs. 5-8)
  sweep        --kind batch|bandwidth|workers  (Figs. 9a, 9b, 11)
  train        real EdgeCNN training over the PS framework (Fig. 10)
  bench-sched  scheduler wall-clock versus network depth (Fig. 12)

FLAGS (defaults = the paper's testbed):
  --model NAME          vgg19|googlenet|inceptionv4|resnet152|edgecnn
  --batch N             per-worker batch size (32)
  --strategy S          sequential|lbl|ibatch|dynacomm|slicing|bruteforce
                        (registry shim names)
  --codec C             wire codec fp32|fp16|int8 (compressed transfers;
                        the scheduler costs transmissions at wire size)
  --sync M              parameter-server synchronization bsp|ssp|asp
                        (ps/sync): bsp is the paper's full barrier; ssp
                        lets workers run up to --staleness-bound N
                        iterations ahead of the slowest (stragglers stop
                        stalling the fleet, snapshots stay within N); asp
                        applies every push immediately, no gating at all
  --staleness-bound N   ssp staleness window, iterations (0 outside ssp)
  --tier T              fleet topology flat|regional (docs/TOPOLOGY.md):
                        regional groups workers behind aggregators that
                        combine pushes and share pulls, cutting cloud
                        ingress/egress by ~group size (train)
  --group-size N        edge workers per regional aggregator (4)
  --agg-sync M          regional->cloud hop sync mode bsp|ssp|asp (the
                        edge hop keeps --sync; ssp shares --staleness-bound)
  --agg-codec C         regional->cloud hop wire codec fp32|fp16|int8 (the
                        edge hop keeps --codec)
  --handler-threads N   per-shard handler pool cap; extra connections wait
                        in the accept backlog (backpressure) (train)
  --io-timeout-ms N     pull/push I/O deadline on worker->shard and
                        aggregator->cloud sockets, ms; 0 disables. A dead
                        peer fails the blocked read within the window
                        (docs/FAULTS.md) (train)
  --checkpoint-dir DIR  each shard writes shard-{s}.ckpt here periodically
                        and on shutdown (train)
  --checkpoint-every-ms N   periodic checkpoint interval, ms (1000) (train)
  --restore DIR         resume shards byte-identically from the
                        shard-{s}.ckpt files in DIR (train)
  --metrics-addr ADDR   serve Prometheus text-format snapshots of the obs
                        registry at host:port (port 0 = ephemeral);
                        docs/OBSERVABILITY.md (train)
  --trace-out FILE      arm span tracing and write a Chrome trace-event
                        JSON timeline (chrome://tracing) on shutdown
                        (train)
  --no-error-feedback   disable EF-SGD residuals for lossy codecs (train)
  --gain-threshold-ms F skip DynaComm's DP re-plan when the predicted gain
                        is under F ms (0 = re-plan every epoch; `auto`, the
                        default, derives F from the measured DP wall-clock
                        vs the comm idle window)
  --workers N --servers N
  --rtt-ms F --bandwidth-gbps F --delta-t-ms F --gflops F
  --epochs N --iters N --lr F --artifacts DIR   (train)
";

fn cmd_schedule(args: &Args) -> Result<()> {
    let cfg = SystemConfig::default().apply_args(args);
    let model = models::by_name(&cfg.model)
        .with_context(|| format!("unknown model '{}'", cfg.model))?;
    let cv = model.cost_vectors(&cfg);
    println!(
        "model={} depth={} batch={} Δt={:.2}ms",
        model.name,
        model.depth(),
        cfg.batch,
        cv.delta_t
    );
    for s in Strategy::ALL {
        let r = sim::simulate_cv(&cv, s);
        println!(
            "\n{:<11} fwd segments={:<4} bwd segments={:<4} total={:.1} ms \
             (scheduler predicted {:.1} ms)",
            s.name(),
            r.sched.plan.fwd.num_transmissions(),
            r.sched.plan.bwd.num_transmissions(),
            r.total_ms(),
            r.sched.predicted_ms()
        );
        println!(
            "  fwd: total={:>9.2} comp={:>9.2} overlap={:>9.2} comm={:>9.2}",
            r.breakdown.fwd.total,
            r.breakdown.fwd.comp_only,
            r.breakdown.fwd.overlap,
            r.breakdown.fwd.comm_only
        );
        println!(
            "  bwd: total={:>9.2} comp={:>9.2} overlap={:>9.2} comm={:>9.2}",
            r.breakdown.bwd.total,
            r.breakdown.bwd.comp_only,
            r.breakdown.bwd.overlap,
            r.breakdown.bwd.comm_only
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let batch = args.usize("batch", 32);
    for (pass, name) in [(Pass::Forward, "forward"), (Pass::Backward, "backward")] {
        let cells = figures::normalized_pass_times(batch, pass);
        println!(
            "{}",
            figures::render_normalized(
                &cells,
                &format!("normalized {name} execution time (batch={batch})")
            )
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    match args.get_or("kind", "batch").as_str() {
        "batch" => println!(
            "{}",
            figures::render_sweep(
                &figures::fig9_batch_sweep(),
                "batch",
                "iteration time reduced ratio vs batch (Fig. 9a)"
            )
        ),
        "bandwidth" => println!(
            "{}",
            figures::render_sweep(
                &figures::fig9_bandwidth_sweep(),
                "gbps",
                "iteration time reduced ratio vs bandwidth (Fig. 9b)"
            )
        ),
        "workers" => println!(
            "{}",
            figures::render_sweep(
                &figures::fig11_worker_sweep(),
                "workers",
                "speedup vs number of workers (Fig. 11)"
            )
        ),
        k => anyhow::bail!("unknown sweep kind '{k}'"),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.artifacts_dir = args.get_or("artifacts", &cfg.artifacts_dir);
    cfg.workers = args.usize("workers", cfg.workers);
    cfg.servers = args.usize("servers", cfg.servers);
    cfg.epochs = args.usize("epochs", cfg.epochs);
    cfg.iters_per_epoch = args.usize("iters", cfg.iters_per_epoch);
    cfg.lr = args.f64("lr", cfg.lr as f64) as f32;
    cfg.profiling = !args.bool("no-profiling");
    if let Some(s) = args.get("gain-threshold-ms") {
        cfg.gain_threshold_ms = dynacomm::config::parse_gain_threshold(s)
            .with_context(|| format!("bad --gain-threshold-ms '{s}'"))?;
    }
    if let Some(s) = args.get("strategy") {
        cfg.strategy = Strategy::parse(s).context("bad --strategy")?;
    }
    if let Some(s) = args.get("codec") {
        cfg.codec = dynacomm::net::codec::CodecId::parse(s).context("bad --codec")?;
    }
    if let Some(s) = args.get("sync") {
        cfg.sync = dynacomm::ps::sync::SyncMode::parse(s).context("bad --sync")?;
    }
    cfg.staleness_bound =
        args.usize("staleness-bound", cfg.staleness_bound as usize) as u32;
    cfg.handler_threads = args.usize("handler-threads", cfg.handler_threads);
    cfg.error_feedback = !args.bool("no-error-feedback");
    if let Some(s) = args.get("tier") {
        cfg.tier = dynacomm::config::Tier::parse(s).context("bad --tier")?;
    }
    cfg.group_size = args.usize("group-size", cfg.group_size);
    if let Some(s) = args.get("agg-sync") {
        cfg.agg_sync = dynacomm::ps::sync::SyncMode::parse(s).context("bad --agg-sync")?;
    }
    if let Some(s) = args.get("agg-codec") {
        cfg.agg_codec =
            dynacomm::net::codec::CodecId::parse(s).context("bad --agg-codec")?;
    }
    cfg.io_timeout_ms = args.usize("io-timeout-ms", cfg.io_timeout_ms as usize) as u64;
    cfg.checkpoint_dir = args.get("checkpoint-dir").map(str::to_string);
    cfg.checkpoint_every_ms =
        args.usize("checkpoint-every-ms", cfg.checkpoint_every_ms as usize) as u64;
    cfg.restore_dir = args.get("restore").map(str::to_string);
    if let Some(a) = args.get("metrics-addr") {
        dynacomm::config::validate_metrics_addr(a)?;
        cfg.metrics_addr = Some(a.to_string());
    }
    cfg.trace_out = args.get("trace-out").map(str::to_string);
    cfg.clock_probe_every = args.usize("clock-probe-every", cfg.clock_probe_every);
    if cfg.tier == dynacomm::config::Tier::Regional {
        println!(
            "tier=regional group-size={} agg-sync={} agg-codec={}",
            cfg.group_size,
            cfg.agg_sync.name(),
            cfg.agg_codec.name()
        );
    }
    let result = train(&cfg)?;
    for (e, ((loss, acc), ms)) in result
        .epoch_loss
        .iter()
        .zip(&result.epoch_train_acc)
        .zip(&result.epoch_iter_ms)
        .enumerate()
    {
        println!("epoch {e}: loss={loss:.4} train-top1={acc:.3} iter={ms:.1} ms");
    }
    println!(
        "val-top1={:.3} samples/sec/worker={:.2}",
        result.val_acc, result.samples_per_sec_per_worker
    );
    let calls: usize = result.per_worker.iter().map(|r| r.sched_ms.len()).sum();
    let reused: usize = result.per_worker.iter().map(|r| r.sched_reused).sum();
    println!("reschedule calls={calls} cached-plan reuses={reused}");
    if cfg.sync != dynacomm::ps::sync::SyncMode::Bsp {
        // The consistency cost of the relaxed sync mode, as measured from
        // the v4 `applied` field on every pull reply.
        let max_stale: u64 = result
            .per_worker
            .iter()
            .flat_map(|r| r.staleness.iter().copied())
            .max()
            .unwrap_or(0);
        println!(
            "sync={} staleness-bound={} max-observed-staleness={max_stale}",
            cfg.sync.name(),
            cfg.staleness_bound
        );
    }
    Ok(())
}

fn cmd_bench_sched(args: &Args) -> Result<()> {
    let reps = args.usize("reps", 5);
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>14}",
        "layers", "dyna-fwd(ms)", "dyna-bwd(ms)", "ibatch-fwd", "ibatch-bwd"
    );
    for depth in [10, 20, 40, 80, 160, 320] {
        let t = figures::time_schedulers(depth, reps, 42);
        println!(
            "{:<8} {:>14.4} {:>14.4} {:>14.4} {:>14.4}",
            depth,
            t.dynacomm_fwd_ms.mean,
            t.dynacomm_bwd_ms.mean,
            t.ibatch_fwd_ms.mean,
            t.ibatch_bwd_ms.mean
        );
    }
    Ok(())
}
