//! `f_m` — the timeline cost evaluator (Eq. 8) and its overlap breakdown.
//!
//! Given the cost vectors and a decomposition decision, this reconstructs
//! the exact mini-procedure timeline honoring the partial-order constraints
//! (1)–(7) and returns the iteration-time split the paper plots in
//! Figs. 5–8: non-overlapping computation / overlapping time /
//! non-overlapping communication. Evaluation is O(L).
//!
//! Timeline semantics (matching Eqs. 13/14):
//!
//! * **Forward**: the servers stream every parameter segment back-to-back,
//!   so segment `j`'s arrival time is `j·Δt + Σ pt` through its last layer.
//!   Segment `j`'s computation starts at `max(prev compute end, arrival)`.
//! * **Backward**: computation runs without stalling (it does not depend on
//!   transmissions); segment `j`'s transmission starts at
//!   `max(prev transmission end, compute end of its shallowest layer)` and
//!   then costs `Δt + Σ gt`.

use super::{prefix, CostVectors, Decomposition};

/// One pass (forward or backward) split the way Figs. 5–8 plot it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PassBreakdown {
    /// Wall-clock of the pass, ms.
    pub total: f64,
    /// Time where only computation is running.
    pub comp_only: f64,
    /// Time where communication and computation overlap.
    pub overlap: f64,
    /// Time where only communication is running.
    pub comm_only: f64,
}

impl PassBreakdown {
    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-6 * (1.0 + a.abs() + b.abs())
    }

    /// The three portions must tile the pass (up to idle gaps, which cannot
    /// occur under these timeline semantics — asserted in tests).
    pub fn parts_sum(&self) -> f64 {
        self.comp_only + self.overlap + self.comm_only
    }

    pub fn is_consistent(&self) -> bool {
        Self::close(self.total, self.parts_sum())
    }
}

/// Forward pass under decomposition `d`.
pub fn eval_forward(cv: &CostVectors, d: &Decomposition) -> PassBreakdown {
    assert_eq!(d.depth(), cv.depth());
    let ppt = prefix(&cv.pt);
    let pfc = prefix(&cv.fc);
    let segs = d.fwd_segments();

    // Communication: the link is busy continuously on [0, comm_end].
    let comm_end = segs.len() as f64 * cv.delta_t + ppt[cv.depth()];

    // Computation: per-segment [start, end) intervals.
    let mut comp_busy = 0.0; // total compute time
    let mut overlap = 0.0; // compute time inside [0, comm_end]
    let mut t: f64 = 0.0; // compute end of the previous segment
    for (j, (a, b)) in segs.iter().enumerate() {
        let arrival = (j + 1) as f64 * cv.delta_t + ppt[*b];
        let start = t.max(arrival);
        let dur = pfc[*b] - pfc[*a - 1];
        let end = start + dur;
        comp_busy += dur;
        // Intersection of [start, end] with the comm-busy window [0, comm_end].
        overlap += (end.min(comm_end) - start.min(comm_end)).max(0.0);
        t = end;
    }
    let total = t.max(comm_end);
    PassBreakdown {
        total,
        comp_only: comp_busy - overlap,
        overlap,
        comm_only: comm_end - overlap,
    }
}

/// Backward pass under decomposition `d`.
pub fn eval_backward(cv: &CostVectors, d: &Decomposition) -> PassBreakdown {
    assert_eq!(d.depth(), cv.depth());
    let depth = cv.depth();
    // sbc_from[l] = compute end time when layer l's backward is done
    // (backward runs L, L-1, ..., 1 without stalls).
    let mut sbc_from = vec![0.0; depth + 2];
    let mut acc = 0.0;
    for l in (1..=depth).rev() {
        acc += cv.bc[l - 1];
        sbc_from[l] = acc;
    }
    let comp_end = acc;

    let pgt = prefix(&cv.gt);
    let segs = d.bwd_segments();
    let mut t: f64 = 0.0; // transmission end of the previous segment
    let mut comm_busy = 0.0;
    let mut overlap = 0.0;
    for (hi, lo) in segs {
        let ready = sbc_from[lo]; // compute of layers hi..lo all done
        let start = t.max(ready);
        let dur = cv.delta_t + (pgt[hi] - pgt[lo - 1]);
        let end = start + dur;
        comm_busy += dur;
        overlap += (end.min(comp_end) - start.min(comp_end)).max(0.0);
        t = end;
    }
    let total = t.max(comp_end);
    PassBreakdown {
        total,
        comp_only: comp_end - overlap,
        overlap,
        comm_only: comm_busy - overlap,
    }
}

/// Codec-aware transmission-time estimate: milliseconds to move a raw f32
/// payload of `raw_bytes` over a link shipping `bytes_per_ms`, after the
/// wire codec's compression ([`crate::net::codec::CodecId::wire_bytes_f64`]
/// gives the exact encoded size). This is the single place the scheduler's
/// cost inputs convert bytes into time — `models::ModelSpec::cost_vectors`
/// builds its pt/gt through it, and the live profiler reaches the same
/// result by being fed wire byte counts — so when the codec changes, the
/// DP re-segments against *compressed* transfer costs.
pub fn transmission_ms(
    codec: crate::net::codec::CodecId,
    raw_bytes: f64,
    bytes_per_ms: f64,
) -> f64 {
    codec.wire_bytes_f64(raw_bytes) / bytes_per_ms
}

/// No forward schedule can finish before every parameter crosses the link
/// (at least one mini-procedure pays `Δt`, and the link serializes all of
/// `pt`) or before every layer computes: `max(Δt + Σ pt, Σ fc)`. Property-
/// tested in `lower_bounds_hold_random`; the gain-thresholded DynaComm
/// scheduler uses it to bound what a fresh DP run could still save.
pub fn forward_lower_bound(cv: &CostVectors) -> f64 {
    let comm = cv.delta_t + cv.pt.iter().sum::<f64>();
    let comp = cv.fc.iter().sum::<f64>();
    comm.max(comp)
}

/// Backward twin of [`forward_lower_bound`]: `max(Δt + Σ gt, Σ bc)`.
pub fn backward_lower_bound(cv: &CostVectors) -> f64 {
    let comm = cv.delta_t + cv.gt.iter().sum::<f64>();
    let comp = cv.bc.iter().sum::<f64>();
    comm.max(comp)
}

/// Whole-iteration breakdown: forward then backward (constraint (3) chains
/// them; parameter pulls of iteration i+1 are not overlapped with iteration
/// i, matching the paper's per-iteration accounting).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationBreakdown {
    pub fwd: PassBreakdown,
    pub bwd: PassBreakdown,
}

impl IterationBreakdown {
    pub fn total(&self) -> f64 {
        self.fwd.total + self.bwd.total
    }
}

pub fn eval_iteration(
    cv: &CostVectors,
    fwd: &Decomposition,
    bwd: &Decomposition,
) -> IterationBreakdown {
    IterationBreakdown {
        fwd: eval_forward(cv, fwd),
        bwd: eval_backward(cv, bwd),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::random_cv;
    use crate::util::rng::Rng;

    fn cv4() -> CostVectors {
        CostVectors {
            pt: vec![1.0, 2.0, 3.0, 4.0],
            fc: vec![4.0, 3.0, 2.0, 1.0],
            bc: vec![8.0, 6.0, 4.0, 2.0],
            gt: vec![1.0, 2.0, 3.0, 4.0],
            delta_t: 0.5,
        }
    }

    #[test]
    fn forward_sequential_is_sum() {
        let cv = cv4();
        let b = eval_forward(&cv, &Decomposition::sequential(4));
        // One transmission (Δt + Σpt) then all compute.
        assert!((b.total - (0.5 + 10.0 + 10.0)).abs() < 1e-9);
        assert_eq!(b.overlap, 0.0);
        assert!((b.comm_only - 10.5).abs() < 1e-9);
        assert!((b.comp_only - 10.0).abs() < 1e-9);
        assert!(b.is_consistent());
    }

    #[test]
    fn backward_sequential_is_sum() {
        let cv = cv4();
        let b = eval_backward(&cv, &Decomposition::sequential(4));
        // All compute (20) then one transmission (0.5 + 10).
        assert!((b.total - 30.5).abs() < 1e-9);
        assert_eq!(b.overlap, 0.0);
        assert!(b.is_consistent());
    }

    #[test]
    fn forward_lbl_overlaps() {
        let cv = cv4();
        let seq = eval_forward(&cv, &Decomposition::sequential(4));
        let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(4));
        assert!(lbl.total < seq.total);
        assert!(lbl.overlap > 0.0);
        assert!(lbl.is_consistent());
    }

    #[test]
    fn forward_lbl_exact_small() {
        // L=2, Δt=1, pt=[2,2], fc=[3,1].
        let cv = CostVectors {
            pt: vec![2.0, 2.0],
            fc: vec![3.0, 1.0],
            bc: vec![1.0, 1.0],
            gt: vec![1.0, 1.0],
            delta_t: 1.0,
        };
        let b = eval_forward(&cv, &Decomposition::layer_by_layer(2));
        // arrivals: seg1 at 1+2=3, seg2 at 2+4=6.
        // fc1: 3..6; fc2: max(6,6)..7. comm busy [0,6].
        assert!((b.total - 7.0).abs() < 1e-9);
        assert!((b.overlap - 3.0).abs() < 1e-9);
        assert!((b.comm_only - 3.0).abs() < 1e-9);
        assert!((b.comp_only - 1.0).abs() < 1e-9);
    }

    #[test]
    fn backward_lbl_exact_small() {
        // L=2, Δt=1, bc=[1,4] (layer2 computes first), gt=[2,3].
        let cv = CostVectors {
            pt: vec![1.0, 1.0],
            fc: vec![1.0, 1.0],
            bc: vec![1.0, 4.0],
            gt: vec![2.0, 3.0],
            delta_t: 1.0,
        };
        let b = eval_backward(&cv, &Decomposition::layer_by_layer(2));
        // compute: layer2 done @4, layer1 done @5 (comp_end=5).
        // seg (2,2): start max(0,4)=4, dur 1+3=4, end 8.
        // seg (1,1): ready @5, start max(8,5)=8, dur 1+2=3, end 11.
        assert!((b.total - 11.0).abs() < 1e-9);
        // overlap: [4,5] of seg1 = 1.0.
        assert!((b.overlap - 1.0).abs() < 1e-9);
        assert!((b.comp_only - 4.0).abs() < 1e-9);
        assert!((b.comm_only - 6.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_always_consistent_random() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let depth = rng.range(1, 20);
            let cv = random_cv(&mut rng, depth);
            // random decomposition
            let mut d = Decomposition::sequential(depth);
            for c in d.cuts.iter_mut() {
                *c = rng.bool();
            }
            let f = eval_forward(&cv, &d);
            let b = eval_backward(&cv, &d);
            assert!(f.is_consistent(), "{f:?}");
            assert!(b.is_consistent(), "{b:?}");
            assert!(f.total >= f.overlap && b.total >= b.overlap);
        }
    }

    #[test]
    fn lower_bounds_hold_random() {
        // No schedule can beat max(total comm, total comp) in either pass —
        // the bound forward_lower_bound/backward_lower_bound encode.
        let mut rng = Rng::new(13);
        for _ in 0..100 {
            let depth = rng.range(2, 16);
            let cv = random_cv(&mut rng, depth);
            let mut d = Decomposition::sequential(depth);
            for c in d.cuts.iter_mut() {
                *c = rng.bool();
            }
            let f = eval_forward(&cv, &d);
            assert!(f.total >= forward_lower_bound(&cv) - 1e-9);
            let b = eval_backward(&cv, &d);
            assert!(b.total >= backward_lower_bound(&cv) - 1e-9);
        }
    }

    #[test]
    fn lower_bounds_are_attained_when_one_side_dominates() {
        // Pure-comm instance: a single transmission hits the bound exactly.
        let cv = CostVectors {
            pt: vec![5.0, 5.0],
            fc: vec![0.0, 0.0],
            bc: vec![0.0, 0.0],
            gt: vec![5.0, 5.0],
            delta_t: 1.0,
        };
        let d = Decomposition::sequential(2);
        assert!((eval_forward(&cv, &d).total - forward_lower_bound(&cv)).abs() < 1e-9);
        assert!((eval_backward(&cv, &d).total - backward_lower_bound(&cv)).abs() < 1e-9);
    }

    #[test]
    fn transmission_ms_scales_with_the_codec() {
        use crate::net::codec::CodecId;
        let raw = 4.0 * 1e6; // 1M f32 elements
        let fp32 = transmission_ms(CodecId::Fp32, raw, 1000.0);
        let fp16 = transmission_ms(CodecId::Fp16, raw, 1000.0);
        let int8 = transmission_ms(CodecId::Int8, raw, 1000.0);
        assert_eq!(fp32, raw / 1000.0);
        assert_eq!(fp16, fp32 / 2.0);
        // int8 is ~26% of fp32 (1 byte/elem + 8-byte chunk headers).
        assert!(int8 < 0.27 * fp32 && int8 > 0.24 * fp32, "{int8} vs {fp32}");
    }

    /// The acceptance property: feeding the DP *compressed* byte counts
    /// changes its decomposition on at least one paper model profile (and
    /// never worsens the predicted pass time — smaller pt/gt can only
    /// help).
    #[test]
    fn int8_compression_re_segments_the_dynacomm_plan() {
        use crate::config::SystemConfig;
        use crate::net::codec::CodecId;
        use crate::sched::dynacomm;
        let mut changed = 0usize;
        for model in crate::models::paper_models() {
            let mut cfg = SystemConfig::default();
            cfg.codec = CodecId::Fp32;
            let cv32 = model.cost_vectors(&cfg);
            cfg.codec = CodecId::Int8;
            let cv8 = model.cost_vectors(&cfg);
            // The codec-aware inputs really are compressed.
            let sum = |v: &[f64]| v.iter().sum::<f64>();
            assert!(sum(&cv8.pt) < 0.3 * sum(&cv32.pt), "{}", model.name);
            assert_eq!(cv8.fc, cv32.fc, "compute costs must not change");

            let (f32_plan, f32_t) = dynacomm::forward_with_value(&cv32);
            let (i8_plan, i8_t) = dynacomm::forward_with_value(&cv8);
            let (b32_plan, b32_t) = dynacomm::backward_with_value(&cv32);
            let (b8_plan, b8_t) = dynacomm::backward_with_value(&cv8);
            assert!(i8_t <= f32_t + 1e-9, "{}: int8 fwd slower", model.name);
            assert!(b8_t <= b32_t + 1e-9, "{}: int8 bwd slower", model.name);
            if f32_plan != i8_plan || b32_plan != b8_plan {
                changed += 1;
            }
        }
        assert!(
            changed > 0,
            "int8 never changed a DynaComm segmentation on any paper model"
        );
    }

    #[test]
    fn more_cuts_cost_more_delta_t_in_comm() {
        let cv = cv4();
        let seq = eval_forward(&cv, &Decomposition::sequential(4));
        let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(4));
        // Total comm busy time grows by (#segments-1)·Δt.
        let seq_comm = seq.comm_only + seq.overlap;
        let lbl_comm = lbl.comm_only + lbl.overlap;
        assert!((lbl_comm - seq_comm - 3.0 * cv.delta_t).abs() < 1e-9);
    }
}
