//! iBatch / iPart — the greedy competitor (Wang et al., AAAI'19 / TPDS'21),
//! as specified by the DynaComm paper's Algorithm 1 (forward) and
//! Algorithm 2 (backward).
//!
//! Transcription notes (the pseudocode in the DynaComm paper has two
//! apparent typos, resolved here the way the surrounding prose demands):
//!
//! * Algorithm 1 never advances `n` inside the repeat loop even though the
//!   coverage test is "next segment's transmission ≥ *current* segment's
//!   computation"; we advance `n ← m` on every step.
//! * Algorithm 1 line 4 breaks ties by "minimum Δt + Σ pt over the *first*
//!   segment", which is constant across the tied pairs; we minimize the
//!   second segment's transmission instead (the smallest covering batch),
//!   matching the greedy intuition in the prose.
//!
//! The companion right-to-left scan ("the other algorithm does the
//! opposite", presented only in [16]) is reconstructed as the mirror
//! greedy: walk from the last layer leftwards, maximizing the computation
//! a segment hides under its successor's transmission. iBatch keeps the
//! better of the two candidates by estimated execution time.

use super::cost::{eval_backward, eval_forward};
use super::{prefix, CostVectors, Decomposition, SchedulePlan, ScheduledPlan, Scheduler};

/// Greedy forward (parameter-transmission) scheduling: best of the
/// left-to-right scan (Algorithm 1) and the reconstructed right-to-left
/// scan.
pub fn forward(cv: &CostVectors) -> Decomposition {
    let l = cv.depth();
    if l < 2 {
        return Decomposition::sequential(l);
    }
    let a = forward_scan(cv);
    let b = reverse_scan(cv);
    if eval_forward(cv, &a).total <= eval_forward(cv, &b).total {
        a
    } else {
        b
    }
}

/// Algorithm 1: left-to-right greedy batching.
fn forward_scan(cv: &CostVectors) -> Decomposition {
    let l = cv.depth();
    let ppt = prefix(&cv.pt);
    let pfc = prefix(&cv.fc);
    let dt = cv.delta_t;

    // Lines 1–4: choose the first two decomposition positions [d1, d2]:
    // pairs where segment 2's transmission covers segment 1's computation,
    // maximizing segment 1's computation, then the smallest covering d2.
    let mut best: Option<(usize, usize)> = None;
    for d1 in 1..l {
        for d2 in d1 + 1..=l {
            let covers = dt + (ppt[d2] - ppt[d1]) >= pfc[d1];
            if !covers {
                continue;
            }
            best = match best {
                None => Some((d1, d2)),
                Some((b1, b2)) => {
                    // max Σ fc(1..d1)  ⇔  max d1 (prefix sums are monotone);
                    // tie-break: min covering transmission ⇔ min d2.
                    if pfc[d1] > pfc[b1] || (pfc[d1] == pfc[b1] && d2 < b2) {
                        Some((d1, d2))
                    } else {
                        Some((b1, b2))
                    }
                }
            };
        }
    }
    let (d1, d2) = match best {
        Some(p) => p,
        // No pair can cover the first segment's compute: batching cannot
        // help the greedy; fall back to the sequential decision.
        None => return Decomposition::sequential(l),
    };

    let mut positions = vec![d1, d2];
    let (mut n, mut m) = (d1, d2);
    // Lines 6–17: extend segment by segment.
    while m != l {
        let need = pfc[m] - pfc[n]; // computation of the current segment
        let mut chosen = l; // fallback: flush the rest in one batch
        let mut best_slack = f64::INFINITY;
        for x in m + 1..=l {
            let comm = dt + (ppt[x] - ppt[m]);
            if comm >= need {
                let slack = comm - need;
                if slack < best_slack {
                    best_slack = slack;
                    chosen = x;
                }
            }
        }
        positions.push(chosen);
        n = m;
        m = chosen;
    }
    Decomposition::from_positions(l, &positions)
}

/// Reconstructed mirror scan: build segments right-to-left, each segment
/// hiding as much computation as fits under its successor's transmission.
fn reverse_scan(cv: &CostVectors) -> Decomposition {
    let l = cv.depth();
    let ppt = prefix(&cv.pt);
    let pfc = prefix(&cv.fc);
    let dt = cv.delta_t;

    let mut positions = Vec::new();
    let mut hi = l; // current segment is (m+1 ..= hi) for the m we pick
    while hi > 0 {
        // Comm budget of the segment ending at hi, for every candidate m:
        // the segment (m+1..hi) transmits Δt + Σpt(m+1..hi); the *previous*
        // segment's compute (.. ..= m) should hide under it. Greedy: choose
        // the smallest m (largest hidden compute) still covered.
        let mut chosen = hi.saturating_sub(1); // fallback: single step left
        for m in (0..hi).rev() {
            let comm = dt + (ppt[hi] - ppt[m]);
            // compute hidden: the whole previous segment is unknown yet;
            // approximate greedily with the compute of layers (m..=?) —
            // use the immediately preceding layer run up to the last cut.
            let prev_compute = pfc[m]; // everything before this boundary
            if comm >= prev_compute {
                chosen = m;
            } else {
                break; // prefix sums are monotone; no smaller m can work
            }
        }
        if chosen == 0 {
            break;
        }
        positions.push(chosen);
        hi = chosen;
    }
    Decomposition::from_positions(l, &positions)
}

/// Algorithm 2: greedy backward (gradient-transmission) scheduling.
pub fn backward(cv: &CostVectors) -> Decomposition {
    let l = cv.depth();
    if l < 2 {
        return Decomposition::sequential(l);
    }
    let dt = cv.delta_t;
    // Σ gt over layers (x ..= L): suffix in physical layer index.
    let mut sgt = vec![0.0; l + 2];
    let mut sbc = vec![0.0; l + 2];
    for x in (1..=l).rev() {
        sgt[x] = sgt[x + 1] + cv.gt[x - 1];
        sbc[x] = sbc[x + 1] + cv.bc[x - 1];
    }

    let mut best: Option<(Decomposition, f64)> = None;
    // Line 2: enumerate the first optional boundary n — the first segment
    // transmits layers L ..= n.
    for n in 2..=l {
        let mut boundaries = vec![n];
        let mut k = 1usize; // transmissions launched so far
        let mut m = n;
        while m != 1 {
            // Options: next boundary x, segment covering (m-1 ..= x);
            // condition: cumulative comm so far ≥ compute of (m-1 ..= x).
            let comm = k as f64 * dt + (sgt[m] - sgt[l + 1]);
            let mut chosen = 1usize; // fallback: flush the rest
            let mut best_slack = f64::INFINITY;
            for x in 1..m {
                let need = sbc[x] - sbc[m]; // Σ bc over (m-1 ..= x)
                if comm >= need {
                    let slack = comm - need;
                    if slack < best_slack {
                        best_slack = slack;
                        chosen = x;
                    }
                }
            }
            boundaries.push(chosen);
            m = chosen;
            k += 1;
        }
        // Boundaries are "segment starts at layer b": segment (prev-1 ..= b)
        // means a physical cut between layers b-1 and b — i.e. positions
        // b-1 in the paper's forward notation — except the terminal 1.
        let cuts: Vec<usize> = boundaries
            .iter()
            .filter(|&&b| b >= 2)
            .map(|&b| b - 1)
            .collect();
        let d = Decomposition::from_positions(l, &cuts);
        let t = eval_backward(cv, &d).total;
        match &best {
            Some((_, bt)) if *bt <= t => {}
            _ => best = Some((d, t)),
        }
    }
    best.unwrap().0
}

/// The greedy competitor behind the [`Scheduler`] API. Stateless — both
/// scans are cheap enough to re-run on every call; predicted finish times
/// come from the O(L) timeline evaluator (the greedy has no table optimum
/// of its own).
#[derive(Debug, Default)]
pub struct IBatchScheduler;

impl IBatchScheduler {
    pub fn new() -> IBatchScheduler {
        IBatchScheduler
    }
}

impl Scheduler for IBatchScheduler {
    fn name(&self) -> &'static str {
        "ibatch"
    }

    fn plan(&mut self, cv: &CostVectors) -> ScheduledPlan {
        let plan = SchedulePlan { fwd: forward(cv), bwd: backward(cv) };
        ScheduledPlan {
            predicted_fwd_ms: eval_forward(cv, &plan.fwd).total,
            predicted_bwd_ms: eval_backward(cv, &plan.bwd).total,
            plan,
            reused: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::random_cv;
    use crate::util::rng::Rng;

    #[test]
    fn forward_valid_decomposition() {
        let mut rng = Rng::new(41);
        for _ in 0..200 {
            let depth = rng.range(1, 30);
            let cv = random_cv(&mut rng, depth);
            let d = forward(&cv);
            assert_eq!(d.depth(), depth);
            let segs = d.fwd_segments();
            assert_eq!(segs.first().unwrap().0, 1);
            assert_eq!(segs.last().unwrap().1, depth);
        }
    }

    #[test]
    fn backward_valid_decomposition() {
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let depth = rng.range(1, 30);
            let cv = random_cv(&mut rng, depth);
            let d = backward(&cv);
            assert_eq!(d.depth(), depth);
            let segs = d.bwd_segments();
            assert_eq!(segs.first().unwrap().0, depth);
            assert_eq!(segs.last().unwrap().1, 1);
        }
    }

    #[test]
    fn batches_when_delta_t_dominates() {
        // Huge Δt: greedy must not produce many tiny segments.
        let cv = CostVectors {
            pt: vec![0.01; 10],
            fc: vec![0.01; 10],
            bc: vec![0.01; 10],
            gt: vec![0.01; 10],
            delta_t: 100.0,
        };
        assert!(forward(&cv).num_transmissions() <= 2);
    }

    #[test]
    fn overlaps_when_costs_are_balanced() {
        // Zero Δt, balanced costs: greedy should decompose (beat sequential).
        let cv = CostVectors {
            pt: vec![1.0; 8],
            fc: vec![1.0; 8],
            bc: vec![2.0; 8],
            gt: vec![1.0; 8],
            delta_t: 0.0,
        };
        let d = forward(&cv);
        let t = eval_forward(&cv, &d).total;
        let seq = eval_forward(&cv, &Decomposition::sequential(8)).total;
        assert!(t < seq, "greedy {t} should beat sequential {seq}");
        let db = backward(&cv);
        let tb = eval_backward(&cv, &db).total;
        let seqb = eval_backward(&cv, &Decomposition::sequential(8)).total;
        assert!(tb < seqb);
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::new(43);
        let cv = random_cv(&mut rng, 15);
        assert_eq!(forward(&cv), forward(&cv));
        assert_eq!(backward(&cv), backward(&cv));
    }
}
