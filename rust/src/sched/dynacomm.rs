//! DynaComm's DP-based scheduling algorithms (Section IV-B).
//!
//! * [`forward`] — Algorithm 3 / Eq. 13: optimal parameter-transmission
//!   decomposition for the forward propagation.
//! * [`backward`] — Algorithm 4 / Eq. 14: optimal gradient-transmission
//!   decomposition for the backward propagation.
//!
//! `F[m][n]` (resp. `B[m][n]`) is the minimum finish time for the first
//! (resp. last) `m` layers using `n` transmission mini-procedures. Segment
//! sums are O(1) via prefix/suffix sums, so both run in O(L^3) time and
//! O(L^2) space, exactly the complexity the paper claims and Fig. 12
//! measures.

use super::cost::{backward_lower_bound, eval_backward, eval_forward, forward_lower_bound};
use super::{prefix, suffix, CostVectors, Decomposition, SchedulePlan, ScheduledPlan, Scheduler};

/// Optimal forward decomposition (Algorithm 3).
pub fn forward(cv: &CostVectors) -> Decomposition {
    forward_with_value(cv).0
}

/// Algorithm 3 plus the DP's own optimum `min_n F[L][n]` — the predicted
/// forward finish time, exposed so tests can cross-check the table value
/// against the independent timeline evaluator and the brute-force oracle.
pub fn forward_with_value(cv: &CostVectors) -> (Decomposition, f64) {
    let l = cv.depth();
    if l == 1 {
        // One mandatory transmission, then the single layer's compute.
        return (Decomposition::sequential(1), cv.delta_t + cv.pt[0] + cv.fc[0]);
    }
    let ppt = prefix(&cv.pt);
    let pfc = prefix(&cv.fc);

    // F[m][n], Path[m][n] flattened; row m, column n, both 0..=L.
    let w = l + 1;
    let mut f = vec![f64::INFINITY; w * w];
    let mut path = vec![usize::MAX; w * w];
    f[0] = 0.0; // F[0][0]

    for m in 1..=l {
        // n·Δt + Σ_{1..m} pt is independent of k; hoist per (m, n).
        for n in 1..=m {
            let arrival = n as f64 * cv.delta_t + ppt[m];
            let mut best = f64::INFINITY;
            let mut best_k = usize::MAX;
            for k in 0..m {
                let prev = f[k * w + n - 1];
                if !prev.is_finite() {
                    continue;
                }
                let t_lst = prev.max(arrival);
                let cand = t_lst + (pfc[m] - pfc[k]);
                if cand < best {
                    best = cand;
                    best_k = k;
                }
            }
            f[m * w + n] = best;
            path[m * w + n] = best_k;
        }
    }

    // Optimal segment count.
    let mut t_forward = f64::INFINITY;
    let mut steps = 0;
    for n in 1..=l {
        if f[l * w + n] < t_forward {
            t_forward = f[l * w + n];
            steps = n;
        }
    }

    // Traceback: enable the decomposition position after layer k for every
    // transition on the optimal path.
    let mut d = Decomposition::sequential(l);
    let mut cur = l;
    for back in 0..steps {
        let k = path[cur * w + (steps - back)];
        debug_assert_ne!(k, usize::MAX);
        if k >= 1 && k <= l - 1 {
            d.cuts[k - 1] = true;
        }
        cur = k;
        if cur == 0 {
            break;
        }
    }
    (d, t_forward)
}

/// Optimal backward decomposition (Algorithm 4).
pub fn backward(cv: &CostVectors) -> Decomposition {
    backward_with_value(cv).0
}

/// Algorithm 4 plus the DP's own optimum `min_n B[L][n]` — the predicted
/// backward finish time (see [`forward_with_value`]).
pub fn backward_with_value(cv: &CostVectors) -> (Decomposition, f64) {
    let l = cv.depth();
    if l == 1 {
        // Compute the single layer, then one mandatory transmission.
        return (Decomposition::sequential(1), cv.bc[0] + cv.delta_t + cv.gt[0]);
    }
    // sbc[m] / sgt[m]: sums over the *last* m layers.
    let sbc = suffix(&cv.bc);
    let sgt = suffix(&cv.gt);

    let w = l + 1;
    let mut b = vec![f64::INFINITY; w * w];
    let mut path = vec![usize::MAX; w * w];
    b[0] = 0.0;

    for m in 1..=l {
        let ready = sbc[m]; // backward compute end of the last m layers
        for n in 1..=m {
            let mut best = f64::INFINITY;
            let mut best_k = usize::MAX;
            for k in 0..m {
                let prev = b[k * w + n - 1];
                if !prev.is_finite() {
                    continue;
                }
                let cand = prev.max(ready) + cv.delta_t + (sgt[m] - sgt[k]);
                if cand < best {
                    best = cand;
                    best_k = k;
                }
            }
            b[m * w + n] = best;
            path[m * w + n] = best_k;
        }
    }

    let mut t_backward = f64::INFINITY;
    let mut steps = 0;
    for n in 1..=l {
        if b[l * w + n] < t_backward {
            t_backward = b[l * w + n];
            steps = n;
        }
    }

    // Traceback: a transition from sub-problem k means the segment boundary
    // sits between physical layers (L-k) and (L-k+1).
    let mut d = Decomposition::sequential(l);
    let mut cur = l;
    for back in 0..steps {
        let k = path[cur * w + (steps - back)];
        debug_assert_ne!(k, usize::MAX);
        if k >= 1 && k <= l - 1 {
            d.cuts[l - k - 1] = true;
        }
        cur = k;
        if cur == 0 {
            break;
        }
    }
    (d, t_backward)
}

/// Sentinel for `gain_threshold_ms` selecting **AUTO** mode: the threshold
/// is derived at run time from the measured DP wall-clock and the
/// iteration's communication idle window instead of being fixed by hand
/// (any negative value selects AUTO; this constant is the canonical
/// spelling, and `--gain-threshold-ms auto` parses to it).
pub const GAIN_THRESHOLD_AUTO: f64 = -1.0;

/// How the re-plan gain threshold is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ThresholdMode {
    /// Operator-supplied threshold (the explicit-flag override).
    Fixed(f64),
    /// Derived from measurements each call; see [`auto_threshold_ms`].
    Auto,
}

/// The AUTO threshold rule. The DP runs on the worker between iterations,
/// so it is *free* while it fits inside the iteration's communication idle
/// window (`idle_ms`: time the CPU would sit waiting on transmissions
/// anyway under the current plan). Any overflow beyond the window delays
/// training once per re-plan, while a better plan pays off on **every** of
/// the `horizon` iterations it will serve — so a re-plan is worth running
/// unless its amortized overflow exceeds the largest gain it could
/// possibly deliver:
///
/// `threshold = max(0, dp_ms − idle_ms) / horizon`
///
/// A DP that fits the idle window yields threshold 0 (always re-plan, it
/// costs nothing); a DP far larger than the window demands a
/// correspondingly large predicted gain before it is re-run.
pub fn auto_threshold_ms(dp_ms: f64, idle_ms: f64, horizon: usize) -> f64 {
    (dp_ms - idle_ms.max(0.0)).max(0.0) / horizon.max(1) as f64
}

/// The paper's strategy behind the [`Scheduler`] API, made stateful: the
/// DP's own table optima are the predicted finish times, and the scheduler
/// caches its last plan so the O(L^3) DP can be *skipped* when re-planning
/// cannot pay for itself (Section IV-C runs the scheduler once per epoch;
/// the ROADMAP asked for this gain-thresholded short-circuit).
///
/// The skip test is sound without running the DP: re-evaluating the cached
/// plan under the fresh cost vectors costs O(L), and no schedule can beat
/// the pass lower bounds `max(Σ comp, Δt + Σ comm)`
/// ([`forward_lower_bound`] / [`backward_lower_bound`]), so
/// `eval(cached) − lower_bound` upper-bounds what a fresh DP could still
/// gain. When that bound is *strictly below* the threshold the cached
/// plan is returned with [`ScheduledPlan::reused`] set. The comparison
/// being strict means a zero threshold re-plans on every call — exactly
/// the stateless behavior, bit-identical plans included.
///
/// The threshold itself is either fixed (the `--gain-threshold-ms` flag)
/// or **auto-tuned** ([`GAIN_THRESHOLD_AUTO`]): the scheduler times its
/// own DP runs (EWMA) and compares that wall-clock against the comm idle
/// window measured from the fresh cost vectors — see
/// [`auto_threshold_ms`] and `docs/SCHEDULER.md`.
pub struct DynaCommScheduler {
    mode: ThresholdMode,
    /// Iterations a plan serves between re-plan opportunities (the
    /// worker's `reschedule_every`); amortizes the DP cost in AUTO mode.
    replan_horizon_iters: usize,
    /// EWMA of the measured DP wall-clock, ms (`None` until the first run).
    dp_ms: Option<f64>,
    /// The threshold the most recent `plan` call applied (observability).
    last_threshold_ms: f64,
    cached: Option<SchedulePlan>,
}

impl DynaCommScheduler {
    /// `gain_threshold_ms = 0.0` disables reuse (always re-plan); a
    /// negative value selects AUTO ([`GAIN_THRESHOLD_AUTO`]); `+∞` means
    /// "reuse whenever a cached plan of the right depth exists". The value
    /// is sanitized, never panicking on user input: NaN collapses to 0
    /// (the safe always-re-plan default; a panic here would surface as an
    /// opaque worker-thread death).
    pub fn new(gain_threshold_ms: f64) -> DynaCommScheduler {
        DynaCommScheduler::with_horizon(gain_threshold_ms, 1)
    }

    /// Like [`DynaCommScheduler::new`], with the AUTO-mode amortization
    /// horizon (iterations per re-plan opportunity; clamped to ≥ 1).
    pub fn with_horizon(gain_threshold_ms: f64, horizon: usize) -> DynaCommScheduler {
        let mode = if gain_threshold_ms.is_nan() {
            ThresholdMode::Fixed(0.0)
        } else if gain_threshold_ms < 0.0 {
            ThresholdMode::Auto
        } else {
            ThresholdMode::Fixed(gain_threshold_ms)
        };
        DynaCommScheduler {
            mode,
            replan_horizon_iters: horizon.max(1),
            dp_ms: None,
            last_threshold_ms: 0.0,
            cached: None,
        }
    }

    /// The configured threshold: the fixed value, or
    /// [`GAIN_THRESHOLD_AUTO`] in AUTO mode.
    pub fn gain_threshold_ms(&self) -> f64 {
        match self.mode {
            ThresholdMode::Fixed(t) => t,
            ThresholdMode::Auto => GAIN_THRESHOLD_AUTO,
        }
    }

    /// Whether the threshold is auto-tuned.
    pub fn is_auto(&self) -> bool {
        self.mode == ThresholdMode::Auto
    }

    /// The threshold applied by the most recent `plan` call (in AUTO mode
    /// this varies with the measured DP cost and idle window).
    pub fn last_threshold_ms(&self) -> f64 {
        self.last_threshold_ms
    }

    #[cfg(test)]
    fn force_dp_ms(&mut self, ms: f64) {
        self.dp_ms = Some(ms);
    }
}

impl Scheduler for DynaCommScheduler {
    fn name(&self) -> &'static str {
        "dynacomm"
    }

    fn plan(&mut self, cv: &CostVectors) -> ScheduledPlan {
        if let Some(cached) = &self.cached {
            if cached.fwd.depth() == cv.depth() {
                let f = eval_forward(cv, &cached.fwd).total;
                let b = eval_backward(cv, &cached.bwd).total;
                let max_gain =
                    (f - forward_lower_bound(cv)) + (b - backward_lower_bound(cv));
                let threshold = match self.mode {
                    ThresholdMode::Fixed(t) => t,
                    ThresholdMode::Auto => {
                        // Idle window under the *cached* plan at fresh
                        // costs: pass finish time minus pure compute.
                        let idle = (f - cv.fc.iter().sum::<f64>()).max(0.0)
                            + (b - cv.bc.iter().sum::<f64>()).max(0.0);
                        match self.dp_ms {
                            Some(dp) => {
                                auto_threshold_ms(dp, idle, self.replan_horizon_iters)
                            }
                            // No DP timing yet: re-plan (and measure).
                            None => 0.0,
                        }
                    }
                };
                self.last_threshold_ms = threshold;
                // Strict comparison plus the explicit zero guard: threshold
                // 0 must always re-plan even if rounding drives the
                // (mathematically non-negative) gain bound a hair below 0.
                if threshold > 0.0 && max_gain < threshold {
                    return ScheduledPlan {
                        plan: cached.clone(),
                        predicted_fwd_ms: f,
                        predicted_bwd_ms: b,
                        reused: true,
                    };
                }
            }
        }
        let t0 = std::time::Instant::now();
        let (fwd, predicted_fwd_ms) = forward_with_value(cv);
        let (bwd, predicted_bwd_ms) = backward_with_value(cv);
        let dp = t0.elapsed().as_secs_f64() * 1e3;
        // Smooth the DP wall-clock so one noisy measurement cannot swing
        // the AUTO threshold.
        self.dp_ms = Some(match self.dp_ms {
            None => dp,
            Some(prev) => 0.5 * dp + 0.5 * prev,
        });
        let plan = SchedulePlan { fwd, bwd };
        self.cached = Some(plan.clone());
        ScheduledPlan { plan, predicted_fwd_ms, predicted_bwd_ms, reused: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::cost::{eval_backward, eval_forward};
    use crate::sched::testutil::random_cv;
    use crate::util::rng::Rng;

    #[test]
    fn forward_prefers_sequential_when_delta_t_is_huge() {
        // With Δt far larger than any possible overlap gain, one segment
        // must win.
        let cv = CostVectors {
            pt: vec![1.0; 6],
            fc: vec![1.0; 6],
            bc: vec![1.0; 6],
            gt: vec![1.0; 6],
            delta_t: 1000.0,
        };
        let d = forward(&cv);
        assert_eq!(d.num_transmissions(), 1);
        let d = backward(&cv);
        assert_eq!(d.num_transmissions(), 1);
    }

    #[test]
    fn forward_prefers_lbl_when_delta_t_is_zero_and_balanced() {
        // Δt = 0 and perfectly balanced per-layer costs: maximal
        // decomposition can only help.
        let cv = CostVectors {
            pt: vec![1.0; 5],
            fc: vec![1.0; 5],
            bc: vec![1.0; 5],
            gt: vec![1.0; 5],
            delta_t: 0.0,
        };
        let d = forward(&cv);
        let t = eval_forward(&cv, &d).total;
        let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(5)).total;
        assert!((t - lbl).abs() < 1e-9, "dp={t} lbl={lbl}");
    }

    #[test]
    fn forward_beats_or_ties_fixed_strategies() {
        let mut rng = Rng::new(21);
        for _ in 0..300 {
            let depth = rng.range(1, 24);
            let cv = random_cv(&mut rng, depth);
            let d = forward(&cv);
            let t = eval_forward(&cv, &d).total;
            let seq = eval_forward(&cv, &Decomposition::sequential(depth)).total;
            let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(depth)).total;
            assert!(t <= seq + 1e-9, "dp={t} seq={seq} depth={depth}");
            assert!(t <= lbl + 1e-9, "dp={t} lbl={lbl} depth={depth}");
        }
    }

    #[test]
    fn backward_beats_or_ties_fixed_strategies() {
        let mut rng = Rng::new(22);
        for _ in 0..300 {
            let depth = rng.range(1, 24);
            let cv = random_cv(&mut rng, depth);
            let d = backward(&cv);
            let t = eval_backward(&cv, &d).total;
            let seq = eval_backward(&cv, &Decomposition::sequential(depth)).total;
            let lbl = eval_backward(&cv, &Decomposition::layer_by_layer(depth)).total;
            assert!(t <= seq + 1e-9, "dp={t} seq={seq} depth={depth}");
            assert!(t <= lbl + 1e-9, "dp={t} lbl={lbl} depth={depth}");
        }
    }

    #[test]
    fn dp_value_matches_timeline_eval() {
        // The DP's table optimum must agree with the independent O(L)
        // timeline evaluator applied to the traced-back decomposition —
        // a mismatch means either the recurrence or the traceback drifted
        // from the paper's timeline semantics. Also deterministic across
        // calls, and (at small depth) equal to the exhaustive optimum.
        let mut rng = Rng::new(23);
        for _ in 0..100 {
            let depth = rng.range(1, 16);
            let cv = random_cv(&mut rng, depth);
            let (df, value_f) = forward_with_value(&cv);
            assert_eq!(df, forward(&cv), "deterministic");
            let eval_f = eval_forward(&cv, &df).total;
            assert!(
                (value_f - eval_f).abs() < 1e-9,
                "depth={depth}: fwd DP value {value_f} vs eval {eval_f}"
            );
            let (db, value_b) = backward_with_value(&cv);
            assert_eq!(db, backward(&cv), "deterministic");
            let eval_b = eval_backward(&cv, &db).total;
            assert!(
                (value_b - eval_b).abs() < 1e-9,
                "depth={depth}: bwd DP value {value_b} vs eval {eval_b}"
            );
            // Small-depth exhaustive cross-check: the DP's own value must
            // equal the brute-force optimum, not merely the eval of its
            // traceback.
            if depth <= 10 {
                let (_, best_f) = crate::sched::bruteforce::forward(&cv);
                assert!(
                    (value_f - best_f).abs() < 1e-9,
                    "depth={depth}: fwd DP {value_f} vs brute {best_f}"
                );
                let (_, best_b) = crate::sched::bruteforce::backward(&cv);
                assert!(
                    (value_b - best_b).abs() < 1e-9,
                    "depth={depth}: bwd DP {value_b} vs brute {best_b}"
                );
            }
        }
    }

    #[test]
    fn paper_fig3_toy_network() {
        // The 4-layer toy network of Fig. 3: a dynamic schedule must beat
        // both Sequential and LBL when Δt is non-trivial and costs are
        // imbalanced.
        let cv = CostVectors {
            pt: vec![4.0, 1.0, 1.0, 6.0],
            fc: vec![1.0, 6.0, 2.0, 2.0],
            bc: vec![2.0, 12.0, 4.0, 4.0],
            gt: vec![4.0, 1.0, 1.0, 6.0],
            delta_t: 1.5,
        };
        let d = forward(&cv);
        let dp = eval_forward(&cv, &d).total;
        let seq = eval_forward(&cv, &Decomposition::sequential(4)).total;
        let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(4)).total;
        assert!(dp < seq && dp < lbl, "dp={dp} seq={seq} lbl={lbl}");
    }

    #[test]
    fn zero_threshold_always_replans_and_matches_stateless() {
        // Threshold 0 must be bit-identical to calling the DP fresh every
        // time, across a drifting sequence of profiles.
        let mut rng = Rng::new(61);
        let mut s = DynaCommScheduler::new(0.0);
        for _ in 0..50 {
            let depth = rng.range(1, 16);
            let cv = random_cv(&mut rng, depth);
            let sp = s.plan(&cv);
            assert!(!sp.reused, "threshold 0 reused a cached plan");
            assert_eq!(sp.plan.fwd, forward(&cv));
            assert_eq!(sp.plan.bwd, backward(&cv));
            let (_, vf) = forward_with_value(&cv);
            assert!((sp.predicted_fwd_ms - vf).abs() < 1e-12);
        }
    }

    #[test]
    fn infinite_threshold_reuses_after_first_plan() {
        let mut rng = Rng::new(62);
        let mut s = DynaCommScheduler::new(f64::INFINITY);
        let depth = 12;
        let cv0 = random_cv(&mut rng, depth);
        let first = s.plan(&cv0);
        assert!(!first.reused, "nothing cached yet");
        for _ in 0..10 {
            let cv = random_cv(&mut rng, depth);
            let sp = s.plan(&cv);
            assert!(sp.reused, "infinite threshold must reuse");
            assert_eq!(sp.plan, first.plan);
            // Reused predictions are the cached plan re-evaluated under the
            // *fresh* costs, not the stale first-call values.
            let f = eval_forward(&cv, &sp.plan.fwd).total;
            assert!((sp.predicted_fwd_ms - f).abs() < 1e-9);
        }
    }

    #[test]
    fn threshold_is_sanitized_not_panicking() {
        // Bad CLI/config values must not kill a worker thread: NaN
        // collapses to the always-re-plan default, negatives mean AUTO.
        assert!(!DynaCommScheduler::new(f64::NAN).is_auto());
        assert_eq!(DynaCommScheduler::new(f64::NAN).gain_threshold_ms(), 0.0);
        assert!(DynaCommScheduler::new(-3.0).is_auto());
        assert!(DynaCommScheduler::new(GAIN_THRESHOLD_AUTO).is_auto());
        assert_eq!(
            DynaCommScheduler::new(f64::INFINITY).gain_threshold_ms(),
            f64::INFINITY
        );
    }

    #[test]
    fn auto_threshold_formula() {
        // DP inside the idle window: free, threshold 0.
        assert_eq!(auto_threshold_ms(3.0, 10.0, 1), 0.0);
        assert_eq!(auto_threshold_ms(10.0, 10.0, 5), 0.0);
        // Overflow amortized over the horizon.
        assert_eq!(auto_threshold_ms(25.0, 10.0, 1), 15.0);
        assert_eq!(auto_threshold_ms(25.0, 10.0, 30), 0.5);
        // Degenerate inputs stay safe.
        assert_eq!(auto_threshold_ms(5.0, -3.0, 0), 5.0);
        assert_eq!(auto_threshold_ms(0.0, 0.0, 10), 0.0);
    }

    #[test]
    fn auto_mode_replans_while_dp_is_free() {
        // Comm-dominated profile: the idle window dwarfs any DP cost, so
        // AUTO keeps re-planning exactly like threshold 0.
        let cv = CostVectors {
            pt: vec![50.0; 8],
            fc: vec![0.1; 8],
            bc: vec![0.1; 8],
            gt: vec![50.0; 8],
            delta_t: 2.0,
        };
        let mut s = DynaCommScheduler::with_horizon(GAIN_THRESHOLD_AUTO, 10);
        assert!(!s.plan(&cv).reused, "first call always plans");
        for _ in 0..5 {
            assert!(!s.plan(&cv).reused, "free DP must re-plan");
            assert_eq!(s.last_threshold_ms(), 0.0);
        }
    }

    #[test]
    fn auto_mode_reuses_when_dp_overwhelms_the_idle_window() {
        let mut rng = Rng::new(65);
        let cv = random_cv(&mut rng, 10);
        let mut s = DynaCommScheduler::with_horizon(GAIN_THRESHOLD_AUTO, 1);
        assert!(!s.plan(&cv).reused);
        // Pretend the DP costs an hour: no conceivable gain can pay for
        // it, so AUTO must answer from the cache.
        s.force_dp_ms(3_600_000.0);
        let sp = s.plan(&cv);
        assert!(sp.reused, "astronomical DP cost must be skipped");
        assert!(s.last_threshold_ms() > 0.0);
        // And dialing the measured cost back to zero re-enables planning.
        s.force_dp_ms(0.0);
        assert!(!s.plan(&cv).reused);
        assert_eq!(s.last_threshold_ms(), 0.0);
    }

    #[test]
    fn depth_change_always_replans() {
        let mut rng = Rng::new(63);
        let mut s = DynaCommScheduler::new(f64::INFINITY);
        assert!(!s.plan(&random_cv(&mut rng, 8)).reused);
        let sp = s.plan(&random_cv(&mut rng, 9));
        assert!(!sp.reused, "cached plan for the wrong depth was reused");
        assert_eq!(sp.plan.fwd.depth(), 9);
    }

    #[test]
    fn reuse_never_costs_more_than_the_threshold() {
        // The contract of gain-thresholded re-planning: whenever the cached
        // plan is reused, its finish time under the fresh costs exceeds the
        // fresh DP optimum by strictly less than the threshold.
        let mut rng = Rng::new(64);
        for threshold in [0.5, 2.0, 10.0] {
            let mut s = DynaCommScheduler::new(threshold);
            let mut reuses = 0;
            for _ in 0..60 {
                let depth = rng.range(2, 12);
                let cv = random_cv(&mut rng, depth);
                let sp = s.plan(&cv);
                if sp.reused {
                    reuses += 1;
                    let (_, best_f) = forward_with_value(&cv);
                    let (_, best_b) = backward_with_value(&cv);
                    let regret = (sp.predicted_fwd_ms - best_f)
                        + (sp.predicted_bwd_ms - best_b);
                    assert!(
                        regret < threshold + 1e-9,
                        "reuse regret {regret} >= threshold {threshold}"
                    );
                }
            }
            let _ = reuses; // reuse frequency is workload-dependent
        }
        // Deterministic reuse: on a pure-comm profile the DP plan sits
        // exactly on the lower bound, so the predicted gain is 0 and any
        // positive threshold must reuse.
        let cv = CostVectors {
            pt: vec![5.0, 5.0],
            fc: vec![0.0, 0.0],
            bc: vec![0.0, 0.0],
            gt: vec![5.0, 5.0],
            delta_t: 1.0,
        };
        let mut s = DynaCommScheduler::new(1e-6);
        assert!(!s.plan(&cv).reused);
        assert!(s.plan(&cv).reused, "zero-gain re-plan was not skipped");
    }
}
