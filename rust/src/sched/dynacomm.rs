//! DynaComm's DP-based scheduling algorithms (Section IV-B).
//!
//! * [`forward`] — Algorithm 3 / Eq. 13: optimal parameter-transmission
//!   decomposition for the forward propagation.
//! * [`backward`] — Algorithm 4 / Eq. 14: optimal gradient-transmission
//!   decomposition for the backward propagation.
//!
//! `F[m][n]` (resp. `B[m][n]`) is the minimum finish time for the first
//! (resp. last) `m` layers using `n` transmission mini-procedures. Segment
//! sums are O(1) via prefix/suffix sums, so both run in O(L^3) time and
//! O(L^2) space, exactly the complexity the paper claims and Fig. 12
//! measures.

use super::{prefix, suffix, CostVectors, Decomposition};

/// Optimal forward decomposition (Algorithm 3).
pub fn forward(cv: &CostVectors) -> Decomposition {
    let l = cv.depth();
    if l == 1 {
        return Decomposition::sequential(1);
    }
    let ppt = prefix(&cv.pt);
    let pfc = prefix(&cv.fc);

    // F[m][n], Path[m][n] flattened; row m, column n, both 0..=L.
    let w = l + 1;
    let mut f = vec![f64::INFINITY; w * w];
    let mut path = vec![usize::MAX; w * w];
    f[0] = 0.0; // F[0][0]

    for m in 1..=l {
        // n·Δt + Σ_{1..m} pt is independent of k; hoist per (m, n).
        for n in 1..=m {
            let arrival = n as f64 * cv.delta_t + ppt[m];
            let mut best = f64::INFINITY;
            let mut best_k = usize::MAX;
            for k in 0..m {
                let prev = f[k * w + n - 1];
                if !prev.is_finite() {
                    continue;
                }
                let t_lst = prev.max(arrival);
                let cand = t_lst + (pfc[m] - pfc[k]);
                if cand < best {
                    best = cand;
                    best_k = k;
                }
            }
            f[m * w + n] = best;
            path[m * w + n] = best_k;
        }
    }

    // Optimal segment count.
    let mut t_forward = f64::INFINITY;
    let mut steps = 0;
    for n in 1..=l {
        if f[l * w + n] < t_forward {
            t_forward = f[l * w + n];
            steps = n;
        }
    }

    // Traceback: enable the decomposition position after layer k for every
    // transition on the optimal path.
    let mut d = Decomposition::sequential(l);
    let mut cur = l;
    for back in 0..steps {
        let k = path[cur * w + (steps - back)];
        debug_assert_ne!(k, usize::MAX);
        if k >= 1 && k <= l - 1 {
            d.cuts[k - 1] = true;
        }
        cur = k;
        if cur == 0 {
            break;
        }
    }
    d
}

/// Optimal backward decomposition (Algorithm 4).
pub fn backward(cv: &CostVectors) -> Decomposition {
    let l = cv.depth();
    if l == 1 {
        return Decomposition::sequential(1);
    }
    // sbc[m] / sgt[m]: sums over the *last* m layers.
    let sbc = suffix(&cv.bc);
    let sgt = suffix(&cv.gt);

    let w = l + 1;
    let mut b = vec![f64::INFINITY; w * w];
    let mut path = vec![usize::MAX; w * w];
    b[0] = 0.0;

    for m in 1..=l {
        let ready = sbc[m]; // backward compute end of the last m layers
        for n in 1..=m {
            let mut best = f64::INFINITY;
            let mut best_k = usize::MAX;
            for k in 0..m {
                let prev = b[k * w + n - 1];
                if !prev.is_finite() {
                    continue;
                }
                let cand = prev.max(ready) + cv.delta_t + (sgt[m] - sgt[k]);
                if cand < best {
                    best = cand;
                    best_k = k;
                }
            }
            b[m * w + n] = best;
            path[m * w + n] = best_k;
        }
    }

    let mut t_backward = f64::INFINITY;
    let mut steps = 0;
    for n in 1..=l {
        if b[l * w + n] < t_backward {
            t_backward = b[l * w + n];
            steps = n;
        }
    }

    // Traceback: a transition from sub-problem k means the segment boundary
    // sits between physical layers (L-k) and (L-k+1).
    let mut d = Decomposition::sequential(l);
    let mut cur = l;
    for back in 0..steps {
        let k = path[cur * w + (steps - back)];
        debug_assert_ne!(k, usize::MAX);
        if k >= 1 && k <= l - 1 {
            d.cuts[l - k - 1] = true;
        }
        cur = k;
        if cur == 0 {
            break;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::cost::{eval_backward, eval_forward};
    use crate::sched::testutil::random_cv;
    use crate::util::rng::Rng;

    #[test]
    fn forward_prefers_sequential_when_delta_t_is_huge() {
        // With Δt far larger than any possible overlap gain, one segment
        // must win.
        let cv = CostVectors {
            pt: vec![1.0; 6],
            fc: vec![1.0; 6],
            bc: vec![1.0; 6],
            gt: vec![1.0; 6],
            delta_t: 1000.0,
        };
        let d = forward(&cv);
        assert_eq!(d.num_transmissions(), 1);
        let d = backward(&cv);
        assert_eq!(d.num_transmissions(), 1);
    }

    #[test]
    fn forward_prefers_lbl_when_delta_t_is_zero_and_balanced() {
        // Δt = 0 and perfectly balanced per-layer costs: maximal
        // decomposition can only help.
        let cv = CostVectors {
            pt: vec![1.0; 5],
            fc: vec![1.0; 5],
            bc: vec![1.0; 5],
            gt: vec![1.0; 5],
            delta_t: 0.0,
        };
        let d = forward(&cv);
        let t = eval_forward(&cv, &d).total;
        let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(5)).total;
        assert!((t - lbl).abs() < 1e-9, "dp={t} lbl={lbl}");
    }

    #[test]
    fn forward_beats_or_ties_fixed_strategies() {
        let mut rng = Rng::new(21);
        for _ in 0..300 {
            let depth = rng.range(1, 24);
            let cv = random_cv(&mut rng, depth);
            let d = forward(&cv);
            let t = eval_forward(&cv, &d).total;
            let seq = eval_forward(&cv, &Decomposition::sequential(depth)).total;
            let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(depth)).total;
            assert!(t <= seq + 1e-9, "dp={t} seq={seq} depth={depth}");
            assert!(t <= lbl + 1e-9, "dp={t} lbl={lbl} depth={depth}");
        }
    }

    #[test]
    fn backward_beats_or_ties_fixed_strategies() {
        let mut rng = Rng::new(22);
        for _ in 0..300 {
            let depth = rng.range(1, 24);
            let cv = random_cv(&mut rng, depth);
            let d = backward(&cv);
            let t = eval_backward(&cv, &d).total;
            let seq = eval_backward(&cv, &Decomposition::sequential(depth)).total;
            let lbl = eval_backward(&cv, &Decomposition::layer_by_layer(depth)).total;
            assert!(t <= seq + 1e-9, "dp={t} seq={seq} depth={depth}");
            assert!(t <= lbl + 1e-9, "dp={t} lbl={lbl} depth={depth}");
        }
    }

    #[test]
    fn dp_value_matches_timeline_eval() {
        // The decomposition traced back from the DP table must evaluate
        // (under the independent timeline evaluator) to a value no worse
        // than any fixed competitor and self-consistent across calls.
        let mut rng = Rng::new(23);
        for _ in 0..100 {
            let depth = rng.range(2, 16);
            let cv = random_cv(&mut rng, depth);
            let d1 = forward(&cv);
            let d2 = forward(&cv);
            assert_eq!(d1, d2, "deterministic");
        }
    }

    #[test]
    fn paper_fig3_toy_network() {
        // The 4-layer toy network of Fig. 3: a dynamic schedule must beat
        // both Sequential and LBL when Δt is non-trivial and costs are
        // imbalanced.
        let cv = CostVectors {
            pt: vec![4.0, 1.0, 1.0, 6.0],
            fc: vec![1.0, 6.0, 2.0, 2.0],
            bc: vec![2.0, 12.0, 4.0, 4.0],
            gt: vec![4.0, 1.0, 1.0, 6.0],
            delta_t: 1.5,
        };
        let d = forward(&cv);
        let dp = eval_forward(&cv, &d).total;
        let seq = eval_forward(&cv, &Decomposition::sequential(4)).total;
        let lbl = eval_forward(&cv, &Decomposition::layer_by_layer(4)).total;
        assert!(dp < seq && dp < lbl, "dp={dp} seq={seq} lbl={lbl}");
    }
}
