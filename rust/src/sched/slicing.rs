//! P3-style fixed-granularity slicing baseline (Jayarajan et al., SysML'19;
//! discussed in the paper's Section II-B).
//!
//! Instead of batching whole layers, P3 slices every tensor at a fixed
//! byte granularity and pipelines the slices. Under the paper's layer-wise
//! cost abstraction that corresponds to cutting the layer sequence so no
//! segment carries more than `slice_ms` of transmission — paying `Δt` per
//! slice. It makes the granularity/overhead trade-off explicit: too small
//! a slice drowns in `Δt` (the "tricky parameter" ByteScheduler later
//! auto-tunes), too large a slice loses overlap. DynaComm's DP sidesteps
//! the knob entirely; the `schedule_sensitivity` example ablates it.

use super::{CostVectors, Decomposition, SchedulePlan, ScheduledPlan, Scheduler};

/// Cut greedily so each segment's transmission payload stays below
/// `slice_ms` (always cutting at layer boundaries — the finest legal
/// granularity of the layer-wise model; a single over-size layer becomes
/// its own segment).
pub fn forward_slices(cv: &CostVectors, slice_ms: f64) -> Decomposition {
    slices(&cv.pt, slice_ms)
}

pub fn backward_slices(cv: &CostVectors, slice_ms: f64) -> Decomposition {
    // Backward flushes deepest-first; the budgeting walks the transmission
    // order, i.e. reversed layer order.
    let rev: Vec<f64> = cv.gt.iter().rev().copied().collect();
    let d = slices(&rev, slice_ms);
    // Mirror the cut positions back to physical layer indexing.
    let mut cuts = d.cuts;
    cuts.reverse();
    Decomposition { cuts }
}

fn slices(costs: &[f64], slice_ms: f64) -> Decomposition {
    assert!(slice_ms > 0.0);
    let depth = costs.len();
    let mut d = Decomposition::sequential(depth);
    let mut acc = 0.0;
    for l in 0..depth - 1 {
        acc += costs[l];
        if acc + costs[l + 1] > slice_ms {
            d.cuts[l] = true;
            acc = 0.0;
        }
    }
    d
}

/// ByteScheduler-style auto-tuning, reduced to its essence: sweep the
/// granularity and keep the best by measured cost. Still a one-dimensional
/// family, so DynaComm (which searches all `2^(L-1)` decompositions in
/// polynomial time) upper-bounds it.
pub fn forward_autotuned(cv: &CostVectors) -> (Decomposition, f64) {
    let total: f64 = cv.pt.iter().sum();
    let mut best: Option<(Decomposition, f64)> = None;
    for steps in 1..=cv.depth() {
        let d = forward_slices(cv, (total / steps as f64).max(1e-9));
        let t = super::cost::eval_forward(cv, &d).total;
        if best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((d, t));
        }
    }
    best.unwrap()
}

/// Backward twin of [`forward_autotuned`]: sweep the gradient-slice
/// granularity and keep the best by the backward timeline evaluator.
pub fn backward_autotuned(cv: &CostVectors) -> (Decomposition, f64) {
    let total: f64 = cv.gt.iter().sum();
    let mut best: Option<(Decomposition, f64)> = None;
    for steps in 1..=cv.depth() {
        let d = backward_slices(cv, (total / steps as f64).max(1e-9));
        let t = super::cost::eval_backward(cv, &d).total;
        if best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((d, t));
        }
    }
    best.unwrap()
}

/// P3/ByteScheduler-style auto-tuned slicing behind the [`Scheduler`] API —
/// a registry entry the legacy `Strategy` enum never had, exercising the
/// registry's open extension point. Stateless: the granularity sweep is
/// O(L^2) and re-runs every call.
#[derive(Debug, Default)]
pub struct SlicingScheduler;

impl SlicingScheduler {
    pub fn new() -> SlicingScheduler {
        SlicingScheduler
    }
}

impl Scheduler for SlicingScheduler {
    fn name(&self) -> &'static str {
        "slicing"
    }

    fn plan(&mut self, cv: &CostVectors) -> ScheduledPlan {
        let (fwd, predicted_fwd_ms) = forward_autotuned(cv);
        let (bwd, predicted_bwd_ms) = backward_autotuned(cv);
        ScheduledPlan {
            plan: SchedulePlan { fwd, bwd },
            predicted_fwd_ms,
            predicted_bwd_ms,
            reused: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::cost::{eval_backward, eval_forward};
    use crate::sched::testutil::random_cv;
    use crate::sched::{bruteforce, dynacomm};
    use crate::util::rng::Rng;

    #[test]
    fn huge_slice_is_sequential() {
        let mut rng = Rng::new(71);
        let cv = random_cv(&mut rng, 10);
        let d = forward_slices(&cv, f64::INFINITY);
        assert_eq!(d.num_transmissions(), 1);
    }

    #[test]
    fn tiny_slice_is_layer_by_layer() {
        let mut rng = Rng::new(72);
        let cv = random_cv(&mut rng, 10);
        let d = forward_slices(&cv, 1e-12);
        assert_eq!(d.num_transmissions(), 10);
    }

    #[test]
    fn segments_respect_budget() {
        let mut rng = Rng::new(73);
        for _ in 0..50 {
            let depth = rng.range(2, 30);
            let cv = random_cv(&mut rng, depth);
            let budget = rng.range_f64(0.5, 10.0);
            let d = forward_slices(&cv, budget);
            for (a, b) in d.fwd_segments() {
                let payload: f64 = cv.pt[a - 1..b].iter().sum();
                // Single-layer segments may exceed the budget (cannot split
                // below a layer); multi-layer segments must respect it.
                if b > a {
                    assert!(payload <= budget + 1e-9, "payload {payload} > {budget}");
                }
            }
        }
    }

    #[test]
    fn backward_mirrors_forward() {
        let mut rng = Rng::new(74);
        let cv = random_cv(&mut rng, 8);
        let d = backward_slices(&cv, 2.0);
        // Transmission order is deepest-first; every multi-layer segment's
        // payload obeys the budget.
        for (hi, lo) in d.bwd_segments() {
            if hi > lo {
                let payload: f64 = cv.gt[lo - 1..hi].iter().sum();
                assert!(payload <= 2.0 + 1e-9);
            }
        }
    }

    /// DynaComm dominates the whole auto-tuned slicing family — the repo's
    /// ablation for the paper's Section II-B discussion.
    #[test]
    fn dynacomm_dominates_autotuned_slicing() {
        let mut rng = Rng::new(75);
        let mut strictly_better = 0;
        for _ in 0..200 {
            let depth = rng.range(3, 14);
            let cv = random_cv(&mut rng, depth);
            let (_, tuned) = forward_autotuned(&cv);
            let dp = eval_forward(&cv, &dynacomm::forward(&cv)).total;
            assert!(dp <= tuned + 1e-7, "slicing beat the DP: {cv:?}");
            if dp < tuned - 1e-6 {
                strictly_better += 1;
            }
        }
        assert!(strictly_better > 0, "DP never strictly beat slicing");
    }

    #[test]
    fn slicing_valid_against_bruteforce_bounds() {
        let mut rng = Rng::new(76);
        for _ in 0..50 {
            let depth = rng.range(2, 11);
            let cv = random_cv(&mut rng, depth);
            let (_, best_f) = bruteforce::forward(&cv);
            let (_, tuned) = forward_autotuned(&cv);
            assert!(tuned >= best_f - 1e-9);
            let d = backward_slices(&cv, 3.0);
            let t = eval_backward(&cv, &d).total;
            let (_, best_b) = bruteforce::backward(&cv);
            assert!(t >= best_b - 1e-9);
        }
    }
}
