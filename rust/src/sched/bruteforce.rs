//! Exact exhaustive search over all `2^(L-1)` decomposition decisions —
//! the `O(L·2^L)` brute force the paper dismisses as impractical
//! (Section III-B). It is exactly what makes it valuable here: an
//! optimality oracle the DP algorithms are property-tested against.

use super::cost::{eval_backward, eval_forward};
use super::{CostVectors, Decomposition, SchedulePlan, ScheduledPlan, Scheduler};

/// Practical depth cap: 2^24 evaluations is already seconds of work.
pub const MAX_DEPTH: usize = 24;

/// Depth up to which the exhaustive search is cheap enough for debug-mode
/// property tests (≤ 2^12 evaluations, milliseconds). The band
/// `(TEST_TRACTABLE_DEPTH, MAX_DEPTH]` still *runs* if asked — it is just
/// too slow to sweep in tests, which skip it via [`intractable_in_tests`].
pub const TEST_TRACTABLE_DEPTH: usize = 13;

/// True for depths where the enumeration would actually run (≤
/// [`MAX_DEPTH`], i.e. no DP fallback) but is too slow for test sweeps.
pub fn intractable_in_tests(depth: usize) -> bool {
    (TEST_TRACTABLE_DEPTH + 1..=MAX_DEPTH).contains(&depth)
}

/// Exhaustive optimum for the forward pass: `(best decomposition, time)`.
pub fn forward(cv: &CostVectors) -> (Decomposition, f64) {
    search(cv, |cv, d| eval_forward(cv, d).total)
}

/// Exhaustive optimum for the backward pass.
pub fn backward(cv: &CostVectors) -> (Decomposition, f64) {
    search(cv, |cv, d| eval_backward(cv, d).total)
}

fn search(
    cv: &CostVectors,
    eval: impl Fn(&CostVectors, &Decomposition) -> f64,
) -> (Decomposition, f64) {
    let l = cv.depth();
    assert!(
        l <= MAX_DEPTH,
        "brute force over {l} layers would need 2^{} evaluations",
        l - 1
    );
    let mut best = Decomposition::sequential(l);
    let mut best_t = eval(cv, &best);
    let mut d = Decomposition::sequential(l);
    for mask in 1u64..(1u64 << (l - 1)) {
        for (i, c) in d.cuts.iter_mut().enumerate() {
            *c = mask >> i & 1 == 1;
        }
        let t = eval(cv, &d);
        if t < best_t {
            best_t = t;
            best = d.clone();
        }
    }
    (best, best_t)
}

/// The exhaustive oracle behind the [`Scheduler`] API. Beyond
/// [`MAX_DEPTH`] it falls back to the DP (provably the same optimum, see
/// the optimality property tests) so registry consumers can never trigger
/// 2^L work by accident.
#[derive(Debug, Default)]
pub struct BruteForceScheduler;

impl BruteForceScheduler {
    pub fn new() -> BruteForceScheduler {
        BruteForceScheduler
    }
}

impl Scheduler for BruteForceScheduler {
    fn name(&self) -> &'static str {
        "bruteforce"
    }

    fn plan(&mut self, cv: &CostVectors) -> ScheduledPlan {
        let ((fwd, predicted_fwd_ms), (bwd, predicted_bwd_ms)) = if cv.depth() > MAX_DEPTH {
            (
                super::dynacomm::forward_with_value(cv),
                super::dynacomm::backward_with_value(cv),
            )
        } else {
            (forward(cv), backward(cv))
        };
        ScheduledPlan {
            plan: SchedulePlan { fwd, bwd },
            predicted_fwd_ms,
            predicted_bwd_ms,
            reused: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::random_cv;
    use crate::sched::{dynacomm, ibatch};
    use crate::util::rng::Rng;

    /// The paper's central claim, tested as a property: the DP schedule is
    /// *optimal* — it matches exhaustive search on every random instance.
    #[test]
    fn dynacomm_forward_is_optimal() {
        let mut rng = Rng::new(31);
        for _ in 0..400 {
            let depth = rng.range(1, 13);
            let cv = random_cv(&mut rng, depth);
            let (_, best) = forward(&cv);
            let dp = super::super::cost::eval_forward(&cv, &dynacomm::forward(&cv)).total;
            assert!(
                (dp - best).abs() < 1e-7,
                "depth={depth} dp={dp} brute={best} cv={cv:?}"
            );
        }
    }

    #[test]
    fn dynacomm_backward_is_optimal() {
        let mut rng = Rng::new(32);
        for _ in 0..400 {
            let depth = rng.range(1, 13);
            let cv = random_cv(&mut rng, depth);
            let (_, best) = backward(&cv);
            let dp = super::super::cost::eval_backward(&cv, &dynacomm::backward(&cv)).total;
            assert!(
                (dp - best).abs() < 1e-7,
                "depth={depth} dp={dp} brute={best} cv={cv:?}"
            );
        }
    }

    /// iBatch is greedy: it must never beat the exhaustive optimum, and on
    /// some instances it must be strictly worse (otherwise the paper's
    /// motivation evaporates).
    #[test]
    fn ibatch_is_suboptimal_somewhere() {
        let mut rng = Rng::new(33);
        let mut strictly_worse_fwd = 0;
        let mut strictly_worse_bwd = 0;
        for _ in 0..200 {
            let depth = rng.range(4, 13);
            let cv = random_cv(&mut rng, depth);
            let (_, best_f) = forward(&cv);
            let ib_f =
                super::super::cost::eval_forward(&cv, &ibatch::forward(&cv)).total;
            assert!(ib_f >= best_f - 1e-7, "greedy beat the optimum?!");
            if ib_f > best_f + 1e-6 {
                strictly_worse_fwd += 1;
            }
            let (_, best_b) = backward(&cv);
            let ib_b =
                super::super::cost::eval_backward(&cv, &ibatch::backward(&cv)).total;
            assert!(ib_b >= best_b - 1e-7);
            if ib_b > best_b + 1e-6 {
                strictly_worse_bwd += 1;
            }
        }
        assert!(strictly_worse_fwd > 0, "iBatch fwd was optimal everywhere");
        assert!(strictly_worse_bwd > 0, "iBatch bwd was optimal everywhere");
    }

    #[test]
    #[should_panic]
    fn depth_cap_enforced() {
        let mut rng = Rng::new(34);
        let cv = random_cv(&mut rng, MAX_DEPTH + 1);
        let _ = forward(&cv);
    }
}
