//! Layer-wise communication scheduling — the paper's contribution.
//!
//! Terminology (Section III): an iteration is `[pt, fc, bc, gt]`; each
//! procedure splits into per-layer mini-procedures. A *decomposition
//! decision* picks which of the `L-1` optional positions between adjacent
//! layers start a new transmission mini-procedure. Each enabled
//! mini-procedure pays the setup overhead `Δt`.
//!
//! * [`cost`] — the `f_m` timeline evaluator (Eq. 8) with the
//!   non-overlapping-compute / overlap / non-overlapping-comm breakdown
//!   used by Figs. 5–8.
//! * [`ibatch`] — the greedy competitor (Algorithms 1 and 2).
//! * [`dynacomm`] — the paper's DP algorithms (Algorithms 3 and 4,
//!   Eqs. 13/14), O(L^3) time / O(L^2) space.
//! * [`bruteforce`] — exact `O(L·2^L)` enumeration, used as the optimality
//!   oracle in tests and benches.
//!
//! Every strategy is exposed behind the [`Scheduler`] trait and created
//! through [`registry`]; `docs/SCHEDULER.md` documents the API and how to
//! add a strategy.

pub mod bruteforce;
pub mod cost;
pub mod dynacomm;
pub mod ibatch;
pub mod registry;
pub mod slicing;

pub use cost::{
    backward_lower_bound, eval_backward, eval_forward, eval_iteration,
    forward_lower_bound, IterationBreakdown, PassBreakdown,
};

/// Per-layer cost vectors for one iteration (Section III-B), in ms.
///
/// `delta_t` is the per-mini-procedure setup overhead Δt (assumed constant;
/// Section IV-A derives it by profiling + averaging).
#[derive(Debug, Clone, PartialEq)]
pub struct CostVectors {
    /// Parameter-transmission cost of layer `l` (index `l-1`).
    pub pt: Vec<f64>,
    /// Forward-computation cost of layer `l`.
    pub fc: Vec<f64>,
    /// Backward-computation cost of layer `l`.
    pub bc: Vec<f64>,
    /// Gradient-transmission cost of layer `l`.
    pub gt: Vec<f64>,
    /// Δt: per-transmission setup/coordination overhead.
    pub delta_t: f64,
}

impl CostVectors {
    pub fn depth(&self) -> usize {
        debug_assert_eq!(self.pt.len(), self.fc.len());
        debug_assert_eq!(self.pt.len(), self.bc.len());
        debug_assert_eq!(self.pt.len(), self.gt.len());
        self.pt.len()
    }

    /// Sanity: all finite, non-negative, consistent lengths.
    pub fn validate(&self) -> anyhow::Result<()> {
        let l = self.pt.len();
        anyhow::ensure!(l > 0, "empty cost vectors");
        anyhow::ensure!(
            self.fc.len() == l && self.bc.len() == l && self.gt.len() == l,
            "inconsistent cost vector lengths"
        );
        let ok = |v: &[f64]| v.iter().all(|x| x.is_finite() && *x >= 0.0);
        anyhow::ensure!(
            ok(&self.pt) && ok(&self.fc) && ok(&self.bc) && ok(&self.gt),
            "negative or non-finite cost"
        );
        anyhow::ensure!(
            self.delta_t.is_finite() && self.delta_t >= 0.0,
            "bad delta_t"
        );
        Ok(())
    }
}

/// A decomposition decision: which of the `L-1` positions between adjacent
/// layers are enabled. `cuts[i]` is the position between layer `i+1` and
/// layer `i+2` (1-based layers). The same physical cuts describe a forward
/// plan (segments ascending from layer 1) or a backward plan (segments
/// descending from layer L); the paper's `p`/`g` vectors are the forward
/// and reversed encodings of this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decomposition {
    pub cuts: Vec<bool>,
}

impl Decomposition {
    /// No cuts: one transmission for the whole procedure (Sequential).
    pub fn sequential(depth: usize) -> Decomposition {
        assert!(depth > 0);
        Decomposition { cuts: vec![false; depth - 1] }
    }

    /// Every cut enabled: one transmission per layer (LBL / Poseidon).
    pub fn layer_by_layer(depth: usize) -> Decomposition {
        assert!(depth > 0);
        Decomposition { cuts: vec![true; depth - 1] }
    }

    /// Build from the paper's forward notation: a position list
    /// `[0, b1, b2, ..., L]` of enabled decomposition positions.
    pub fn from_positions(depth: usize, positions: &[usize]) -> Decomposition {
        let mut d = Decomposition::sequential(depth);
        for &p in positions {
            if p >= 1 && p <= depth - 1 {
                d.cuts[p - 1] = true;
            }
        }
        d
    }

    pub fn depth(&self) -> usize {
        self.cuts.len() + 1
    }

    /// Number of transmission mini-procedures this decomposition induces.
    pub fn num_transmissions(&self) -> usize {
        1 + self.cuts.iter().filter(|&&c| c).count()
    }

    /// Forward segments, ascending: 1-based inclusive `(first, last)` layer
    /// ranges, each one transmission mini-procedure.
    pub fn fwd_segments(&self) -> Vec<(usize, usize)> {
        let depth = self.depth();
        let mut segs = Vec::with_capacity(self.num_transmissions());
        let mut start = 1;
        for l in 1..depth {
            if self.cuts[l - 1] {
                segs.push((start, l));
                start = l + 1;
            }
        }
        segs.push((start, depth));
        segs
    }

    /// Backward segments, descending: 1-based inclusive `(hi, lo)` layer
    /// ranges in transmission order (deepest layers flush first).
    pub fn bwd_segments(&self) -> Vec<(usize, usize)> {
        let depth = self.depth();
        let mut segs = Vec::with_capacity(self.num_transmissions());
        let mut hi = depth;
        for l in (1..depth).rev() {
            // cut between layer l and l+1
            if self.cuts[l - 1] {
                segs.push((hi, l + 1));
                hi = l;
            }
        }
        segs.push((hi, 1));
        segs
    }
}

/// Forward + backward decomposition decisions for one iteration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchedulePlan {
    pub fwd: Decomposition,
    pub bwd: Decomposition,
}

impl SchedulePlan {
    /// One transmission per procedure for both passes.
    pub fn sequential(depth: usize) -> SchedulePlan {
        let d = Decomposition::sequential(depth);
        SchedulePlan { fwd: d.clone(), bwd: d }
    }

    /// One transmission per layer for both passes.
    pub fn layer_by_layer(depth: usize) -> SchedulePlan {
        let d = Decomposition::layer_by_layer(depth);
        SchedulePlan { fwd: d.clone(), bwd: d }
    }
}

/// What a [`Scheduler::plan`] call returns: the decomposition decisions
/// plus the strategy's own predicted pass finish times (ms) under the
/// cost vectors it was handed. For DynaComm the predictions are the DP
/// table optima (`min_n F[L][n]` / `min_n B[L][n]`); for every other
/// strategy they come from the O(L) timeline evaluator, so in all cases
/// `predicted_fwd_ms == eval_forward(cv, &plan.fwd).total` (and likewise
/// backward) — an invariant the registry conformance tests pin down.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledPlan {
    pub plan: SchedulePlan,
    /// Predicted forward-pass finish time, ms.
    pub predicted_fwd_ms: f64,
    /// Predicted backward-pass finish time, ms.
    pub predicted_bwd_ms: f64,
    /// True when a stateful scheduler answered from its cache instead of
    /// re-running its decision procedure (gain-thresholded re-planning).
    pub reused: bool,
}

impl ScheduledPlan {
    /// Predicted whole-iteration finish time, ms.
    pub fn predicted_ms(&self) -> f64 {
        self.predicted_fwd_ms + self.predicted_bwd_ms
    }
}

struct SchedCounters {
    replans: crate::obs::Counter,
    reuses: crate::obs::Counter,
}

fn sched_counters() -> &'static SchedCounters {
    static CELL: std::sync::OnceLock<SchedCounters> = std::sync::OnceLock::new();
    CELL.get_or_init(|| {
        let inst = crate::obs::next_inst();
        SchedCounters {
            replans: crate::obs_counter!("dynacomm_sched_replans_total", "", inst),
            reuses: crate::obs_counter!("dynacomm_sched_plan_reuses_total", "", inst),
        }
    })
}

/// Record one scheduler decision in the unified obs registry: a fresh
/// re-plan or a gain-thresholded cache reuse ([`ScheduledPlan::reused`]).
/// Called by plan consumers (the edge worker's reschedule path) so every
/// strategy is counted without each one carrying instrumentation.
pub fn note_replan(reused: bool) {
    let c = sched_counters();
    if reused {
        c.reuses.inc();
    } else {
        c.replans.inc();
    }
}

/// A layer-wise communication scheduling strategy.
///
/// Schedulers are stateful (`&mut self`): a strategy may cache its last
/// plan and answer [`ScheduledPlan::reused`] when re-planning cannot pay
/// for itself — the DynaComm scheduler skips its O(L^3) DP this way.
/// Stateless strategies simply recompute every call. Instances come from
/// [`registry::create`] (by name) or [`registry::create_for`] (from the
/// [`crate::config::Strategy`] config shim).
pub trait Scheduler {
    /// Registry name of this scheduler (`registry::NAMES` entry).
    fn name(&self) -> &'static str;

    /// Produce (or reuse) the decomposition decisions for one iteration
    /// under the given per-layer costs.
    fn plan(&mut self, cv: &CostVectors) -> ScheduledPlan;
}

/// Inclusive prefix sums with a leading 0: `out[m] = Σ_{l=1..m} v[l]`.
pub fn prefix(v: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(v.len() + 1);
    out.push(0.0);
    let mut acc = 0.0;
    for x in v {
        acc += x;
        out.push(acc);
    }
    out
}

/// Suffix sums: `out[m] = Σ over the last m layers = Σ_{l=L-m+1..L} v[l]`.
pub fn suffix(v: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(v.len() + 1);
    out.push(0.0);
    let mut acc = 0.0;
    for x in v.iter().rev() {
        acc += x;
        out.push(acc);
    }
    out
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::CostVectors;
    use crate::util::rng::Rng;

    /// Random cost vectors with heavy-tailed layer costs — the regime the
    /// paper describes (conv layers: big compute / small tensors; fc
    /// layers: the reverse).
    pub fn random_cv(rng: &mut Rng, depth: usize) -> CostVectors {
        let mut pt = Vec::with_capacity(depth);
        let mut fc = Vec::with_capacity(depth);
        let mut bc = Vec::with_capacity(depth);
        let mut gt = Vec::with_capacity(depth);
        for _ in 0..depth {
            pt.push(rng.lognormal(0.0, 1.2));
            fc.push(rng.lognormal(0.0, 1.2));
            bc.push(rng.lognormal(0.5, 1.2));
            gt.push(rng.lognormal(0.0, 1.2));
        }
        CostVectors { pt, fc, bc, gt, delta_t: rng.range_f64(0.1, 3.0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_is_one_segment() {
        let d = Decomposition::sequential(5);
        assert_eq!(d.num_transmissions(), 1);
        assert_eq!(d.fwd_segments(), vec![(1, 5)]);
        assert_eq!(d.bwd_segments(), vec![(5, 1)]);
    }

    #[test]
    fn lbl_is_one_segment_per_layer() {
        let d = Decomposition::layer_by_layer(4);
        assert_eq!(d.num_transmissions(), 4);
        assert_eq!(d.fwd_segments(), vec![(1, 1), (2, 2), (3, 3), (4, 4)]);
        assert_eq!(d.bwd_segments(), vec![(4, 4), (3, 3), (2, 2), (1, 1)]);
    }

    #[test]
    fn from_positions_matches_paper_notation() {
        // [0, 2, 5] over L=5: segments [1..2], [3..5].
        let d = Decomposition::from_positions(5, &[0, 2, 5]);
        assert_eq!(d.fwd_segments(), vec![(1, 2), (3, 5)]);
        assert_eq!(d.bwd_segments(), vec![(5, 3), (2, 1)]);
    }

    #[test]
    fn segments_partition_layers() {
        let d = Decomposition::from_positions(7, &[1, 4, 6]);
        let fwd = d.fwd_segments();
        let mut covered = Vec::new();
        for (a, b) in &fwd {
            assert!(a <= b);
            covered.extend(*a..=*b);
        }
        assert_eq!(covered, (1..=7).collect::<Vec<_>>());
        // backward covers the same layers in reverse order.
        let bwd = d.bwd_segments();
        let mut covered_b = Vec::new();
        for (hi, lo) in &bwd {
            assert!(hi >= lo);
            let mut seg: Vec<usize> = (*lo..=*hi).collect();
            seg.reverse();
            covered_b.extend(seg);
        }
        assert_eq!(covered_b, (1..=7).rev().collect::<Vec<_>>());
    }

    #[test]
    fn prefix_suffix_sums() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(prefix(&v), vec![0.0, 1.0, 3.0, 6.0]);
        assert_eq!(suffix(&v), vec![0.0, 3.0, 5.0, 6.0]);
    }

    #[test]
    fn depth_one_has_no_cuts() {
        let d = Decomposition::sequential(1);
        assert_eq!(d.num_transmissions(), 1);
        assert_eq!(d.fwd_segments(), vec![(1, 1)]);
    }
}
