//! Scheduler registry: boxed [`Scheduler`] strategies by name.
//!
//! The registry is the single place strategies are instantiated — the
//! worker, trainer, simulator, sweeps, and figure drivers all route through
//! it, so adding a strategy (e.g. ACE-Sync-style adaptive synchronization
//! or AccEPT-style compressed slabs, PAPERS.md) means implementing
//! [`Scheduler`] and registering one more arm here; no call site changes.
//!
//! [`crate::config::Strategy`] remains the config/CLI shim for the four
//! paper strategies; the registry accepts every `Strategy::parse` spelling
//! plus entries the enum never had (`slicing`, `bruteforce`).

use anyhow::Result;

use super::cost::{eval_backward, eval_forward};
use super::{CostVectors, SchedulePlan, ScheduledPlan, Scheduler};
use crate::config::Strategy;

/// Tuning knobs threaded into stateful schedulers at creation time.
/// The default (`gain_threshold_ms: 0.0`) re-plans on every call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedulerParams {
    /// DynaComm: skip the O(L^3) DP when re-planning cannot gain more than
    /// this many ms over the cached plan. `0.0` re-plans on every call
    /// (the stateless behavior); **negative selects AUTO**
    /// ([`crate::sched::dynacomm::GAIN_THRESHOLD_AUTO`]), deriving the
    /// threshold from the measured DP wall-clock vs the comm idle window;
    /// see [`crate::sched::dynacomm::DynaCommScheduler`].
    pub gain_threshold_ms: f64,
    /// Iterations a plan serves between re-plan opportunities (the
    /// worker's `reschedule_every`); amortizes the DP cost in AUTO mode.
    pub replan_horizon_iters: usize,
}

impl Default for SchedulerParams {
    fn default() -> Self {
        SchedulerParams { gain_threshold_ms: 0.0, replan_horizon_iters: 1 }
    }
}

/// Canonical names of every registry entry, in creation-tested order.
pub const NAMES: [&str; 6] =
    ["sequential", "lbl", "ibatch", "dynacomm", "slicing", "bruteforce"];

/// Create a scheduler by name with default [`SchedulerParams`].
pub fn create(name: &str) -> Result<Box<dyn Scheduler>> {
    create_with(name, SchedulerParams::default())
}

/// Create a scheduler by name. Accepts every [`Strategy::parse`] spelling
/// plus the registry-only entries; unknown names list what is available.
pub fn create_with(name: &str, params: SchedulerParams) -> Result<Box<dyn Scheduler>> {
    if let Some(strategy) = Strategy::parse(name) {
        return Ok(create_for_with(strategy, params));
    }
    match name.to_ascii_lowercase().as_str() {
        "slicing" | "p3" | "bytescheduler" => {
            Ok(Box::new(super::slicing::SlicingScheduler::new()))
        }
        "bruteforce" | "oracle" => {
            Ok(Box::new(super::bruteforce::BruteForceScheduler::new()))
        }
        _ => anyhow::bail!(
            "unknown scheduler '{name}' (known: {})",
            NAMES.join(", ")
        ),
    }
}

/// Create the scheduler behind a config [`Strategy`] with default params.
pub fn create_for(strategy: Strategy) -> Box<dyn Scheduler> {
    create_for_with(strategy, SchedulerParams::default())
}

/// Create the scheduler behind a config [`Strategy`].
pub fn create_for_with(strategy: Strategy, params: SchedulerParams) -> Box<dyn Scheduler> {
    match strategy {
        Strategy::Sequential => Box::new(FixedScheduler::sequential()),
        Strategy::LayerByLayer => Box::new(FixedScheduler::layer_by_layer()),
        Strategy::IBatch => Box::new(super::ibatch::IBatchScheduler::new()),
        Strategy::DynaComm => Box::new(super::dynacomm::DynaCommScheduler::with_horizon(
            params.gain_threshold_ms,
            params.replan_horizon_iters,
        )),
    }
}

/// Sequential / layer-by-layer: fixed decompositions whose predicted
/// finish times come from the O(L) timeline evaluator.
pub struct FixedScheduler {
    name: &'static str,
    build: fn(usize) -> SchedulePlan,
}

impl FixedScheduler {
    pub fn sequential() -> FixedScheduler {
        FixedScheduler { name: "sequential", build: SchedulePlan::sequential }
    }

    pub fn layer_by_layer() -> FixedScheduler {
        FixedScheduler { name: "lbl", build: SchedulePlan::layer_by_layer }
    }
}

impl Scheduler for FixedScheduler {
    fn name(&self) -> &'static str {
        self.name
    }

    fn plan(&mut self, cv: &CostVectors) -> ScheduledPlan {
        let plan = (self.build)(cv.depth());
        ScheduledPlan {
            predicted_fwd_ms: eval_forward(cv, &plan.fwd).total,
            predicted_bwd_ms: eval_backward(cv, &plan.bwd).total,
            plan,
            reused: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::random_cv;
    use crate::util::rng::Rng;

    #[test]
    fn every_name_creates_and_reports_itself() {
        for name in NAMES {
            let s = create(name).unwrap();
            assert_eq!(s.name(), name, "canonical name round-trip");
        }
        // Alias spellings resolve too.
        for alias in ["seq", "layer-by-layer", "ipart", "dp", "p3", "oracle"] {
            assert!(create(alias).is_ok(), "{alias}");
        }
        assert!(create("nope").is_err());
        let err = format!("{:#}", create("nope").unwrap_err());
        assert!(err.contains("dynacomm"), "error lists known names: {err}");
    }

    #[test]
    fn strategy_shim_maps_onto_registry_names() {
        for s in Strategy::ALL {
            assert_eq!(create_for(s).name(), s.name());
        }
    }

    #[test]
    fn fixed_schedulers_predict_their_eval_totals() {
        let mut rng = Rng::new(81);
        for _ in 0..50 {
            let depth = rng.range(1, 20);
            let cv = random_cv(&mut rng, depth);
            for (mut s, segs) in [
                (FixedScheduler::sequential(), 1),
                (FixedScheduler::layer_by_layer(), depth),
            ] {
                let sp = s.plan(&cv);
                assert_eq!(sp.plan.fwd.num_transmissions(), segs);
                assert!(!sp.reused);
                let f = eval_forward(&cv, &sp.plan.fwd).total;
                let b = eval_backward(&cv, &sp.plan.bwd).total;
                assert!((sp.predicted_fwd_ms - f).abs() < 1e-9);
                assert!((sp.predicted_bwd_ms - b).abs() < 1e-9);
                assert!((sp.predicted_ms() - (f + b)).abs() < 1e-9);
            }
        }
    }
}
