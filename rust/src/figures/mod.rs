//! Experiment drivers that regenerate every table and figure of the
//! paper's evaluation (Section V). Shared between `cargo bench` harnesses
//! and the examples; each driver returns printable rows and a JSON record
//! that benches write under `results/`.

use std::time::Instant;

use crate::config::{Strategy, SystemConfig};
use crate::models;
use crate::sched::{self, CostVectors, Scheduler};
use crate::sim::{self, sweep, workload};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;

/// Pass selector for Figs. 5–8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Forward,
    Backward,
}

/// One cell of Figs. 5–8: normalized execution-time split for a
/// (model, strategy) pair.
#[derive(Debug, Clone)]
pub struct NormalizedCell {
    pub model: String,
    pub strategy: Strategy,
    pub comp_only: f64,
    pub overlap: f64,
    pub comm_only: f64,
}

impl NormalizedCell {
    pub fn total(&self) -> f64 {
        self.comp_only + self.overlap + self.comm_only
    }

    /// "running time reduced by" vs Sequential = 1 - total.
    pub fn reduction(&self) -> f64 {
        1.0 - self.total()
    }
}

/// Figs. 5–8: normalized execution time of one pass for all four models and
/// all four strategies at the given batch size.
pub fn normalized_pass_times(batch: usize, pass: Pass) -> Vec<NormalizedCell> {
    let mut cfg = SystemConfig::default();
    cfg.batch = batch;
    let mut cells = Vec::new();
    for model in models::paper_models() {
        let cv = model.cost_vectors(&cfg);
        // Sequential's own predicted pass time is the normalization
        // baseline (its prediction equals the timeline evaluation — the
        // ScheduledPlan contract).
        let seq = sched::registry::create_for(Strategy::Sequential).plan(&cv);
        let baseline = match pass {
            Pass::Forward => seq.predicted_fwd_ms,
            Pass::Backward => seq.predicted_bwd_ms,
        };
        for s in Strategy::ALL {
            let sp = sched::registry::create_for(s).plan(&cv);
            let b = match pass {
                Pass::Forward => sched::eval_forward(&cv, &sp.plan.fwd),
                Pass::Backward => sched::eval_backward(&cv, &sp.plan.bwd),
            };
            let n = sim::normalize(&b, baseline);
            cells.push(NormalizedCell {
                model: model.name.clone(),
                strategy: s,
                comp_only: n.comp_only,
                overlap: n.overlap,
                comm_only: n.comm_only,
            });
        }
    }
    cells
}

/// Render Figs. 5–8 cells as an aligned text table.
pub fn render_normalized(cells: &[NormalizedCell], title: &str) -> String {
    let mut out = format!("{title}\n");
    out.push_str(&format!(
        "{:<14} {:<11} {:>9} {:>9} {:>9} {:>9} {:>10}\n",
        "model", "strategy", "comp", "overlap", "comm", "total", "reduced"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<14} {:<11} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.2}%\n",
            c.model,
            c.strategy.name(),
            c.comp_only,
            c.overlap,
            c.comm_only,
            c.total(),
            100.0 * c.reduction()
        ));
    }
    out
}

pub fn normalized_to_json(cells: &[NormalizedCell]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("model", Json::Str(c.model.clone())),
                    ("strategy", Json::Str(c.strategy.name().into())),
                    ("comp_only", Json::Num(c.comp_only)),
                    ("overlap", Json::Num(c.overlap)),
                    ("comm_only", Json::Num(c.comm_only)),
                    ("reduced", Json::Num(c.reduction())),
                ])
            })
            .collect(),
    )
}

/// Fig. 9: sensitivity sweeps on ResNet-152.
pub fn fig9_batch_sweep() -> Vec<sweep::SweepRow> {
    let m = models::by_name("resnet152").unwrap();
    let cfg = SystemConfig::default();
    sweep::sweep_batch(&m, &cfg, &[8, 16, 24, 32, 48, 64])
}

pub fn fig9_bandwidth_sweep() -> Vec<sweep::SweepRow> {
    let m = models::by_name("resnet152").unwrap();
    let cfg = SystemConfig::default();
    sweep::sweep_bandwidth(&m, &cfg, &[1.0, 5.0, 10.0])
}

/// Fig. 11: speedup vs workers on ResNet-152.
pub fn fig11_worker_sweep() -> Vec<sweep::SweepRow> {
    let m = models::by_name("resnet152").unwrap();
    let cfg = SystemConfig::default();
    sweep::sweep_workers(&m, &cfg, &[1, 2, 4, 8])
}

pub fn render_sweep(rows: &[sweep::SweepRow], xlabel: &str, title: &str) -> String {
    let mut out = format!("{title}\n{:<10}", xlabel);
    for s in Strategy::ALL {
        out.push_str(&format!(" {:>11}", s.name()));
    }
    out.push('\n');
    for r in rows {
        out.push_str(&format!("{:<10}", r.x));
        for s in Strategy::ALL {
            out.push_str(&format!(" {:>11.4}", r.get(s)));
        }
        out.push('\n');
    }
    out
}

pub fn sweep_to_json(rows: &[sweep::SweepRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut pairs = vec![("x", Json::Num(r.x))];
                for (s, v) in &r.values {
                    pairs.push((s.name(), Json::Num(*v)));
                }
                Json::obj(pairs)
            })
            .collect(),
    )
}

/// One Fig. 12 / Table I measurement: scheduling wall-clock in ms.
#[derive(Debug, Clone)]
pub struct SchedTiming {
    pub depth: usize,
    pub dynacomm_fwd_ms: stats::Summary,
    pub dynacomm_bwd_ms: stats::Summary,
    pub ibatch_fwd_ms: stats::Summary,
    pub ibatch_bwd_ms: stats::Summary,
}

/// Measure scheduler wall-clock on random profiles of a given depth
/// (Fig. 12) — `reps` timed runs each.
pub fn time_schedulers(depth: usize, reps: usize, seed: u64) -> SchedTiming {
    let mut rng = Rng::new(seed);
    let cvs: Vec<CostVectors> = (0..reps)
        .map(|_| workload::generate(&mut rng, depth, workload::WorkloadParams::default()))
        .collect();
    let time_it = |f: &dyn Fn(&CostVectors) -> sched::Decomposition| -> stats::Summary {
        let samples: Vec<f64> = cvs
            .iter()
            .map(|cv| {
                let t0 = Instant::now();
                let d = f(cv);
                let el = t0.elapsed().as_secs_f64() * 1e3;
                std::hint::black_box(&d);
                el
            })
            .collect();
        stats::summarize(&samples)
    };
    SchedTiming {
        depth,
        dynacomm_fwd_ms: time_it(&sched::dynacomm::forward),
        dynacomm_bwd_ms: time_it(&sched::dynacomm::backward),
        ibatch_fwd_ms: time_it(&sched::ibatch::forward),
        ibatch_bwd_ms: time_it(&sched::ibatch::backward),
    }
}

/// Table I: scheduler cost vs the idle window (`Δt + gt¹` / `Δt + pt¹`) for
/// each paper model under the default testbed.
pub struct Table1Row {
    pub model: String,
    pub dynacomm_fwd_ms: stats::Summary,
    pub ibatch_fwd_ms: stats::Summary,
    pub idle_fwd_ms: f64, // Δt + gt¹
    pub dynacomm_bwd_ms: stats::Summary,
    pub ibatch_bwd_ms: stats::Summary,
    pub idle_bwd_ms: f64, // Δt + pt¹ of the next iteration
}

pub fn table1(reps: usize) -> Vec<Table1Row> {
    let cfg = SystemConfig::default();
    models::paper_models()
        .into_iter()
        .map(|m| {
            let cv = m.cost_vectors(&cfg);
            let time_many = |f: &dyn Fn(&CostVectors) -> sched::Decomposition| {
                let samples: Vec<f64> = (0..reps)
                    .map(|_| {
                        let t0 = Instant::now();
                        std::hint::black_box(f(&cv));
                        t0.elapsed().as_secs_f64() * 1e3
                    })
                    .collect();
                stats::summarize(&samples)
            };
            Table1Row {
                model: m.name.clone(),
                dynacomm_fwd_ms: time_many(&sched::dynacomm::forward),
                ibatch_fwd_ms: time_many(&sched::ibatch::forward),
                idle_fwd_ms: cv.delta_t + cv.gt[0],
                dynacomm_bwd_ms: time_many(&sched::dynacomm::backward),
                ibatch_bwd_ms: time_many(&sched::ibatch::backward),
                idle_bwd_ms: cv.delta_t + cv.pt[0],
            }
        })
        .collect()
}

/// One row of the Table-I companion: full `Scheduler::plan` wall-clock at
/// a given DynaComm gain threshold over a drifting profile sequence.
#[derive(Debug, Clone)]
pub struct GainThresholdRow {
    pub threshold_ms: f64,
    /// Wall-clock of the `plan` call itself (reused calls included — that
    /// is where the savings appear).
    pub plan_ms: stats::Summary,
    /// Calls answered from the cache.
    pub reused: usize,
    pub calls: usize,
}

/// Measure the scheduling-cost savings of gain-thresholded re-planning:
/// one stateful DynaComm scheduler per threshold, fed `calls` noisy
/// re-profilings of the same comm-dominated workload (the regime where the
/// cached plan stays provably near-optimal, so reuse can trigger).
pub fn gain_threshold_savings(
    depth: usize,
    calls: usize,
    seed: u64,
    thresholds: &[f64],
) -> Vec<GainThresholdRow> {
    let mut rng = Rng::new(seed);
    let params = workload::WorkloadParams {
        comm_mu: 2.0,
        comp_mu: -1.0,
        sigma: 0.8,
        delta_t: 5.0,
    };
    let base = workload::generate(&mut rng, depth, params);
    // Pre-generate the drifting sequence so every threshold sees the exact
    // same profiles (±5% multiplicative jitter, like epoch-to-epoch noise).
    let profiles: Vec<CostVectors> = (0..calls)
        .map(|_| {
            let mut cv = base.clone();
            for v in cv
                .pt
                .iter_mut()
                .chain(cv.fc.iter_mut())
                .chain(cv.bc.iter_mut())
                .chain(cv.gt.iter_mut())
            {
                *v *= 1.0 + 0.05 * rng.normal();
                *v = v.max(0.0);
            }
            cv
        })
        .collect();
    thresholds
        .iter()
        .map(|&threshold_ms| {
            let mut s = sched::dynacomm::DynaCommScheduler::new(threshold_ms);
            let mut samples = Vec::with_capacity(calls);
            let mut reused = 0;
            for cv in &profiles {
                let t0 = Instant::now();
                let sp = s.plan(cv);
                samples.push(t0.elapsed().as_secs_f64() * 1e3);
                if sp.reused {
                    reused += 1;
                }
            }
            GainThresholdRow {
                threshold_ms,
                plan_ms: stats::summarize(&samples),
                reused,
                calls,
            }
        })
        .collect()
}

/// Write a JSON result file under `results/`.
pub fn write_result(name: &str, value: Json) -> std::io::Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{name}.json"), value.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_cells_cover_grid() {
        let cells = normalized_pass_times(32, Pass::Forward);
        assert_eq!(cells.len(), 16); // 4 models x 4 strategies
        // Sequential rows normalize to exactly 1.0.
        for c in cells.iter().filter(|c| c.strategy == Strategy::Sequential) {
            assert!((c.total() - 1.0).abs() < 1e-9, "{c:?}");
        }
        // DynaComm minimal per model.
        for model in ["vgg19", "googlenet", "inceptionv4", "resnet152"] {
            let of = |s: Strategy| {
                cells
                    .iter()
                    .find(|c| c.model == model && c.strategy == s)
                    .unwrap()
                    .total()
            };
            let d = of(Strategy::DynaComm);
            assert!(d <= of(Strategy::Sequential) + 1e-9, "{model}");
            assert!(d <= of(Strategy::LayerByLayer) + 1e-9, "{model}");
            assert!(d <= of(Strategy::IBatch) + 1e-9, "{model}");
        }
    }

    #[test]
    fn render_produces_rows() {
        let cells = normalized_pass_times(16, Pass::Backward);
        let text = render_normalized(&cells, "fig8");
        assert!(text.lines().count() >= 18);
        assert!(text.contains("dynacomm"));
    }

    #[test]
    fn sched_timing_scales_superlinearly() {
        // O(L^3) vs O(L): 4x depth should cost much more than 4x time.
        let a = time_schedulers(20, 5, 1);
        let b = time_schedulers(80, 5, 1);
        assert!(
            b.dynacomm_fwd_ms.mean > 4.0 * a.dynacomm_fwd_ms.mean,
            "20→{:.4} 80→{:.4}",
            a.dynacomm_fwd_ms.mean,
            b.dynacomm_fwd_ms.mean
        );
    }

    #[test]
    fn gain_threshold_savings_reuse_counts() {
        let rows = gain_threshold_savings(24, 10, 7, &[0.0, f64::INFINITY]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].reused, 0, "threshold 0 must always re-plan");
        assert_eq!(
            rows[1].reused, 9,
            "infinite threshold reuses every call after the first"
        );
        assert_eq!(rows[1].calls, 10);
    }

    #[test]
    fn table1_has_all_models() {
        let rows = table1(3);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.idle_fwd_ms > 0.0);
            assert!(r.dynacomm_fwd_ms.mean >= 0.0);
        }
    }
}
