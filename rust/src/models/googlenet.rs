//! GoogLeNet / Inception-v1 (Szegedy et al., CVPR 2015) at 224x224.
//!
//! Per the DynaComm depth-merge rule, each Inception module collapses to
//! two layers: depth 1 holds every 1x1 at the module input (the #1x1
//! branch, the 3x3/5x5 reduces, and the pool projection), depth 2 holds the
//! 3x3 and 5x5 convolutions. With the three stem convs and the classifier
//! this yields the network's canonical "22 layers deep":
//! 3 + 9·2 + 1 = 22. Auxiliary classifiers are train-time extras the MXNet
//! example omits; they are omitted here too.

use super::{conv_layer, fc_layer, merge, ModelSpec};

/// Inception module channel spec from Table 1 of the GoogLeNet paper.
struct Module {
    name: &'static str,
    cin: usize,
    n1x1: usize,
    red3: usize,
    n3x3: usize,
    red5: usize,
    n5x5: usize,
    pool_proj: usize,
    hw: usize,
}

pub fn googlenet() -> ModelSpec {
    let mut layers = Vec::with_capacity(22);
    layers.push(conv_layer("conv1", 7, 3, 64, 112, 112));
    layers.push(conv_layer("conv2_reduce", 1, 64, 64, 56, 56));
    layers.push(conv_layer("conv2", 3, 64, 192, 56, 56));

    let modules = [
        Module { name: "3a", cin: 192, n1x1: 64, red3: 96, n3x3: 128, red5: 16, n5x5: 32, pool_proj: 32, hw: 28 },
        Module { name: "3b", cin: 256, n1x1: 128, red3: 128, n3x3: 192, red5: 32, n5x5: 96, pool_proj: 64, hw: 28 },
        Module { name: "4a", cin: 480, n1x1: 192, red3: 96, n3x3: 208, red5: 16, n5x5: 48, pool_proj: 64, hw: 14 },
        Module { name: "4b", cin: 512, n1x1: 160, red3: 112, n3x3: 224, red5: 24, n5x5: 64, pool_proj: 64, hw: 14 },
        Module { name: "4c", cin: 512, n1x1: 128, red3: 128, n3x3: 256, red5: 24, n5x5: 64, pool_proj: 64, hw: 14 },
        Module { name: "4d", cin: 512, n1x1: 112, red3: 144, n3x3: 288, red5: 32, n5x5: 64, pool_proj: 64, hw: 14 },
        Module { name: "4e", cin: 528, n1x1: 256, red3: 160, n3x3: 320, red5: 32, n5x5: 128, pool_proj: 128, hw: 14 },
        Module { name: "5a", cin: 832, n1x1: 256, red3: 160, n3x3: 320, red5: 32, n5x5: 128, pool_proj: 128, hw: 7 },
        Module { name: "5b", cin: 832, n1x1: 384, red3: 192, n3x3: 384, red5: 48, n5x5: 128, pool_proj: 128, hw: 7 },
    ];
    for m in modules {
        // Depth 1: all 1x1 projections at the module input.
        layers.push(merge(
            format!("inc{}_proj", m.name),
            &[
                conv_layer("b1", 1, m.cin, m.n1x1, m.hw, m.hw),
                conv_layer("b2r", 1, m.cin, m.red3, m.hw, m.hw),
                conv_layer("b3r", 1, m.cin, m.red5, m.hw, m.hw),
                conv_layer("b4p", 1, m.cin, m.pool_proj, m.hw, m.hw),
            ],
        ));
        // Depth 2: the spatial convolutions.
        layers.push(merge(
            format!("inc{}_spatial", m.name),
            &[
                conv_layer("b2", 3, m.red3, m.n3x3, m.hw, m.hw),
                conv_layer("b3", 5, m.red5, m.n5x5, m.hw, m.hw),
            ],
        ));
    }
    layers.push(fc_layer("fc", 1024, 1000));
    ModelSpec { name: "googlenet".to_string(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_is_22() {
        assert_eq!(googlenet().depth(), 22);
    }

    #[test]
    fn total_params_matches_published() {
        // Published (no aux classifiers): ~7.0M parameters.
        let p = googlenet().total_params() as f64 / 1e6;
        assert!((p - 7.0).abs() < 0.7, "params = {p}M");
    }

    #[test]
    fn total_fwd_flops_matches_published() {
        // Published: ~3.0 GFLOP per 224x224 sample (2 ops/MAC).
        let g = googlenet().total_fwd_flops() / 1e9;
        assert!((1.8..4.0).contains(&g), "fwd = {g} GFLOP");
    }

    #[test]
    fn compute_heavy_relative_to_comm() {
        // "GoogLeNet is more computationally expensive while VGG-19's
        // communication overhead dominates": bytes-per-FLOP must be much
        // smaller than VGG-19's.
        let g = googlenet();
        let v = super::super::vgg::vgg19();
        let ratio_g = 4.0 * g.total_params() as f64 / g.total_fwd_flops();
        let ratio_v = 4.0 * v.total_params() as f64 / v.total_fwd_flops();
        assert!(ratio_g < ratio_v, "g={ratio_g} v={ratio_v}");
    }
}
