//! ResNet-152 (He et al., CVPR 2016) at 224x224.
//!
//! Bottleneck stages [3, 8, 36, 3]; each bottleneck contributes three
//! parameterized layers (1x1 reduce, 3x3, 1x1 expand), giving
//! 1 + 3·(3+8+36+3) + 1 = 152 layers. Projection shortcuts sit at the same
//! depth as the first conv of their block and are merged into it
//! (Section III-A branch rule); batch-norm scale/shift parameters ride along
//! with their conv.

use super::{conv_flops, conv_params, fc_layer, LayerSpec, ModelSpec};

struct Stage {
    blocks: usize,
    width: usize, // bottleneck width w; output is 4w
    hw: usize,    // spatial resolution inside the stage
}

pub fn resnet152() -> ModelSpec {
    let mut layers: Vec<LayerSpec> = Vec::with_capacity(152);
    // conv1: 7x7/2, 64 channels, output 112x112.
    layers.push(bn_conv("conv1", 7, 3, 64, 112, 112));

    let stages = [
        Stage { blocks: 3, width: 64, hw: 56 },
        Stage { blocks: 8, width: 128, hw: 28 },
        Stage { blocks: 36, width: 256, hw: 14 },
        Stage { blocks: 3, width: 512, hw: 7 },
    ];
    let mut cin = 64; // channels entering the first stage (after maxpool)
    for (si, st) in stages.iter().enumerate() {
        for b in 0..st.blocks {
            let cout = st.width * 4;
            // 1x1 reduce — merged with the projection shortcut (cin -> 4w,
            // 1x1) in the first block of each stage.
            let mut reduce = bn_conv(
                format!("res{}_{b}a", si + 2),
                1,
                cin,
                st.width,
                st.hw,
                st.hw,
            );
            if b == 0 {
                let proj = bn_conv("proj", 1, cin, cout, st.hw, st.hw);
                reduce.params += proj.params;
                reduce.fwd_flops += proj.fwd_flops;
                reduce.bwd_flops += proj.bwd_flops;
            }
            layers.push(reduce);
            layers.push(bn_conv(
                format!("res{}_{b}b", si + 2),
                3,
                st.width,
                st.width,
                st.hw,
                st.hw,
            ));
            layers.push(bn_conv(
                format!("res{}_{b}c", si + 2),
                1,
                st.width,
                cout,
                st.hw,
                st.hw,
            ));
            cin = cout;
        }
    }
    layers.push(fc_layer("fc", 2048, 1000));
    ModelSpec { name: "resnet152".to_string(), layers }
}

/// Conv + batch-norm: BN adds 2·cout parameters and ~4 FLOPs/output element.
fn bn_conv(
    name: impl Into<String>,
    k: usize,
    cin: usize,
    cout: usize,
    h: usize,
    w: usize,
) -> LayerSpec {
    let f = conv_flops(k, cin, cout, h, w) + 4.0 * (cout * h * w) as f64;
    LayerSpec {
        name: name.into(),
        params: conv_params(k, cin, cout) + cout,
        fwd_flops: f,
        bwd_flops: 2.0 * f,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_is_152() {
        assert_eq!(resnet152().depth(), 152);
    }

    #[test]
    fn total_params_matches_published() {
        // Published ResNet-152: ~60.2M parameters.
        let p = resnet152().total_params() as f64 / 1e6;
        assert!((p - 60.2).abs() < 1.5, "params = {p}M");
    }

    #[test]
    fn total_fwd_flops_matches_published() {
        // Published: ~11.3 GMACs per 224x224 sample; 2 ops/MAC → ~22.6 GFLOP.
        let g = resnet152().total_fwd_flops() / 1e9;
        assert!((g - 22.6).abs() < 2.0, "fwd = {g} GFLOP");
    }

    #[test]
    fn final_fc_is_a_large_transmission() {
        // The paper highlights LBL mishandling the FC tail of ResNet-152:
        // the last layer holds a disproportionate share of parameter bytes.
        let m = resnet152();
        let fc = m.layers.last().unwrap();
        assert!(fc.params > 2_000_000);
        let conv_median = {
            let mut p: Vec<usize> = m.layers[..151].iter().map(|l| l.params).collect();
            p.sort_unstable();
            p[p.len() / 2]
        };
        assert!(fc.params > 5 * conv_median);
    }
}
