//! Layer-wise cost-model zoo for the paper's four evaluation networks plus
//! the real EdgeCNN workload.
//!
//! The scheduling problem only consumes per-layer cost vectors
//! `(p̄t, f̄c, b̄c, ḡt)` and `Δt` (Section III-B); this module derives them
//! from published architecture math — per-layer parameter bytes and
//! forward/backward FLOPs — combined with a [`SystemConfig`] (device
//! GFLOP/s, link bandwidth, RTT, Δt).
//!
//! Following Section III-A: branch layers at the same depth are merged into
//! one layer (GoogLeNet / Inception-v4 modules), and parameter-free
//! transformation layers (pooling, flatten, concat) are folded into their
//! preceding parameterized layer.

pub mod edgecnn;
pub mod googlenet;
pub mod inception;
pub mod resnet;
pub mod vgg;

use crate::config::SystemConfig;
use crate::sched::CostVectors;

/// One (depth-merged) parameterized layer of a CNN.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    /// Trainable parameter count (weights + biases, all merged branches).
    pub params: usize,
    /// Forward FLOPs for one sample.
    pub fwd_flops: f64,
    /// Backward FLOPs for one sample (input + weight gradients; ~2x fwd).
    pub bwd_flops: f64,
}

impl LayerSpec {
    pub fn param_bytes(&self) -> f64 {
        self.params as f64 * 4.0 // f32
    }
}

/// A full model: ordered layers, shallowest first.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn total_fwd_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.fwd_flops).sum()
    }

    /// Derive the paper's cost vectors for one iteration at `cfg.batch`.
    ///
    /// * `pt[l]` / `gt[l]`: serialization time of layer *l*'s tensor at the
    ///   effective link rate (latency and setup live in `Δt`, which is paid
    ///   once per mini-procedure, not per layer), after `cfg.codec`'s wire
    ///   compression (`sched::cost::transmission_ms`) — so the scheduler's
    ///   inputs shrink with the codec and the DP re-segments accordingly.
    /// * `fc[l]` / `bc[l]`: compute time at the device's sustained rate.
    /// * `delta_t`: `Δt` = setup/coordination + one-way latency, matching
    ///   Table I's `Δt + pt¹/gt¹ ≈ 14 ms` at 10 ms RTT.
    pub fn cost_vectors(&self, cfg: &SystemConfig) -> CostVectors {
        let bw_bytes_per_ms = effective_bandwidth_bytes_per_ms(cfg);
        let batch = cfg.batch as f64;
        let mut pt = Vec::with_capacity(self.depth());
        let mut fc = Vec::with_capacity(self.depth());
        let mut bc = Vec::with_capacity(self.depth());
        let mut gt = Vec::with_capacity(self.depth());
        for layer in &self.layers {
            let bytes = layer.param_bytes();
            let ms = crate::sched::cost::transmission_ms(cfg.codec, bytes, bw_bytes_per_ms);
            pt.push(ms);
            gt.push(ms);
            fc.push(cfg.device.compute_ms(layer.fwd_flops * batch));
            bc.push(cfg.device.compute_ms(layer.bwd_flops * batch));
        }
        CostVectors {
            pt,
            fc,
            bc,
            gt,
            delta_t: cfg.net.delta_t_ms + cfg.net.rtt_ms / 2.0,
        }
    }
}

/// Effective per-worker goodput in bytes/ms.
///
/// The paper's nominal "10 Gbps" NICs do not deliver 10 Gbps of parameter
/// goodput to each worker: 8 workers share 4 server NICs, and the
/// framework's serialization/coordination path costs more. The paper's own
/// reported numbers (42.86% forward reduction on VGG-19 at bs=32 implies
/// `pt ≈ fc` in the forward phase) pin the achieved bytes-per-FLOP ratio;
/// `GOODPUT_EFFICIENCY` is calibrated so ResNet-152 at bs=32 balances
/// around 3–5 Gbps nominal — which reproduces the paper's Fig. 9b shape
/// (comm-bound at 1 Gbps, peak gains near 5 Gbps, compute-bound at
/// 10 Gbps); see DESIGN.md §3 and EXPERIMENTS.md. Sweeping nominal
/// bandwidth scales this linearly, preserving the crossover shape.
pub const GOODPUT_EFFICIENCY: f64 = 0.112;

pub fn effective_bandwidth_bytes_per_ms(cfg: &SystemConfig) -> f64 {
    cfg.net.bandwidth_gbps * GOODPUT_EFFICIENCY * 1e9 / 8.0 / 1e3
}

/// Look a model up by name.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name.to_ascii_lowercase().as_str() {
        "vgg19" | "vgg-19" => Some(vgg::vgg19()),
        "googlenet" => Some(googlenet::googlenet()),
        "inceptionv4" | "inception-v4" => Some(inception::inception_v4()),
        "resnet152" | "resnet-152" => Some(resnet::resnet152()),
        "edgecnn" => Some(edgecnn::edgecnn()),
        _ => None,
    }
}

/// The four evaluation networks of Section V, in the paper's order.
pub fn paper_models() -> Vec<ModelSpec> {
    vec![
        vgg::vgg19(),
        googlenet::googlenet(),
        inception::inception_v4(),
        resnet::resnet152(),
    ]
}

/// FLOPs of a `k x k` convolution producing `h x w x cout` from `cin`
/// channels (2 ops per MAC).
pub(crate) fn conv_flops(k: usize, cin: usize, cout: usize, h: usize, w: usize) -> f64 {
    2.0 * (k * k * cin * cout * h * w) as f64
}

pub(crate) fn conv_params(k: usize, cin: usize, cout: usize) -> usize {
    k * k * cin * cout + cout
}

pub(crate) fn fc_flops(fin: usize, fout: usize) -> f64 {
    2.0 * (fin * fout) as f64
}

pub(crate) fn fc_params(fin: usize, fout: usize) -> usize {
    fin * fout + fout
}

/// Build a conv LayerSpec; backward ≈ 2x forward (input grad + weight grad).
pub(crate) fn conv_layer(
    name: impl Into<String>,
    k: usize,
    cin: usize,
    cout: usize,
    h: usize,
    w: usize,
) -> LayerSpec {
    let f = conv_flops(k, cin, cout, h, w);
    LayerSpec {
        name: name.into(),
        params: conv_params(k, cin, cout),
        fwd_flops: f,
        bwd_flops: 2.0 * f,
    }
}

pub(crate) fn fc_layer(name: impl Into<String>, fin: usize, fout: usize) -> LayerSpec {
    let f = fc_flops(fin, fout);
    LayerSpec {
        name: name.into(),
        params: fc_params(fin, fout),
        fwd_flops: f,
        bwd_flops: 2.0 * f,
    }
}

/// Merge same-depth branch layers into one LayerSpec (Section III-A).
pub(crate) fn merge(name: impl Into<String>, parts: &[LayerSpec]) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        params: parts.iter().map(|p| p.params).sum(),
        fwd_flops: parts.iter().map(|p| p.fwd_flops).sum(),
        bwd_flops: parts.iter().map(|p| p.bwd_flops).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn zoo_lookup() {
        for name in ["vgg19", "googlenet", "inceptionv4", "resnet152", "edgecnn"] {
            let m = by_name(name).unwrap();
            assert!(!m.layers.is_empty(), "{name}");
            assert!(m.layers.iter().all(|l| l.params > 0), "{name}");
            assert!(m.layers.iter().all(|l| l.fwd_flops > 0.0), "{name}");
        }
        assert!(by_name("alexnet").is_none());
    }

    #[test]
    fn cost_vectors_shape_and_positivity() {
        let cfg = SystemConfig::default();
        for m in paper_models() {
            let cv = m.cost_vectors(&cfg);
            assert_eq!(cv.pt.len(), m.depth());
            assert_eq!(cv.fc.len(), m.depth());
            assert_eq!(cv.bc.len(), m.depth());
            assert_eq!(cv.gt.len(), m.depth());
            assert!(cv.delta_t > 0.0);
            assert!(cv.pt.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn delta_t_matches_table1_regime() {
        // Table I reports Δt + first-layer transmission ≈ 14 ms at 10 ms RTT.
        let cfg = SystemConfig::default();
        let m = by_name("resnet152").unwrap();
        let cv = m.cost_vectors(&cfg);
        let dt_plus_pt1 = cv.delta_t + cv.pt[0];
        assert!(
            (10.0..20.0).contains(&dt_plus_pt1),
            "Δt + pt¹ = {dt_plus_pt1} ms, expected ≈14 ms"
        );
    }

    #[test]
    fn batch_scales_compute_not_comm() {
        let m = by_name("vgg19").unwrap();
        let mut cfg = SystemConfig::default();
        cfg.batch = 16;
        let cv16 = m.cost_vectors(&cfg);
        cfg.batch = 32;
        let cv32 = m.cost_vectors(&cfg);
        assert!((cv32.fc[0] / cv16.fc[0] - 2.0).abs() < 1e-9);
        assert_eq!(cv32.pt, cv16.pt);
    }

    #[test]
    fn bwd_is_heavier_than_fwd() {
        for m in paper_models() {
            for l in &m.layers {
                assert!(l.bwd_flops >= l.fwd_flops, "{}:{}", m.name, l.name);
            }
        }
    }
}
