//! VGG-19 (Simonyan & Zisserman, ICLR 2015), configuration E at 224x224.
//!
//! 16 convolutional layers + 3 fully connected = 19 parameterized layers.
//! Max-pool layers carry no parameters and are folded into the preceding
//! conv (Section III-A of the DynaComm paper).

use super::{conv_layer, fc_layer, LayerSpec, ModelSpec};

pub fn vgg19() -> ModelSpec {
    let mut layers: Vec<LayerSpec> = Vec::with_capacity(19);
    // (blocks of (cout, repeats) at spatial resolution hw)
    let blocks: [(usize, usize, usize); 5] = [
        (64, 2, 224),
        (128, 2, 112),
        (256, 4, 56),
        (512, 4, 28),
        (512, 4, 14),
    ];
    let mut cin = 3;
    for (bi, (cout, reps, hw)) in blocks.iter().enumerate() {
        for r in 0..*reps {
            layers.push(conv_layer(
                format!("conv{}_{}", bi + 1, r + 1),
                3,
                cin,
                *cout,
                *hw,
                *hw,
            ));
            cin = *cout;
        }
    }
    // 512 x 7 x 7 = 25088 after the last pool.
    layers.push(fc_layer("fc6", 25088, 4096));
    layers.push(fc_layer("fc7", 4096, 4096));
    layers.push(fc_layer("fc8", 4096, 1000));
    ModelSpec { name: "vgg19".to_string(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_is_19() {
        assert_eq!(vgg19().depth(), 19);
    }

    #[test]
    fn total_params_matches_published() {
        // Published VGG-19: ~143.67M parameters.
        let p = vgg19().total_params() as f64 / 1e6;
        assert!((p - 143.67).abs() < 0.5, "params = {p}M");
    }

    #[test]
    fn total_fwd_flops_matches_published() {
        // Published: ~19.6 GMACs for one 224x224 sample; we count
        // 2 ops/MAC, so ~39.3 GFLOP.
        let g = vgg19().total_fwd_flops() / 1e9;
        assert!((g - 39.3).abs() < 2.0, "fwd = {g} GFLOP");
    }

    #[test]
    fn fc_layers_dominate_params_conv_dominate_flops() {
        let m = vgg19();
        let fc_params: usize = m.layers[16..].iter().map(|l| l.params).sum();
        assert!(fc_params as f64 / m.total_params() as f64 > 0.8);
        let conv_flops: f64 = m.layers[..16].iter().map(|l| l.fwd_flops).sum();
        assert!(conv_flops / m.total_fwd_flops() > 0.9);
    }
}
