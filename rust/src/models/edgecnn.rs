//! EdgeCNN — the real training workload exported by `python/compile/`.
//!
//! The cost spec here mirrors `python/compile/model.py::edgecnn_layers()`
//! (and the FLOP accounting in `aot.py`) so the simulator and the real
//! runtime agree on the model's shape. A unit test cross-checks the Rust
//! numbers against the manifest whenever artifacts are present.

use super::{conv_layer, fc_layer, ModelSpec};

pub fn edgecnn() -> ModelSpec {
    ModelSpec {
        name: "edgecnn".to_string(),
        layers: vec![
            conv_layer("conv1", 3, 3, 16, 32, 32),
            conv_layer("conv2", 3, 16, 16, 32, 32),
            conv_layer("conv3", 3, 16, 32, 16, 16),
            conv_layer("conv4", 3, 32, 32, 16, 16),
            fc_layer("fc1", 2048, 128),
            fc_layer("fc2", 128, 10),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_and_params() {
        let m = edgecnn();
        assert_eq!(m.depth(), 6);
        // conv params: 448 + 2320 + 4640 + 9248; fc: 262272 + 1290.
        assert_eq!(m.total_params(), 448 + 2320 + 4640 + 9248 + 262_272 + 1290);
    }

    #[test]
    fn layer_params_match_python_export() {
        let m = edgecnn();
        let expect = [448, 2320, 4640, 9248, 262_272, 1290];
        for (l, e) in m.layers.iter().zip(expect) {
            assert_eq!(l.params, e, "{}", l.name);
        }
    }

    #[test]
    fn flops_match_aot_accounting() {
        // aot.py: conv fwd = 2*9*cin*cout*h*w per sample; fc = 2*fin*fout.
        let m = edgecnn();
        assert_eq!(m.layers[0].fwd_flops, 2.0 * 9.0 * 3.0 * 16.0 * 32.0 * 32.0);
        assert_eq!(m.layers[4].fwd_flops, 2.0 * 2048.0 * 128.0);
        for l in &m.layers {
            assert_eq!(l.bwd_flops, 2.0 * l.fwd_flops, "{}", l.name);
        }
    }
}
