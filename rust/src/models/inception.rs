//! Inception-v4 (Szegedy et al., AAAI 2017) at 299x299.
//!
//! Modules are collapsed depth-wise per the DynaComm branch-merge rule:
//! every branch layer at the same distance from the module input lands in
//! one merged layer. Branch lengths differ, so an Inception-B module
//! contributes 5 depths, Inception-A 3, Inception-C 4, the stem 9, the
//! reductions 3 and 4 — 76 parameterized depths in total, placing the
//! network between GoogLeNet (22) and ResNet-152 (152), exactly the
//! "deeper network" regime where the paper shows greedy iBatch falling
//! behind.

use super::{fc_layer, merge, LayerSpec, ModelSpec};

/// Rectangular (possibly asymmetric) convolution, 2 ops/MAC.
fn rect(
    name: impl Into<String>,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    h: usize,
    w: usize,
) -> LayerSpec {
    let f = 2.0 * (kh * kw * cin * cout * h * w) as f64;
    LayerSpec {
        name: name.into(),
        params: kh * kw * cin * cout + cout,
        fwd_flops: f,
        bwd_flops: 2.0 * f,
    }
}

pub fn inception_v4() -> ModelSpec {
    let mut l: Vec<LayerSpec> = Vec::with_capacity(76);

    // ---- Stem (299x299x3 -> 35x35x384), 9 depths ----
    l.push(rect("stem_conv1", 3, 3, 3, 32, 149, 149));
    l.push(rect("stem_conv2", 3, 3, 32, 32, 147, 147));
    l.push(rect("stem_conv3", 3, 3, 32, 64, 147, 147));
    // parallel {maxpool | conv 3x3/2 96} -> 73x73x160
    l.push(rect("stem_mixed1", 3, 3, 64, 96, 73, 73));
    // two parallel towers, aligned depth-wise:
    l.push(merge(
        "stem_mixed2_proj",
        &[
            rect("a1", 1, 1, 160, 64, 73, 73),
            rect("b1", 1, 1, 160, 64, 73, 73),
        ],
    ));
    l.push(merge(
        "stem_mixed2_mid",
        &[
            rect("a2", 3, 3, 64, 96, 71, 71),
            rect("b2", 7, 1, 64, 64, 73, 73),
        ],
    ));
    l.push(rect("stem_mixed2_b3", 1, 7, 64, 64, 73, 73));
    l.push(rect("stem_mixed2_b4", 3, 3, 64, 96, 71, 71));
    // parallel {conv 3x3/2 192 | maxpool} -> 35x35x384
    l.push(rect("stem_mixed3", 3, 3, 192, 192, 35, 35));

    // ---- 4x Inception-A @35x35, cin 384, 3 depths each ----
    for i in 0..4 {
        let cin = 384;
        let hw = 35;
        l.push(merge(
            format!("incA{i}_proj"),
            &[
                rect("b1", 1, 1, cin, 96, hw, hw),
                rect("b2r", 1, 1, cin, 64, hw, hw),
                rect("b3r", 1, 1, cin, 64, hw, hw),
                rect("b4p", 1, 1, cin, 96, hw, hw),
            ],
        ));
        l.push(merge(
            format!("incA{i}_mid"),
            &[
                rect("b2", 3, 3, 64, 96, hw, hw),
                rect("b3a", 3, 3, 64, 96, hw, hw),
            ],
        ));
        l.push(rect(format!("incA{i}_tail"), 3, 3, 96, 96, hw, hw));
    }

    // ---- Reduction-A (35 -> 17), 3 depths ----
    l.push(merge(
        "redA_head",
        &[
            rect("b1", 3, 3, 384, 384, 17, 17),
            rect("b2r", 1, 1, 384, 192, 35, 35),
        ],
    ));
    l.push(rect("redA_mid", 3, 3, 192, 224, 35, 35));
    l.push(rect("redA_tail", 3, 3, 224, 256, 17, 17));

    // ---- 7x Inception-B @17x17, cin 1024, 5 depths each ----
    for i in 0..7 {
        let cin = 1024;
        let hw = 17;
        l.push(merge(
            format!("incB{i}_proj"),
            &[
                rect("b1", 1, 1, cin, 384, hw, hw),
                rect("b2r", 1, 1, cin, 192, hw, hw),
                rect("b3r", 1, 1, cin, 192, hw, hw),
                rect("b4p", 1, 1, cin, 128, hw, hw),
            ],
        ));
        l.push(merge(
            format!("incB{i}_d2"),
            &[
                rect("b2a", 1, 7, 192, 224, hw, hw),
                rect("b3a", 7, 1, 192, 192, hw, hw),
            ],
        ));
        l.push(merge(
            format!("incB{i}_d3"),
            &[
                rect("b2b", 7, 1, 224, 256, hw, hw),
                rect("b3b", 1, 7, 192, 224, hw, hw),
            ],
        ));
        l.push(rect(format!("incB{i}_d4"), 7, 1, 224, 224, hw, hw));
        l.push(rect(format!("incB{i}_d5"), 1, 7, 224, 256, hw, hw));
    }

    // ---- Reduction-B (17 -> 8), 4 depths ----
    l.push(merge(
        "redB_proj",
        &[
            rect("b1r", 1, 1, 1024, 192, 17, 17),
            rect("b2r", 1, 1, 1024, 256, 17, 17),
        ],
    ));
    l.push(merge(
        "redB_d2",
        &[
            rect("b1", 3, 3, 192, 192, 8, 8),
            rect("b2a", 1, 7, 256, 256, 17, 17),
        ],
    ));
    l.push(rect("redB_d3", 7, 1, 256, 320, 17, 17));
    l.push(rect("redB_d4", 3, 3, 320, 320, 8, 8));

    // ---- 3x Inception-C @8x8, cin 1536, 4 depths each ----
    for i in 0..3 {
        let cin = 1536;
        let hw = 8;
        l.push(merge(
            format!("incC{i}_proj"),
            &[
                rect("b1", 1, 1, cin, 256, hw, hw),
                rect("b2r", 1, 1, cin, 384, hw, hw),
                rect("b3r", 1, 1, cin, 384, hw, hw),
                rect("b4p", 1, 1, cin, 256, hw, hw),
            ],
        ));
        l.push(merge(
            format!("incC{i}_d2"),
            &[
                rect("b2s1", 1, 3, 384, 256, hw, hw),
                rect("b2s2", 3, 1, 384, 256, hw, hw),
                rect("b3a", 1, 3, 384, 448, hw, hw),
            ],
        ));
        l.push(rect(format!("incC{i}_d3"), 3, 1, 448, 512, hw, hw));
        l.push(merge(
            format!("incC{i}_d4"),
            &[
                rect("b3s1", 3, 1, 512, 256, hw, hw),
                rect("b3s2", 1, 3, 512, 256, hw, hw),
            ],
        ));
    }

    l.push(fc_layer("fc", 1536, 1000));
    ModelSpec { name: "inceptionv4".to_string(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_is_76() {
        assert_eq!(inception_v4().depth(), 76);
    }

    #[test]
    fn depth_sits_between_googlenet_and_resnet() {
        let d = inception_v4().depth();
        assert!(d > super::super::googlenet::googlenet().depth());
        assert!(d < super::super::resnet::resnet152().depth());
    }

    #[test]
    fn total_params_near_published() {
        // Published Inception-v4: ~42.7M parameters. The depth-merge
        // abstraction keeps every parameterized conv, so totals match to
        // within the BN/aux bookkeeping differences.
        let p = inception_v4().total_params() as f64 / 1e6;
        assert!((30.0..52.0).contains(&p), "params = {p}M");
    }

    #[test]
    fn total_fwd_flops_near_published() {
        // Published: ~24.6 GFLOP per 299x299 sample (2 ops/MAC).
        let g = inception_v4().total_fwd_flops() / 1e9;
        assert!((15.0..32.0).contains(&g), "fwd = {g} GFLOP");
    }
}
