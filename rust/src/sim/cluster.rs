//! Multi-worker BSP cluster model — Fig. 11 scalability.
//!
//! Workers run homogeneous iterations and synchronize at a BSP barrier (the
//! default PS mode the paper evaluates). Growing the cluster keeps each
//! worker's compute constant but stretches its transmissions: all workers'
//! pulls/pushes contend for the aggregate server-side bandwidth, and
//! coordination cost (Δt) grows with fan-in. DynaComm re-runs its DP on the
//! *contended* cost vectors each time the cluster is resized, which is
//! exactly why its scalability curve stays above the fixed strategies'.

use crate::config::{Strategy, SystemConfig};
use crate::models::{effective_bandwidth_bytes_per_ms, ModelSpec};
use crate::sched::CostVectors;
use crate::sim::simulate_cv;

/// Per-worker cost vectors once `workers` devices share the servers.
///
/// * Bandwidth: each worker gets
///   `min(own link, server aggregate / workers)`.
/// * Δt: server-side coordination grows mildly with fan-in
///   (`·(1 + 0.05·(workers-1))`), modeling request queueing at the shards.
pub fn contended_cost_vectors(
    model: &ModelSpec,
    cfg: &SystemConfig,
    workers: usize,
) -> CostVectors {
    assert!(workers >= 1);
    let mut cv = model.cost_vectors(cfg);
    let link = effective_bandwidth_bytes_per_ms(cfg);
    let server_total = link * (cfg.server_bandwidth_gbps / cfg.net.bandwidth_gbps);
    let share = server_total / workers as f64;
    let eff = link.min(share);
    let stretch = link / eff;
    for t in cv.pt.iter_mut().chain(cv.gt.iter_mut()) {
        *t *= stretch;
    }
    cv.delta_t *= 1.0 + 0.05 * (workers as f64 - 1.0);
    cv
}

/// One point of Fig. 11: throughput-based speedup of an `n`-worker cluster
/// over "single worker training speed" (paper metric) — a lone device
/// training locally with no parameter-server traffic, i.e. pure compute.
///
/// Speedup(n) = n · T_local / T(n): n workers each process a batch per
/// iteration, but the iteration stretches under contention. Using the
/// common compute-only baseline (rather than each strategy's own T(1))
/// keeps the curves comparable, exactly as the figure plots them.
pub fn speedup(model: &ModelSpec, cfg: &SystemConfig, strategy: Strategy, workers: usize) -> f64 {
    let cv = model.cost_vectors(cfg);
    let t_local: f64 = cv.fc.iter().sum::<f64>() + cv.bc.iter().sum::<f64>();
    let tn = iteration_ms(model, cfg, strategy, workers);
    workers as f64 * t_local / tn
}

/// BSP iteration time of an `n`-worker cluster (slowest worker bounds the
/// barrier; workers are homogeneous here, so it is the common time).
pub fn iteration_ms(
    model: &ModelSpec,
    cfg: &SystemConfig,
    strategy: Strategy,
    workers: usize,
) -> f64 {
    let cv = contended_cost_vectors(model, cfg, workers);
    simulate_cv(&cv, strategy).total_ms()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn setup() -> (ModelSpec, SystemConfig) {
        (models::by_name("resnet152").unwrap(), SystemConfig::default())
    }

    #[test]
    fn single_worker_speedup_below_one_and_ranked() {
        // With PS traffic, a single worker cannot beat local training;
        // scheduled strategies sit closer to 1.0 than Sequential.
        let (m, cfg) = setup();
        for s in Strategy::ALL {
            let sp = speedup(&m, &cfg, s, 1);
            assert!(sp <= 1.0 + 1e-9, "{}: {sp}", s.name());
        }
        assert!(
            speedup(&m, &cfg, Strategy::DynaComm, 1)
                > speedup(&m, &cfg, Strategy::Sequential, 1)
        );
    }

    #[test]
    fn speedup_is_sublinear_under_contention() {
        let (m, cfg) = setup();
        for s in Strategy::ALL {
            let s8 = speedup(&m, &cfg, s, 8);
            assert!(s8 < 8.0 + 1e-9, "{}: {s8}", s.name());
            assert!(s8 > 1.0, "{}: {s8}", s.name());
        }
    }

    #[test]
    fn dynacomm_scales_best_at_8_workers() {
        // Fig. 11: DynaComm 7.2x > iBatch 6.2x > LBL 5.4x > Sequential.
        let (m, cfg) = setup();
        let dyna = speedup(&m, &cfg, Strategy::DynaComm, 8);
        let ibatch = speedup(&m, &cfg, Strategy::IBatch, 8);
        let lbl = speedup(&m, &cfg, Strategy::LayerByLayer, 8);
        assert!(
            dyna >= ibatch - 1e-9 && dyna >= lbl - 1e-9,
            "dyna={dyna:.2} ibatch={ibatch:.2} lbl={lbl:.2}"
        );
    }

    #[test]
    fn contention_stretches_comm_not_comp() {
        let (m, cfg) = setup();
        let cv1 = contended_cost_vectors(&m, &cfg, 1);
        let cv8 = contended_cost_vectors(&m, &cfg, 8);
        assert_eq!(cv1.fc, cv8.fc);
        assert!(cv8.pt[0] >= cv1.pt[0]);
        assert!(cv8.delta_t > cv1.delta_t);
    }
}
