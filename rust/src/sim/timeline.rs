//! Explicit mini-procedure timelines.
//!
//! `sched::cost` computes pass totals in O(L) without materializing events;
//! this module builds the full event list — every transmission and
//! computation mini-procedure with its `[start, end)` interval — so that
//! (a) the partial-order constraints (1)–(7) can be checked mechanically,
//! (b) examples can print Gantt charts, and (c) the O(L) evaluator is
//! cross-validated against an independent reconstruction.

use crate::sched::{prefix, CostVectors, Decomposition, PassBreakdown};

/// What a timeline event is doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Parameter transmission of layers `(a..=b)`.
    ParamTx,
    /// Forward computation of layers `(a..=b)`.
    FwdComp,
    /// Backward computation of layers `(a..=b)` (descending).
    BwdComp,
    /// Gradient transmission of layers `(a..=b)` (descending).
    GradTx,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Inclusive 1-based layer range; `lo <= hi` always.
    pub lo: usize,
    pub hi: usize,
    pub start: f64,
    pub end: f64,
}

impl Event {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// The forward-pass timeline under decomposition `d`.
pub fn forward_timeline(cv: &CostVectors, d: &Decomposition) -> Vec<Event> {
    let ppt = prefix(&cv.pt);
    let pfc = prefix(&cv.fc);
    let segs = d.fwd_segments();
    let mut events = Vec::with_capacity(2 * segs.len());
    let mut tx_end = 0.0_f64;
    let mut comp_end = 0.0_f64;
    for (a, b) in segs {
        // Transmission: link busy back-to-back, Δt then payload.
        let tx_start = tx_end;
        tx_end = tx_start + cv.delta_t + (ppt[b] - ppt[a - 1]);
        events.push(Event { kind: EventKind::ParamTx, lo: a, hi: b, start: tx_start, end: tx_end });
        // Computation: after previous segment compute and own arrival.
        let start = comp_end.max(tx_end);
        comp_end = start + (pfc[b] - pfc[a - 1]);
        events.push(Event { kind: EventKind::FwdComp, lo: a, hi: b, start, end: comp_end });
    }
    events
}

/// The backward-pass timeline under decomposition `d`, shifted to t=0.
pub fn backward_timeline(cv: &CostVectors, d: &Decomposition) -> Vec<Event> {
    let depth = cv.depth();
    let mut events = Vec::new();
    // Backward compute: layer L down to 1, no stalls.
    let mut t = 0.0_f64;
    let mut done_at = vec![0.0_f64; depth + 1];
    for l in (1..=depth).rev() {
        let start = t;
        t += cv.bc[l - 1];
        events.push(Event { kind: EventKind::BwdComp, lo: l, hi: l, start, end: t });
        done_at[l] = t;
    }
    let pgt = prefix(&cv.gt);
    let mut tx_end = 0.0_f64;
    for (hi, lo) in d.bwd_segments() {
        let ready = done_at[lo];
        let start = tx_end.max(ready);
        tx_end = start + cv.delta_t + (pgt[hi] - pgt[lo - 1]);
        events.push(Event { kind: EventKind::GradTx, lo, hi, start, end: tx_end });
    }
    events
}

/// Recompute a [`PassBreakdown`] from an event list by sweeping interval
/// boundaries — independent of the O(L) evaluator's arithmetic.
pub fn breakdown_from_events(events: &[Event], comm: &[EventKind]) -> PassBreakdown {
    let is_comm = |k: EventKind| comm.contains(&k);
    let mut points: Vec<f64> = Vec::with_capacity(events.len() * 2);
    for e in events {
        points.push(e.start);
        points.push(e.end);
    }
    points.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points.dedup();
    let mut comp_only = 0.0;
    let mut overlap = 0.0;
    let mut comm_only = 0.0;
    for w in points.windows(2) {
        let (a, b) = (w[0], w[1]);
        let mid = (a + b) / 2.0;
        let comm_busy = events
            .iter()
            .any(|e| is_comm(e.kind) && e.start <= mid && mid < e.end);
        let comp_busy = events
            .iter()
            .any(|e| !is_comm(e.kind) && e.start <= mid && mid < e.end);
        match (comm_busy, comp_busy) {
            (true, true) => overlap += b - a,
            (true, false) => comm_only += b - a,
            (false, true) => comp_only += b - a,
            (false, false) => {}
        }
    }
    let total = points.last().copied().unwrap_or(0.0) - points.first().copied().unwrap_or(0.0);
    PassBreakdown { total, comp_only, overlap, comm_only }
}

/// Mechanically verify the paper's partial-order constraints (1)–(7) on a
/// forward timeline.
pub fn check_forward_constraints(events: &[Event], depth: usize) -> Result<(), String> {
    let tx: Vec<&Event> = events.iter().filter(|e| e.kind == EventKind::ParamTx).collect();
    let fc: Vec<&Event> = events.iter().filter(|e| e.kind == EventKind::FwdComp).collect();
    // (4) transmissions ordered by layer.
    for w in tx.windows(2) {
        if w[0].end > w[1].start + 1e-9 {
            return Err(format!("constraint (4) violated: {:?} {:?}", w[0], w[1]));
        }
    }
    // (5) computations ordered by layer.
    for w in fc.windows(2) {
        if w[0].end > w[1].start + 1e-9 {
            return Err(format!("constraint (5) violated: {:?} {:?}", w[0], w[1]));
        }
    }
    // (1) every layer's pt ends before its fc starts.
    for l in 1..=depth {
        let t = tx.iter().find(|e| e.lo <= l && l <= e.hi).ok_or("missing pt")?;
        let c = fc.iter().find(|e| e.lo <= l && l <= e.hi).ok_or("missing fc")?;
        if t.end > c.start + 1e-9 {
            return Err(format!("constraint (1) violated at layer {l}"));
        }
    }
    Ok(())
}

/// Mechanically verify constraints (2), (6), (7) on a backward timeline.
pub fn check_backward_constraints(events: &[Event], depth: usize) -> Result<(), String> {
    let bc: Vec<&Event> = events.iter().filter(|e| e.kind == EventKind::BwdComp).collect();
    let gt: Vec<&Event> = events.iter().filter(|e| e.kind == EventKind::GradTx).collect();
    // (6) backward compute descends layer by layer.
    for w in bc.windows(2) {
        if w[0].lo != w[1].lo + 1 || w[0].end > w[1].start + 1e-9 {
            return Err(format!("constraint (6) violated: {:?} {:?}", w[0], w[1]));
        }
    }
    // (7) gradient transmissions descend.
    for w in gt.windows(2) {
        if w[0].lo <= w[1].hi || w[0].end > w[1].start + 1e-9 {
            return Err(format!("constraint (7) violated: {:?} {:?}", w[0], w[1]));
        }
    }
    // (2) every layer's bc ends before its gt starts.
    for l in 1..=depth {
        let c = bc.iter().find(|e| e.lo == l).ok_or("missing bc")?;
        let t = gt.iter().find(|e| e.lo <= l && l <= e.hi).ok_or("missing gt")?;
        if c.end > t.start + 1e-9 {
            return Err(format!("constraint (2) violated at layer {l}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::random_cv;
    use crate::sched::{bruteforce, eval_backward, eval_forward, registry, Scheduler};
    use crate::util::rng::Rng;

    fn random_decomposition(rng: &mut Rng, depth: usize) -> Decomposition {
        let mut d = Decomposition::sequential(depth);
        for c in d.cuts.iter_mut() {
            *c = rng.bool();
        }
        d
    }

    /// Every registry scheduler's plan, plus random decompositions, per
    /// pass. The exhaustive oracle only runs where it is tractable.
    fn candidate_plans(
        rng: &mut Rng,
        cv: &CostVectors,
    ) -> Vec<(Decomposition, Decomposition)> {
        let depth = cv.depth();
        let mut out = Vec::new();
        for name in registry::NAMES {
            if name == "bruteforce" && bruteforce::intractable_in_tests(depth) {
                continue;
            }
            let sp = registry::create(name).unwrap().plan(cv);
            out.push((sp.plan.fwd, sp.plan.bwd));
        }
        let r = random_decomposition(rng, depth);
        out.push((r.clone(), r));
        out
    }

    #[test]
    fn forward_constraints_hold_for_all_schedulers() {
        let mut rng = Rng::new(51);
        for _ in 0..100 {
            let depth = rng.range(1, 20);
            let cv = random_cv(&mut rng, depth);
            for (fwd, _) in candidate_plans(&mut rng, &cv) {
                let ev = forward_timeline(&cv, &fwd);
                check_forward_constraints(&ev, depth).unwrap();
            }
        }
    }

    #[test]
    fn backward_constraints_hold_for_all_schedulers() {
        let mut rng = Rng::new(52);
        for _ in 0..100 {
            let depth = rng.range(1, 20);
            let cv = random_cv(&mut rng, depth);
            for (_, bwd) in candidate_plans(&mut rng, &cv) {
                let ev = backward_timeline(&cv, &bwd);
                check_backward_constraints(&ev, depth).unwrap();
            }
        }
    }

    #[test]
    fn event_breakdown_matches_o_l_evaluator_forward() {
        // The independent interval sweep must agree with sched::cost.
        let mut rng = Rng::new(53);
        for _ in 0..200 {
            let depth = rng.range(1, 16);
            let cv = random_cv(&mut rng, depth);
            let d = random_decomposition(&mut rng, depth);
            let fast = eval_forward(&cv, &d);
            let ev = forward_timeline(&cv, &d);
            let slow = breakdown_from_events(&ev, &[EventKind::ParamTx]);
            assert!((fast.total - slow.total).abs() < 1e-6, "{fast:?} {slow:?}");
            assert!((fast.overlap - slow.overlap).abs() < 1e-6, "{fast:?} {slow:?}");
            assert!((fast.comp_only - slow.comp_only).abs() < 1e-6);
            assert!((fast.comm_only - slow.comm_only).abs() < 1e-6);
        }
    }

    #[test]
    fn event_breakdown_matches_o_l_evaluator_backward() {
        let mut rng = Rng::new(54);
        for _ in 0..200 {
            let depth = rng.range(1, 16);
            let cv = random_cv(&mut rng, depth);
            let d = random_decomposition(&mut rng, depth);
            let fast = eval_backward(&cv, &d);
            let ev = backward_timeline(&cv, &d);
            let slow = breakdown_from_events(&ev, &[EventKind::GradTx]);
            assert!((fast.total - slow.total).abs() < 1e-6, "{fast:?} {slow:?}");
            assert!((fast.overlap - slow.overlap).abs() < 1e-6, "{fast:?} {slow:?}");
            assert!((fast.comp_only - slow.comp_only).abs() < 1e-6);
            assert!((fast.comm_only - slow.comm_only).abs() < 1e-6);
        }
    }
}
