//! Parameter sweeps — the sensitivity (Fig. 9) and scalability (Fig. 11)
//! experiment drivers, shared between benches and examples. Each sweep
//! point routes through the `sched::Scheduler` registry (via
//! [`reduced_ratio`] / [`cluster::speedup`]), so registry-only strategies
//! are a one-line addition to these figures.

use crate::config::{Strategy, SystemConfig};
use crate::models::ModelSpec;
use crate::sim::{cluster, reduced_ratio};

/// One sweep row: the x-value plus the reduced ratio (or speedup) per
/// strategy, in `Strategy::ALL` order.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub x: f64,
    pub values: Vec<(Strategy, f64)>,
}

/// Fig. 9 (a): iteration-time-reduced ratio versus batch size.
pub fn sweep_batch(model: &ModelSpec, base: &SystemConfig, batches: &[usize]) -> Vec<SweepRow> {
    batches
        .iter()
        .map(|&b| {
            let mut cfg = base.clone();
            cfg.batch = b;
            let cv = model.cost_vectors(&cfg);
            SweepRow {
                x: b as f64,
                values: Strategy::ALL
                    .iter()
                    .map(|&s| (s, reduced_ratio(&cv, s)))
                    .collect(),
            }
        })
        .collect()
}

/// Fig. 9 (b): iteration-time-reduced ratio versus nominal bandwidth.
pub fn sweep_bandwidth(
    model: &ModelSpec,
    base: &SystemConfig,
    bandwidths_gbps: &[f64],
) -> Vec<SweepRow> {
    bandwidths_gbps
        .iter()
        .map(|&bw| {
            let mut cfg = base.clone();
            cfg.net.bandwidth_gbps = bw;
            let cv = model.cost_vectors(&cfg);
            SweepRow {
                x: bw,
                values: Strategy::ALL
                    .iter()
                    .map(|&s| (s, reduced_ratio(&cv, s)))
                    .collect(),
            }
        })
        .collect()
}

/// Fig. 11: speedup versus number of workers.
pub fn sweep_workers(model: &ModelSpec, base: &SystemConfig, workers: &[usize]) -> Vec<SweepRow> {
    workers
        .iter()
        .map(|&n| SweepRow {
            x: n as f64,
            values: Strategy::ALL
                .iter()
                .map(|&s| (s, cluster::speedup(model, base, s, n)))
                .collect(),
        })
        .collect()
}

impl SweepRow {
    pub fn get(&self, s: Strategy) -> f64 {
        self.values.iter().find(|(k, _)| *k == s).map(|(_, v)| *v).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn batch_sweep_shows_crossover_shape() {
        // Fig. 9a: gains rise from small batches, then fall once compute
        // dominates — the ratio at a very large batch must be below the
        // peak.
        let m = models::by_name("resnet152").unwrap();
        let cfg = SystemConfig::default();
        let rows = sweep_batch(&m, &cfg, &[4, 8, 16, 24, 32, 48, 64, 96, 128]);
        let dyna: Vec<f64> = rows.iter().map(|r| r.get(Strategy::DynaComm)).collect();
        let peak = dyna.iter().cloned().fold(f64::MIN, f64::max);
        assert!(*dyna.last().unwrap() < peak, "{dyna:?}");
        assert!(peak > 0.2, "peak reduction too small: {peak}");
    }

    #[test]
    fn bandwidth_sweep_peak_in_the_middle() {
        // Fig. 9b shape: low at comm-bound (1 Gbps), peak at balanced
        // (5 Gbps), lower again at compute-bound (10 Gbps).
        let m = models::by_name("resnet152").unwrap();
        let cfg = SystemConfig::default();
        let rows = sweep_bandwidth(&m, &cfg, &[1.0, 5.0, 10.0]);
        let d: Vec<f64> = rows.iter().map(|r| r.get(Strategy::DynaComm)).collect();
        assert!(d[1] > d[0], "5 Gbps ({}) should beat 1 Gbps ({})", d[1], d[0]);
        assert!(d[1] > d[2], "5 Gbps ({}) should beat 10 Gbps ({})", d[1], d[2]);
    }

    #[test]
    fn worker_sweep_monotone_strategies_ranked() {
        let m = models::by_name("resnet152").unwrap();
        let cfg = SystemConfig::default();
        let rows = sweep_workers(&m, &cfg, &[1, 2, 4, 8]);
        for r in &rows {
            assert!(r.get(Strategy::DynaComm) >= r.get(Strategy::LayerByLayer) - 1e-9);
            assert!(r.get(Strategy::DynaComm) >= r.get(Strategy::Sequential) - 1e-9);
        }
        // speedup grows with workers for DynaComm.
        assert!(rows[3].get(Strategy::DynaComm) > rows[0].get(Strategy::DynaComm));
    }

    #[test]
    fn dynacomm_dominates_across_sweeps() {
        let m = models::by_name("resnet152").unwrap();
        let cfg = SystemConfig::default();
        for rows in [
            sweep_batch(&m, &cfg, &[8, 16, 32, 64]),
            sweep_bandwidth(&m, &cfg, &[1.0, 2.0, 5.0, 10.0, 20.0]),
        ] {
            for r in rows {
                let d = r.get(Strategy::DynaComm);
                for s in [Strategy::Sequential, Strategy::LayerByLayer, Strategy::IBatch] {
                    assert!(d >= r.get(s) - 1e-9, "x={} {}", r.x, s.name());
                }
            }
        }
    }
}
