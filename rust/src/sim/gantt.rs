//! ASCII Gantt rendering of mini-procedure timelines — the textual
//! equivalent of the paper's Fig. 2 / Fig. 3 diagrams, used by the
//! quickstart example and handy when debugging schedules.

use super::timeline::{Event, EventKind};

/// Render a two-lane (comm / comp) Gantt chart, `width` characters wide.
pub fn render(events: &[Event], width: usize) -> String {
    assert!(width >= 10);
    let end = events.iter().map(|e| e.end).fold(0.0_f64, f64::max);
    if end <= 0.0 {
        return String::new();
    }
    let scale = width as f64 / end;
    let mut comm = vec![' '; width];
    let mut comp = vec![' '; width];
    for e in events {
        let (lane, ch) = match e.kind {
            EventKind::ParamTx => (&mut comm, '▒'),
            EventKind::GradTx => (&mut comm, '▓'),
            EventKind::FwdComp => (&mut comp, '█'),
            EventKind::BwdComp => (&mut comp, '█'),
        };
        let a = ((e.start * scale) as usize).min(width - 1);
        let b = ((e.end * scale).ceil() as usize).clamp(a + 1, width);
        for c in lane[a..b].iter_mut() {
            *c = ch;
        }
        // Tick the segment boundary so adjacent segments stay visible.
        lane[a] = '|';
    }
    let mut out = String::new();
    out.push_str("comm ");
    out.extend(comm);
    out.push('\n');
    out.push_str("comp ");
    out.extend(comp);
    out.push('\n');
    out.push_str(&format!("     0{:>width$.1} ms\n", end, width = width - 1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::testutil::random_cv;
    use crate::sched::{dynacomm, Decomposition};
    use crate::sim::timeline::{backward_timeline, forward_timeline};
    use crate::util::rng::Rng;

    #[test]
    fn renders_two_lanes() {
        let mut rng = Rng::new(81);
        let cv = random_cv(&mut rng, 6);
        let ev = forward_timeline(&cv, &dynacomm::forward(&cv));
        let g = render(&ev, 60);
        let lines: Vec<&str> = g.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("comm "));
        assert!(lines[1].starts_with("comp "));
        assert!(lines[0].contains('▒'));
        assert!(lines[1].contains('█'));
    }

    #[test]
    fn backward_uses_grad_glyph() {
        let mut rng = Rng::new(82);
        let cv = random_cv(&mut rng, 4);
        let ev = backward_timeline(&cv, &Decomposition::layer_by_layer(4));
        let g = render(&ev, 40);
        assert!(g.contains('▓'));
    }

    #[test]
    fn empty_events_render_empty() {
        assert_eq!(render(&[], 40), "");
    }
}
