//! Discrete-event simulation of distributed training iterations.
//!
//! * [`timeline`] — explicit mini-procedure event timelines honoring the
//!   partial-order constraints (1)–(7); cross-validates the O(L) `f_m`
//!   evaluator in `sched::cost` and feeds the per-segment Gantt output of
//!   the examples.
//! * [`cluster`] — multi-worker BSP model with server-side bandwidth
//!   contention (Fig. 11 scalability).
//! * [`straggler`] — per-worker slowdown injection × sync modes
//!   (`ps::sync`): what BSP loses to a slow worker and how much
//!   bounded-staleness SSP / async ASP recover.
//! * [`sweep`] — batch-size / bandwidth / worker sweeps (Fig. 9, Fig. 11).
//! * [`workload`] — random profile generator (Fig. 12, Table I).

pub mod cluster;
pub mod gantt;
pub mod straggler;
pub mod sweep;
pub mod timeline;
pub mod workload;

use crate::config::{Strategy, SystemConfig};
use crate::models::ModelSpec;
use crate::sched::{self, CostVectors, IterationBreakdown, ScheduledPlan, Scheduler};

/// Simulate one iteration of `model` under `cfg` with the configured
/// strategy: derive cost vectors, run the scheduler, evaluate the timeline.
pub fn simulate(model: &ModelSpec, cfg: &SystemConfig) -> SimResult {
    let cv = model.cost_vectors(cfg);
    let mut scheduler =
        sched::registry::create_for_with(cfg.strategy, cfg.scheduler_params());
    let (sched, breakdown) = simulate_scheduler(scheduler.as_mut(), &cv);
    SimResult { strategy: cfg.strategy, sched, breakdown }
}

/// Same, over externally supplied cost vectors (real profiles, workloads),
/// with a fresh default-parameter scheduler from the registry.
pub fn simulate_cv(cv: &CostVectors, strategy: Strategy) -> SimResult {
    let mut scheduler = sched::registry::create_for(strategy);
    let (sched, breakdown) = simulate_scheduler(scheduler.as_mut(), cv);
    SimResult { strategy, sched, breakdown }
}

/// Core entry: run any [`Scheduler`] (possibly stateful, mid-sequence —
/// registry-only entries included) and evaluate its plan on the
/// independent timeline evaluator.
pub fn simulate_scheduler(
    scheduler: &mut dyn Scheduler,
    cv: &CostVectors,
) -> (ScheduledPlan, IterationBreakdown) {
    let sched = scheduler.plan(cv);
    let breakdown = sched::eval_iteration(cv, &sched.plan.fwd, &sched.plan.bwd);
    (sched, breakdown)
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub strategy: Strategy,
    /// The scheduler's decision plus its own predicted finish times.
    pub sched: ScheduledPlan,
    /// The independent timeline evaluation of that plan.
    pub breakdown: IterationBreakdown,
}

impl SimResult {
    pub fn total_ms(&self) -> f64 {
        self.breakdown.total()
    }
}

/// Figs. 5–8 metric: execution time normalized by the Sequential strategy's
/// total for the same pass.
#[derive(Debug, Clone, Copy)]
pub struct Normalized {
    pub comp_only: f64,
    pub overlap: f64,
    pub comm_only: f64,
}

impl Normalized {
    pub fn total(&self) -> f64 {
        self.comp_only + self.overlap + self.comm_only
    }
}

/// Normalize a pass breakdown against a baseline total.
pub fn normalize(pass: &sched::PassBreakdown, baseline_total: f64) -> Normalized {
    Normalized {
        comp_only: pass.comp_only / baseline_total,
        overlap: pass.overlap / baseline_total,
        comm_only: pass.comm_only / baseline_total,
    }
}

/// Iteration-time-reduced ratio vs Sequential (Fig. 9 metric).
pub fn reduced_ratio(cv: &CostVectors, strategy: Strategy) -> f64 {
    let seq = simulate_cv(cv, Strategy::Sequential).total_ms();
    let opt = simulate_cv(cv, strategy).total_ms();
    1.0 - opt / seq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn dynacomm_wins_on_every_paper_model() {
        // The paper's headline: DynaComm achieves optimal layer-wise
        // scheduling for ALL cases compared to competing strategies.
        let mut cfg = SystemConfig::default();
        for batch in [16, 32] {
            cfg.batch = batch;
            for m in models::paper_models() {
                let cv = m.cost_vectors(&cfg);
                let dyna = simulate_cv(&cv, Strategy::DynaComm).total_ms();
                for s in [Strategy::Sequential, Strategy::LayerByLayer, Strategy::IBatch] {
                    let t = simulate_cv(&cv, s).total_ms();
                    assert!(
                        dyna <= t + 1e-6,
                        "{} bs={batch}: dynacomm={dyna:.2} {}={t:.2}",
                        m.name,
                        s.name()
                    );
                }
            }
        }
    }

    #[test]
    fn scheduler_predictions_match_timeline_eval() {
        // Every strategy's self-reported predicted finish time must agree
        // with the independent timeline evaluation of its plan — the
        // ScheduledPlan contract.
        let cfg = SystemConfig::default();
        for m in models::paper_models() {
            let cv = m.cost_vectors(&cfg);
            for s in Strategy::ALL {
                let r = simulate_cv(&cv, s);
                assert!(
                    (r.sched.predicted_fwd_ms - r.breakdown.fwd.total).abs() < 1e-6,
                    "{} {} fwd", m.name, s.name()
                );
                assert!(
                    (r.sched.predicted_bwd_ms - r.breakdown.bwd.total).abs() < 1e-6,
                    "{} {} bwd", m.name, s.name()
                );
                assert!((r.sched.predicted_ms() - r.total_ms()).abs() < 1e-6);
                assert!(!r.sched.reused, "fresh scheduler cannot reuse");
            }
        }
    }

    #[test]
    fn reduced_ratio_in_unit_range() {
        let cfg = SystemConfig::default();
        for m in models::paper_models() {
            let cv = m.cost_vectors(&cfg);
            for s in Strategy::ALL {
                let r = reduced_ratio(&cv, s);
                assert!((-0.5..1.0).contains(&r), "{} {} r={r}", m.name, s.name());
            }
        }
    }

    #[test]
    fn dynacomm_reduction_is_substantial() {
        // Paper: up to ~42% iteration-time reduction. Our calibrated
        // testbed should land layer-wise gains in the tens of percent.
        let cfg = SystemConfig::default();
        let m = crate::models::by_name("resnet152").unwrap();
        let cv = m.cost_vectors(&cfg);
        let r = reduced_ratio(&cv, Strategy::DynaComm);
        assert!(r > 0.15, "reduction only {r:.3}");
    }
}
